"""Architecture registry: one module per assigned arch (+ the paper's own
co-occurrence workload). ``get_spec(arch_id)`` returns the full-size config;
``spec.smoke()`` returns the reduced same-family config for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell: ``kind`` selects which step gets lowered."""

    name: str
    kind: str  # "train" | "prefill" | "decode" | "serve" | "full_graph" | ...
    sizes: dict


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # "lm" | "gnn" | "recsys" | "cooc"
    model: Any
    shapes: dict[str, ShapeSpec]
    smoke: Callable[[], Any]  # reduced-config factory for CPU smoke tests
    notes: str = ""


_ARCH_MODULES = {
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "nemotron-4-340b": "repro.configs.nemotron_4_340b",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "dien": "repro.configs.dien",
    "bert4rec": "repro.configs.bert4rec",
    "xdeepfm": "repro.configs.xdeepfm",
    "bst": "repro.configs.bst",
    "cooc-wt10g": "repro.configs.cooc_wt10g",
}


def list_archs() -> list[str]:
    return sorted(_ARCH_MODULES)


def get_spec(arch_id: str) -> ArchSpec:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list_archs()}")
    return importlib.import_module(_ARCH_MODULES[arch_id]).spec()


# the four LM input-shape cells (same set for all five LM archs)
def lm_shapes() -> dict[str, ShapeSpec]:
    return {
        "train_4k": ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
        "prefill_32k": ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
        "decode_32k": ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
        "long_500k": ShapeSpec("long_500k", "decode", dict(seq_len=524288, global_batch=1)),
    }
