"""Nemotron-4-340B — dense GQA (96Q/8KV), squared-ReLU FFN
[arXiv:2402.16819; unverified]."""

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="nemotron-4-340b",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_head=192,
    d_ff=73728,
    vocab_size=256000,
    attn="gqa",
    ffn_kind="squared_relu",
    dtype="bfloat16",
)


def smoke():
    return LMConfig(
        name="nemotron-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=256,
        vocab_size=256,
        attn="gqa",
        ffn_kind="squared_relu",
        dtype="float32",
        kv_chunk=16,
        remat=False,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="nemotron-4-340b",
        family="lm",
        model=CONFIG,
        shapes=lm_shapes(),
        smoke=smoke,
        notes="Largest dense arch (d_model=18432); squared-ReLU (Primer) "
        "FFN, no gate matrix.",
    )
