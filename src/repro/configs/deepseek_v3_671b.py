"""DeepSeek-V3 671B — MLA, 1 shared + 256 routed experts (top-8), MTP.
[arXiv:2412.19437; hf]. Uniform MoE stack (the real model's first three
dense layers are folded into the MoE stack — noted in DESIGN.md)."""

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: latent-compressed, all heads share the latent
    d_head=128,
    d_ff=2048,               # MoE expert intermediate size
    vocab_size=129280,
    attn="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    ffn_kind="swiglu",
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    expert_d_ff=2048,
    mtp=True,
    dtype="bfloat16",
)


def smoke():
    return LMConfig(
        name="deepseek-v3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=96,
        vocab_size=256,
        attn="mla",
        q_lora_rank=32,
        kv_lora_rank=24,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        ffn_kind="swiglu",
        n_experts=8,
        top_k=2,
        capacity_factor=8.0,  # no drops → decode ≡ forward is exactly testable
        n_shared_experts=1,
        expert_d_ff=96,
        mtp=True,
        dtype="float32",
        kv_chunk=16,
        remat=False,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="deepseek-v3-671b",
        family="lm",
        model=CONFIG,
        shapes=lm_shapes(),
        smoke=smoke,
        notes="MLA + fine-grained MoE + MTP; absorbed-MLA decode keeps the "
        "500k cache in latent space (576 dims/token vs 32768 for full KV).",
    )
