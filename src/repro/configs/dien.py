"""DIEN — Deep Interest Evolution Network [arXiv:1809.03672].
embed_dim=18, seq_len=100, GRU/AUGRU dim 108, MLP 200-80."""

import dataclasses

from repro.configs import ArchSpec, ShapeSpec
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="dien",
    kind="dien",
    n_items=10_000_000,
    n_cats=10_000,
    embed_dim=18,
    seq_len=100,
    gru_dim=108,
    mlp=(200, 80),
)


def recsys_shapes() -> dict:
    return {
        "train_batch": ShapeSpec("train_batch", "train", dict(batch=65536)),
        "serve_p99": ShapeSpec("serve_p99", "serve", dict(batch=512)),
        "serve_bulk": ShapeSpec("serve_bulk", "serve", dict(batch=262144)),
        "retrieval_cand": ShapeSpec(
            "retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)
        ),
    }


def smoke():
    return dataclasses.replace(
        CONFIG, name="dien-smoke", n_items=1000, n_cats=50, seq_len=12
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dien",
        family="recsys",
        model=CONFIG,
        shapes=recsys_shapes(),
        smoke=smoke,
        notes="GRU interest extraction + AUGRU interest evolution "
        "(lax.scan over the 100-step behaviour sequence).",
    )
