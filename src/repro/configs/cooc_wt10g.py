"""The paper's own workload: WT10G-scale co-occurrence counting
(1.69M docs, 5.75M vocab, 74.1B distinct pairs — Table 1 rightmost column).

The dry-run lowers the distributed FREQ-SPLIT steps: the dense-head Gram
accumulation (MXU path) and the sparse-tail histogram (scatter path)."""

import dataclasses

from repro.configs import ArchSpec, ShapeSpec


@dataclasses.dataclass(frozen=True)
class CoocConfig:
    name: str
    num_docs: int
    vocab_size: int
    head: int                 # FREQ-SPLIT head size (df-descending IDs)
    doc_chunk: int            # documents per device-side Gram accumulation
    schedule: str = "ring"    # "ring" | "allgather" (EXPERIMENTS.md §Perf)
    dtype: str = "bfloat16"


CONFIG = CoocConfig(
    name="cooc-wt10g",
    num_docs=1_691_666,
    vocab_size=5_750_000,
    head=65_536,
    doc_chunk=524_288,
)

SHAPES = {
    "head_gram": ShapeSpec(
        "head_gram", "cooc_gram", dict(doc_chunk=524_288, head=65_536)
    ),
    "tail_hist": ShapeSpec(
        "tail_hist", "cooc_hist",
        dict(postings_chunk=8_388_608, rows=256, vocab_tile=65_536),
    ),
}


def smoke():
    return dataclasses.replace(
        CONFIG, name="cooc-smoke", num_docs=512, vocab_size=256, head=32,
        doc_chunk=128, dtype="float32",
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="cooc-wt10g",
        family="cooc",
        model=CONFIG,
        shapes=SHAPES,
        smoke=smoke,
        notes="C = Σ_s B_sᵀ B_s; docs shard over (pod, data), vocab tiles "
        "over model; ring collective-permute schedule overlaps comm/compute.",
    )
