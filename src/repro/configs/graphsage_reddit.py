"""GraphSAGE (Reddit config): 2 layers, d_hidden=128, mean aggregator,
sample sizes 25-10 [arXiv:1706.02216]. Each shape cell carries its own
d_feat / graph size; the dry-run overrides d_in per shape."""

import dataclasses

from repro.configs import ArchSpec, ShapeSpec
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="graphsage-reddit",
    n_layers=2,
    d_in=602,          # Reddit features (overridden per shape)
    d_hidden=128,
    n_classes=41,
    aggregator="mean",
    sample_sizes=(25, 10),
)

SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "full_graph",
        dict(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7),
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "sampled",
        dict(
            n_nodes=232965, n_edges=114615892, batch_nodes=1024,
            fanout=(15, 10), d_feat=602, n_classes=41,
        ),
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "full_graph",
        dict(n_nodes=2449029, n_edges=61859140, d_feat=100, n_classes=47),
    ),
    "molecule": ShapeSpec(
        "molecule", "batched_graphs",
        dict(n_nodes=30, n_edges=64, batch=128, d_feat=16, n_classes=2),
    ),
}


def smoke():
    return dataclasses.replace(
        CONFIG, name="graphsage-smoke", d_in=32, d_hidden=16, n_classes=5,
        sample_sizes=(5, 3),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="graphsage-reddit",
        family="gnn",
        model=CONFIG,
        shapes=SHAPES,
        smoke=smoke,
        notes="Message passing = edge gather + segment_sum (no SpMM in JAX); "
        "minibatch_lg uses the real fixed-fanout sampler in data/sampler.py.",
    )
