"""xDeepFM [arXiv:1803.05170]: 39 sparse fields, embed_dim=10,
CIN 200-200-200, DNN 400-400. Three fields are multi-hot bags (exercises the
EmbeddingBag = take + masked-segment-sum substrate)."""

import dataclasses

from repro.configs import ArchSpec
from repro.configs.dien import recsys_shapes
from repro.models.recsys import RecsysConfig

# 30 small + 6 medium + 3 large (multi-hot) fields → 39M embedding rows
FIELD_VOCABS = tuple([100_000] * 30 + [1_000_000] * 6 + [10_000_000] * 3)

CONFIG = RecsysConfig(
    name="xdeepfm",
    kind="xdeepfm",
    embed_dim=10,
    field_vocabs=FIELD_VOCABS,
    n_multi_hot=3,
    max_bag=8,
    cin_layers=(200, 200, 200),
    mlp=(400, 400),
)


def smoke():
    return dataclasses.replace(
        CONFIG,
        name="xdeepfm-smoke",
        field_vocabs=tuple([50] * 6 + [100] * 2),
        n_multi_hot=2,
        max_bag=4,
        cin_layers=(8, 8),
        mlp=(16, 16),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="xdeepfm",
        family="recsys",
        model=CONFIG,
        shapes=recsys_shapes(),
        smoke=smoke,
        notes="CIN = outer-product + field-compression einsum; single "
        "39M-row table with per-field offsets, row-sharded over 'model'.",
    )
