"""MiniCPM3-4B — dense MLA [hf:openbmb/MiniCPM3-4B]."""

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=6400,
    vocab_size=73448,
    attn="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_nope_dim=64,
    qk_rope_dim=32,
    v_head_dim=64,
    ffn_kind="swiglu",
    dtype="bfloat16",
)


def smoke():
    return LMConfig(
        name="minicpm3-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        attn="mla",
        q_lora_rank=32,
        kv_lora_rank=24,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        ffn_kind="swiglu",
        dtype="float32",
        kv_chunk=16,
        remat=False,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="minicpm3-4b",
        family="lm",
        model=CONFIG,
        shapes=lm_shapes(),
        smoke=smoke,
        notes="Small dense MLA — the latent cache (288 dims/token) makes "
        "long_500k decode trivially memory-feasible.",
    )
