"""BST — Behavior Sequence Transformer (Alibaba) [arXiv:1905.06874]:
embed_dim=32, seq_len=20, 1 block, 8 heads, MLP 1024-512-256."""

import dataclasses

from repro.configs import ArchSpec
from repro.configs.dien import recsys_shapes
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="bst",
    kind="bst",
    n_items=4_000_000,
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp=(1024, 512, 256),
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="bst-smoke", n_items=800, seq_len=8, mlp=(32, 16, 8)
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="bst",
        family="recsys",
        model=CONFIG,
        shapes=recsys_shapes(),
        smoke=smoke,
        notes="Transformer over [history ⊕ target] then MLP; target-aware "
        "scoring (not two-tower) except the retrieval head projection.",
    )
