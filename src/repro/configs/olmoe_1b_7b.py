"""OLMoE-1B-7B — 64 experts top-8, QK-norm [arXiv:2409.02060; hf]."""

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab_size=50304,
    attn="gqa",
    qk_norm=True,
    ffn_kind="swiglu",
    n_experts=64,
    top_k=8,
    n_shared_experts=0,
    expert_d_ff=1024,
    dtype="bfloat16",
)


def smoke():
    return LMConfig(
        name="olmoe-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=96,
        vocab_size=256,
        attn="gqa",
        qk_norm=True,
        ffn_kind="swiglu",
        n_experts=8,
        top_k=2,
        capacity_factor=8.0,  # no drops → decode ≡ forward is exactly testable
        expert_d_ff=96,
        dtype="float32",
        kv_chunk=16,
        remat=False,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="olmoe-1b-7b",
        family="lm",
        model=CONFIG,
        shapes=lm_shapes(),
        smoke=smoke,
        notes="Fully-MHA MoE; 64 experts top-8; QK-norm.",
    )
