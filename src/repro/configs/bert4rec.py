"""BERT4Rec [arXiv:1904.06690]: bidirectional 2-block transformer over item
sequences, masked-item training, weight-tied full-softmax head."""

import dataclasses

from repro.configs import ArchSpec
from repro.configs.dien import recsys_shapes
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="bert4rec",
    kind="bert4rec",
    n_items=54_546,   # Steam dataset scale (paper's largest item set)
    embed_dim=64,
    seq_len=200,
    n_blocks=2,
    n_heads=2,
    n_masked=20,
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="bert4rec-smoke", n_items=500, seq_len=16, n_masked=4
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="bert4rec",
        family="recsys",
        model=CONFIG,
        shapes=recsys_shapes(),
        smoke=smoke,
        notes="Encoder-only (bidirectional) — serve shapes score full "
        "sequences; there is no KV-cache decode step (DESIGN.md §8).",
    )
