"""Qwen1.5-110B — dense GQA (64Q/8KV), QKV bias [hf:Qwen/Qwen1.5-110B]."""

from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-110b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49152,
    vocab_size=152064,
    attn="gqa",
    qkv_bias=True,
    ffn_kind="swiglu",
    dtype="bfloat16",
)


def smoke():
    return LMConfig(
        name="qwen-smoke",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_head=8,
        d_ff=192,
        vocab_size=256,
        attn="gqa",
        qkv_bias=True,
        ffn_kind="swiglu",
        dtype="float32",
        kv_chunk=16,
        remat=False,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen1.5-110b",
        family="lm",
        model=CONFIG,
        shapes=lm_shapes(),
        smoke=smoke,
        notes="Dense GQA with QKV bias; d_ff=49152 makes this the most "
        "FFN-dominated of the dense archs.",
    )
