"""Downstream statistics the paper motivates: PMI / PPMI / top-k pairs."""

from __future__ import annotations

import numpy as np


def pmi_matrix(counts: np.ndarray, df: np.ndarray, num_docs: int) -> np.ndarray:
    """PMI[i,j] = log( P(i,j) / (P(i)P(j)) ) over the strict upper triangle.

    counts: dense strict-upper co-occurrence matrix; df: document frequencies.
    Entries with zero co-occurrence are -inf (no smoothing — exact counts are
    the whole point of the paper).
    """
    D = float(num_docs)
    with np.errstate(divide="ignore", invalid="ignore"):
        p_ij = counts / D
        p_i = (df / D)[:, None]
        p_j = (df / D)[None, :]
        out = np.log(p_ij / (p_i * p_j))
    out[counts == 0] = -np.inf
    return np.triu(out, k=1)


def ppmi_matrix(counts: np.ndarray, df: np.ndarray, num_docs: int) -> np.ndarray:
    out = pmi_matrix(counts, df, num_docs)
    np.maximum(out, 0.0, out=out)
    out[~np.isfinite(out)] = 0.0
    return out


def top_k_pairs(counts: np.ndarray, k: int = 10):
    """Most frequent co-occurring pairs (paper §3: "to"–"the" at 1.3M docs)."""
    upper = np.triu(counts, k=1)
    flat = upper.ravel()
    k = min(k, int((flat > 0).sum()))
    if k == 0:
        return []
    idx = np.argpartition(flat, -k)[-k:]
    idx = idx[np.argsort(-flat[idx])]
    V = counts.shape[1]
    return [(int(i // V), int(i % V), int(flat[i])) for i in idx]
