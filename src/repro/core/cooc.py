"""Method registry + single entry point for co-occurrence counting."""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.hybrid import count_freq_split
from repro.core.list_blocks import count_list_blocks, count_list_blocks_gram
from repro.core.list_pairs import count_list_pairs, count_list_pairs_bitpacked
from repro.core.list_scan import count_list_scan, count_list_scan_segment
from repro.core.multi_scan import count_multi_scan, count_multi_scan_matmul
from repro.core.naive import count_naive
from repro.core.types import DenseSink, PairSink
from repro.data.corpus import Collection

# name -> counting callable(collection, sink, **kwargs) -> stats dict
METHODS: dict[str, Callable] = {
    # paper-faithful algorithms (§2)
    "naive": count_naive,
    "list-pairs": count_list_pairs,
    "list-blocks": count_list_blocks,
    "list-scan": count_list_scan,
    "multi-scan": count_multi_scan,
    # TPU adaptations (same traversal orders, MXU/VPU execution)
    "list-pairs-bitpacked": count_list_pairs_bitpacked,
    "list-blocks-gram": count_list_blocks_gram,
    "list-scan-segment": count_list_scan_segment,
    "multi-scan-matmul": count_multi_scan_matmul,
    # beyond-paper hybrid
    "freq-split": count_freq_split,
}


def count(method: str, c: Collection, sink: PairSink | None = None, **kwargs):
    """Run ``method`` over collection ``c``. Returns (sink, stats)."""
    if method not in METHODS:
        raise KeyError(f"unknown method {method!r}; have {sorted(METHODS)}")
    if sink is None:
        sink = DenseSink(c.vocab_size)
    stats = METHODS[method](c, sink, **kwargs)
    return sink, stats


def dense_counts(method: str, c: Collection, **kwargs) -> np.ndarray:
    """Convenience for tests: dense strict-upper count matrix."""
    sink, _ = count(method, c, DenseSink(c.vocab_size), **kwargs)
    return sink.mat


def count_to_store(
    method: str,
    c: Collection,
    store_path: str,
    *,
    memory_budget_pairs: int = 4 << 20,
    **kwargs,
):
    """Count ``c`` with ``method`` straight into a persistent queryable store
    (repro.store): output streams through a budgeted SpillSink, so the
    counting phase holds O(memory_budget_pairs) pairs instead of a dense V×V
    matrix. Creates the store if ``store_path`` is new, else appends a
    segment (exact incremental update). Returns (store, segment)."""
    from repro.store import Store  # deferred: store wires back into count()

    if Store.exists(store_path):
        store = Store.open(store_path)
        if store.vocab_size != c.vocab_size:
            raise ValueError(
                f"store vocab {store.vocab_size} != collection vocab {c.vocab_size}"
            )
    else:
        store = Store.create(store_path, c.vocab_size)
    seg = store.append_collection(
        c, method=method, memory_budget_pairs=memory_budget_pairs, **kwargs
    )
    return store, seg
