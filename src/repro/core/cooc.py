"""Compatibility entry points over the typed counting-plan API.

The method registry now lives in ``core/specs.py`` (typed ``MethodSpec``
records with validated params and §3 cost models) and planning/execution in
``core/plan.py`` (``CountJob`` → ``Planner`` → ``Plan`` → ``PlanExecutor``).
This module keeps the original call signatures as thin shims:

* ``count(method, c, sink, **kwargs)``   — one validated method invocation;
* ``dense_counts(method, c, **kwargs)``  — dense matrix convenience (tests);
* ``count_to_store(method, c, path)``    — count straight into a store;
* ``METHODS``                            — legacy name → callable view.

Migration: ``count("auto", ...)`` is not supported here — build a
``CountJob`` (with ``method="auto"``) and go through the ``Planner`` so the
selection is recorded in the plan.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.specs import REGISTRY, get_spec
from repro.core.types import DenseSink, PairSink
from repro.data.corpus import Collection

# legacy view of the typed registry (name -> counting callable); kept for
# callers that introspect the method set
METHODS: dict[str, Callable] = {name: spec.fn for name, spec in REGISTRY.items()}


def count(method: str, c: Collection, sink: PairSink | None = None, **kwargs):
    """Run ``method`` over collection ``c``. Returns (sink, stats).

    Compatibility shim over the typed registry: kwargs are validated against
    the method's ``MethodSpec`` (unknown or ill-typed params raise TypeError)
    and the output is byte-identical to calling the method directly.
    """
    spec = get_spec(method)  # KeyError for unknown methods (seed behavior)
    if sink is None:
        sink = DenseSink(c.vocab_size)
    stats = spec.run(c, sink, **kwargs)
    return sink, stats


def dense_counts(method: str, c: Collection, **kwargs) -> np.ndarray:
    """Convenience for tests: dense strict-upper count matrix."""
    sink, _ = count(method, c, DenseSink(c.vocab_size), **kwargs)
    return sink.mat


def count_to_store(
    method: str,
    c: Collection,
    store_path: str,
    *,
    memory_budget_pairs: int = 4 << 20,
    num_shards: int = 1,
    df_descending: bool = False,
    **kwargs,
):
    """Count ``c`` with ``method`` (or ``"auto"``) straight into a persistent
    queryable store (repro.store) through the plan executor: output streams
    through budgeted per-shard SpillSinks, so the counting phase holds
    O(memory_budget_pairs) pairs instead of a dense V×V matrix. Creates the
    store if ``store_path`` is new, else appends a segment (exact incremental
    update). Returns (store, segment)."""
    from repro.core.plan import CountJob, Planner

    job = CountJob(
        collection=c,
        output="store",
        method=method,
        out_path=store_path,
        memory_budget_pairs=memory_budget_pairs,
        num_shards=num_shards,
        df_descending=df_descending,
        method_kwargs=kwargs,
    )
    res = Planner().plan(job).execute()
    return res.store, res.segment
