"""NAÏVE (paper §2): document-order dictionary accumulation with flushing.

For each document, all distinct term pairs are generated and their dictionary
counts incremented. When the dictionary exceeds ``flush_pairs`` distinct pairs
(the paper used 100M) it is flushed to a temporary sorted run; runs are merged
at the end, accelerated by in-memory offsets to the primary keys — we keep the
same structure (sorted runs + k-way merge by primary key).

Pairs are packed into int64 keys (i * V + j) for the dictionary, exactly the
"pair → count" hash-map shape of the paper's implementation.
"""

from __future__ import annotations

import numpy as np

from repro.core.types import PairSink
from repro.data.corpus import Collection


def _doc_pair_keys(ts: np.ndarray, V: int) -> np.ndarray:
    """All strict-upper pair keys of one (sorted, unique) document."""
    n = len(ts)
    i = np.repeat(ts, n)
    j = np.tile(ts, n)
    mask = i < j
    return i[mask].astype(np.int64) * V + j[mask].astype(np.int64)


def count_naive(
    c: Collection, sink: PairSink, *, flush_pairs: int = 2_000_000
) -> dict:
    """Returns run statistics (number of flushes, peak dict size) alongside
    emitting the final merged counts to ``sink``."""
    V = c.vocab_size
    acc: dict[int, int] = {}
    runs: list[tuple[np.ndarray, np.ndarray]] = []  # (sorted keys, counts)
    peak = 0

    def flush():
        nonlocal acc
        if not acc:
            return
        keys = np.fromiter(acc.keys(), dtype=np.int64, count=len(acc))
        cnts = np.fromiter(acc.values(), dtype=np.int64, count=len(acc))
        order = np.argsort(keys)
        runs.append((keys[order], cnts[order]))
        acc = {}

    for d in range(c.num_docs):
        keys = _doc_pair_keys(c.doc(d), V)
        for k in keys.tolist():
            acc[k] = acc.get(k, 0) + 1
        peak = max(peak, len(acc))
        if len(acc) >= flush_pairs:
            flush()
    flush()

    n_runs = len(runs)
    _merge_runs(runs, V, sink)
    return {"num_flushes": n_runs, "peak_dict_pairs": peak}


def _merge_runs(runs, V: int, sink: PairSink) -> None:
    """K-way merge of sorted (key, count) runs, emitting per-primary rows."""
    if not runs:
        return
    if len(runs) == 1:
        keys, cnts = runs[0]
    else:
        keys = np.concatenate([r[0] for r in runs])
        cnts = np.concatenate([r[1] for r in runs])
        order = np.argsort(keys, kind="stable")
        keys, cnts = keys[order], cnts[order]
        # collapse duplicate keys (same pair in several runs)
        uniq, idx = np.unique(keys, return_index=True)
        sums = np.add.reduceat(cnts, idx)
        keys, cnts = uniq, sums
    primaries = (keys // V).astype(np.int64)
    secondaries = (keys % V).astype(np.int64)
    # rows are contiguous because keys are sorted by (primary, secondary)
    starts = np.concatenate([[0], np.nonzero(np.diff(primaries))[0] + 1, [len(keys)]])
    for s, e in zip(starts[:-1], starts[1:]):
        if e > s:
            sink.emit_row(int(primaries[s]), secondaries[s:e], cnts[s:e])
