"""LIST-BLOCKS (paper §2): block-pair-order traversal.

The vocabulary's inverted lists are aggregated into b blocks of up to k lists
each (b ≈ k ≈ √V, the paper's recommended choice). Within a block, postings
are re-organised by document — "smaller versions of the original documents"
restricted to that vocabulary slice. Blocks are then paired: the outer block
holds the primary keys, inner blocks the secondary keys; matching documents
generate primary × secondary count increments; finally within-outer pairs are
counted. b(b+1)/2 block pairs total; each outer block's accumulator is
complete (write-once) when its inner sweep finishes — no merge phase.

This is exactly a tiled upper-triangular Gram matmul C[I,J] = B[:,I]ᵀ B[:,J];
``count_list_blocks_gram`` runs the same traversal through the MXU Pallas
kernel (kernels/cooc_gram.py) on 0/1 incidence tiles.
"""

from __future__ import annotations

import math

import numpy as np

from repro.core.types import PairSink, emit_dense_rows
from repro.data.corpus import Collection
from repro.data.index import incidence_dense


def _block_mini_docs(c: Collection, lo: int, hi: int):
    """Postings of vocab block [lo, hi) re-organised by document:
    (doc_ids present, list of per-doc term arrays restricted to the block)."""
    doc_ids = []
    mini = []
    for d in range(c.num_docs):
        ts = c.doc(d)
        sel = ts[(ts >= lo) & (ts < hi)]
        if len(sel):
            doc_ids.append(d)
            mini.append(sel)
    return np.asarray(doc_ids, dtype=np.int64), mini


def count_list_blocks(c: Collection, sink: PairSink, *, block_size: int | None = None) -> dict:
    V = c.vocab_size
    k = block_size or max(1, int(math.isqrt(V)))
    nblk = (V + k - 1) // k
    block_pairs = 0

    # Pre-build all blocks' mini documents (the paper holds the collection in
    # memory for this method; blocks are the dominant memory consumer).
    blocks = []
    for b in range(nblk):
        lo, hi = b * k, min((b + 1) * k, V)
        blocks.append((lo, hi, *_block_mini_docs(c, lo, hi)))

    for bo in range(nblk):
        lo, hi, docs_o, mini_o = blocks[bo]
        width = hi - lo
        acc = np.zeros((width, V - lo), dtype=np.int64)  # primary-local × [lo, V)
        # within-outer-block pairs first (the paper's "inner join")
        for ts in mini_o:
            loc = ts - lo
            n = len(loc)
            if n >= 2:
                ii = np.repeat(loc, n)
                jj = np.tile(loc, n)
                m = ii < jj
                np.add.at(acc, (ii[m], jj[m]), 1)
        block_pairs += 1
        # pair with all inner blocks to the right
        for bi in range(bo + 1, nblk):
            ilo, ihi, docs_i, mini_i = blocks[bi]
            block_pairs += 1
            # matching document pairs via sorted merge of doc id lists
            oi = np.searchsorted(docs_o, docs_i)
            oi = np.clip(oi, 0, len(docs_o) - 1) if len(docs_o) else oi
            for pos_i, d in enumerate(docs_i):
                if len(docs_o) == 0:
                    break
                p = oi[pos_i]
                if p < len(docs_o) and docs_o[p] == d:
                    prim = mini_o[p] - lo
                    sec = mini_i[pos_i] - lo
                    np.add.at(acc, (np.repeat(prim, len(sec)), np.tile(sec, len(prim))), 1)
        emit_dense_rows(acc, sink, row_lo=lo, col_lo=lo)
        blocks[bo] = None  # discard outer block (paper: "no longer considered")
    return {"num_blocks": nblk, "block_pairs": block_pairs, "block_size": k}


def count_list_blocks_gram(
    c: Collection,
    sink: PairSink,
    *,
    vocab_tile: int = 512,
    doc_tile: int = 2048,
    use_kernel: bool = True,
) -> dict:
    """TPU-adapted LIST-BLOCKS: tiled Gram matmul over 0/1 incidence tiles.

    Streams (doc_tile × vocab_tile) tiles of B through the Pallas MXU kernel
    (kernels/cooc_gram.py). Tiling over documents bounds device memory the
    same way the paper's flushing bounds host memory — but every output tile
    is complete when emitted, so there is no merge. f32 accumulation is exact
    for per-call doc counts < 2^24.
    """
    from repro.kernels import ops as kops

    V, D = c.vocab_size, c.num_docs
    nvb = (V + vocab_tile - 1) // vocab_tile
    matmuls = 0
    for bi in range(nvb):
        ilo, ihi = bi * vocab_tile, min((bi + 1) * vocab_tile, V)
        for bj in range(bi, nvb):
            jlo, jhi = bj * vocab_tile, min((bj + 1) * vocab_tile, V)
            acc = np.zeros((ihi - ilo, jhi - jlo), dtype=np.int64)
            for dlo in range(0, D, doc_tile):
                dhi = min(dlo + doc_tile, D)
                bi_tile = incidence_dense(c, dlo, dhi, ilo, ihi)
                bj_tile = (
                    bi_tile
                    if (jlo, jhi) == (ilo, ihi)
                    else incidence_dense(c, dlo, dhi, jlo, jhi)
                )
                acc += np.asarray(
                    kops.cooc_gram(bi_tile, bj_tile, use_kernel=use_kernel)
                ).astype(np.int64)
                matmuls += 1
            emit_dense_rows(acc, sink, row_lo=ilo, col_lo=jlo)
    return {"vocab_tiles": nvb, "matmuls": matmuls}
