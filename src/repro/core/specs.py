"""Typed method specifications: the counting registry, made first-class.

Each counting method is described by a frozen :class:`MethodSpec` — its
callable, its typed/validated tuning parameters, and a **cost model** derived
from the paper's §3 asymptotics, expressed over :class:`CollectionStats`
(documents, postings, df distribution, vocabulary). The specs replace the
raw ``METHODS`` dict: drivers and benchmarks stop re-hardcoding per-method
kwargs tables, and the planner (core/plan.py) turns ``method="auto"`` into a
measured decision instead of a caller-supplied string.

Cost-model units: one vectorized numpy element operation ≈ 1 unit; a
Python-level call (loop iteration, numpy dispatch) is charged a constant
number of units. The absolute scale is arbitrary — only the *ranking* across
methods matters — but the terms mirror the paper's analysis:

* NAÏVE          O(Σ len²) dictionary operations (large constant);
* LIST-PAIRS     O(v²) intersections, each reading both posting lists;
* LIST-BLOCKS    b ≈ √V blocks → O(P·√V) postings work, no merge phase;
* LIST-SCAN      O(Σ len²) element work + per-posting traversal + a
                 V-wide accumulator per live row;
* MULTI-SCAN     ⌈V/a⌉ passes over the (shrinking) forward file;
* FREQ-SPLIT     dense head Gram (matmul-cheap) + tail postings work.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping

import numpy as np

from repro.data.corpus import Collection, CollectionStats

# ---------------------------------------------------------------------------
# typed tuning parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Param:
    """One typed tuning knob of a counting method."""

    name: str
    type: type
    default: object
    minimum: int | None = None
    allow_none: bool = False
    doc: str = ""

    def validate(self, value):
        """Coerce-free validation; raises TypeError/ValueError."""
        if value is None:
            if not self.allow_none:
                raise TypeError(f"param {self.name!r} must not be None")
            return value
        # bool is an int subclass; keep the two distinct for clarity
        if self.type is int and isinstance(value, bool):
            raise TypeError(f"param {self.name!r} expects int, got bool")
        if not isinstance(value, self.type):
            raise TypeError(
                f"param {self.name!r} expects {self.type.__name__}, "
                f"got {type(value).__name__} ({value!r})"
            )
        if self.minimum is not None and value < self.minimum:
            raise ValueError(
                f"param {self.name!r} must be >= {self.minimum}, got {value}"
            )
        return value


# ---------------------------------------------------------------------------
# cost-model constants (calibrated against the CPU reference implementations;
# see tests/test_plan.py golden selections)
# ---------------------------------------------------------------------------

ELEM = 1.0          # one vectorized element op
CALL = 8.0          # cheap numpy dispatch / intersection call overhead
PY_LOOP = 48.0      # Python-level per-iteration overhead (doc fetch, slicing)
DICT_OP = 16.0      # per-pair dictionary get/set (NAÏVE's large constant)
MATMUL_ELEM = 0.002  # per-flop cost of a BLAS/MXU Gram matmul


def cost_naive(s: CollectionStats, kw: Mapping) -> float:
    # every pair occurrence is a dictionary operation; flushing adds sorts
    return DICT_OP * 2.0 * s.pair_occurrences + PY_LOOP * s.num_docs


def cost_list_pairs(s: CollectionStats, kw: Mapping) -> float:
    # v²/2 intersections; each reads both posting lists → Σ_{i<j}(df_i+df_j)
    # = (v-1)·P elements
    v = s.live_vocab
    return 0.5 * v * v * CALL + ELEM * max(v - 1, 0) * s.num_postings


def cost_list_blocks(s: CollectionStats, kw: Mapping) -> float:
    V = s.vocab_size
    k = kw.get("block_size") or max(1, math.isqrt(V))
    b = (V + k - 1) // k
    return (
        PY_LOOP / 6.0 * s.num_docs * b          # block build: doc scan per block
        + 2.0 * CALL * b * b                    # block-pair loop overhead
        + 1.5 * ELEM * s.num_postings * b       # postings touched per block pair
        + 4.0 * ELEM * 2.0 * s.pair_occurrences  # np.add.at increments
    )


def cost_list_scan(s: CollectionStats, kw: Mapping) -> float:
    return (
        2.0 * CALL * s.live_vocab               # per-row bookkeeping
        + 2.0 * PY_LOOP * s.num_postings        # per-(term, doc) inner loop
        + 2.0 * ELEM * 2.0 * s.pair_occurrences  # histogram increments
        + 0.5 * ELEM * s.live_vocab * s.vocab_size  # row clear + nonzero scan
    )


def cost_multi_scan(s: CollectionStats, kw: Mapping) -> float:
    a = kw.get("accumulators", 100)
    passes = max(1, (s.vocab_size + a - 1) // a)
    # the skip ("fully processed documents") halves the effective doc scans
    docs_scanned = 0.5 * s.num_docs * passes if passes > 1 else s.num_docs
    return (
        1.5 * PY_LOOP * docs_scanned            # per-doc window probe
        + 1.5 * PY_LOOP * s.num_postings        # per primary occurrence
        + 2.0 * ELEM * 2.0 * s.pair_occurrences
        + 0.25 * ELEM * s.vocab_size * s.vocab_size  # a×V accumulator sweeps
    )


def cost_freq_split(s: CollectionStats, kw: Mapping) -> float:
    H = min(kw.get("head", 1024), s.vocab_size)
    head_postings = s.postings_in_top(H)
    tail_postings = s.num_postings - head_postings
    return (
        MATMUL_ELEM * s.num_docs * H * H        # dense head Gram (MXU/BLAS)
        + 0.5 * ELEM * s.num_docs * H           # incidence tile build
        + 2.0 * PY_LOOP * tail_postings         # tail LIST-SCAN inner loop
        + 2.0 * ELEM * 2.0 * s.pair_occurrences
        + 0.25 * ELEM * (s.vocab_size - H) * s.vocab_size  # tail col sweeps
    )


def _tpu_discount(base: Callable[[CollectionStats, Mapping], float]):
    """TPU adaptations follow their parent traversal's asymptotics; rank them
    with the parent's model (auto-selection never picks them — they are
    explicit choices for accelerator runs)."""
    return base


# working-set estimates (bytes) -------------------------------------------------


def mem_naive(s: CollectionStats, kw: Mapping) -> float:
    flush = kw.get("flush_pairs", 2_000_000)
    return 100.0 * min(flush, 2.0 * s.pair_occurrences + 1)


def mem_list_pairs(s: CollectionStats, kw: Mapping) -> float:
    return 8.0 * (s.num_postings + s.live_vocab)  # inverted index


def mem_list_blocks(s: CollectionStats, kw: Mapping) -> float:
    V = s.vocab_size
    k = kw.get("block_size") or max(1, math.isqrt(V))
    return 8.0 * k * V + 8.0 * s.num_postings  # outer accumulator + blocks


def mem_list_scan(s: CollectionStats, kw: Mapping) -> float:
    return 8.0 * s.vocab_size + 8.0 * s.num_postings  # row acc + index


def mem_multi_scan(s: CollectionStats, kw: Mapping) -> float:
    a = kw.get("accumulators", 100)
    return 8.0 * a * s.vocab_size


def mem_freq_split(s: CollectionStats, kw: Mapping) -> float:
    H = min(kw.get("head", 1024), s.vocab_size)
    return 8.0 * H * H + 8.0 * (s.num_postings + s.vocab_size)


# ---------------------------------------------------------------------------
# MethodSpec + registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Everything the planner, drivers, and benchmarks need to know about one
    counting method — replacing the stringly-typed ``METHODS`` dict entry and
    the per-driver kwargs tables."""

    name: str
    fn: Callable
    kind: str  # "paper" | "tpu" | "hybrid"
    params: tuple[Param, ...] = ()
    cost: Callable[[CollectionStats, Mapping], float] = cost_list_scan
    memory_bytes: Callable[[CollectionStats, Mapping], float] = mem_list_scan
    needs_df_descending: bool = False
    needs_emit_col: bool = False
    # benchmark metadata (single source of truth for benchmarks/common.py):
    # kwarg overrides used by the figure benchmarks, and the document-count
    # cap beyond which the method is too slow to benchmark (None = unbounded).
    # ``bench_caps`` holds per-suite exceptions — e.g. the subprocess memory
    # figure tolerates scales the timing figure can't.
    bench_overrides: Mapping[str, object] = dataclasses.field(default_factory=dict)
    bench_max_docs: int | None = None
    bench_caps: Mapping[str, int] = dataclasses.field(default_factory=dict)
    doc: str = ""

    # -------------------------------------------------------------- params
    def param(self, name: str) -> Param:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(f"method {self.name!r} has no param {name!r}")

    def defaults(self) -> dict:
        return {p.name: p.default for p in self.params}

    def validate_kwargs(self, kwargs: Mapping) -> dict:
        """Validate a *partial* kwargs mapping (unknown keys rejected)."""
        known = {p.name: p for p in self.params}
        out = {}
        for k, v in kwargs.items():
            if k not in known:
                raise TypeError(
                    f"method {self.name!r} got unknown param {k!r}; "
                    f"valid: {sorted(known) or 'none'}"
                )
            out[k] = known[k].validate(v)
        return out

    def resolve_kwargs(self, overrides: Mapping | None = None) -> dict:
        """Defaults merged with validated ``overrides`` — the full kwargs the
        method callable will be invoked with."""
        out = self.defaults()
        if overrides:
            out.update(self.validate_kwargs(overrides))
        return out

    # ---------------------------------------------------------------- run
    def run(self, c: Collection, sink, **kwargs) -> dict:
        """Invoke the method (kwargs validated first)."""
        return self.fn(c, sink, **self.validate_kwargs(kwargs))


_P = Param  # local shorthand for the table below

REGISTRY: dict[str, MethodSpec] = {}


def register(spec: MethodSpec) -> MethodSpec:
    if spec.name in REGISTRY:
        raise ValueError(f"method {spec.name!r} already registered")
    REGISTRY[spec.name] = spec
    return spec


def get_spec(name: str) -> MethodSpec:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown method {name!r}; have {sorted(REGISTRY)}"
        ) from None


def method_names(kind: str | None = None) -> list[str]:
    return [n for n, s in REGISTRY.items() if kind is None or s.kind == kind]


def _build_registry() -> None:
    # deferred imports: the method modules import only data/ + types
    from repro.core.hybrid import count_freq_split
    from repro.core.list_blocks import count_list_blocks, count_list_blocks_gram
    from repro.core.list_pairs import count_list_pairs, count_list_pairs_bitpacked
    from repro.core.list_scan import count_list_scan, count_list_scan_segment
    from repro.core.multi_scan import count_multi_scan, count_multi_scan_matmul
    from repro.core.naive import count_naive

    use_kernel = _P("use_kernel", bool, True, doc="Pallas kernel vs jnp oracle")

    register(MethodSpec(
        "naive", count_naive, "paper",
        params=(_P("flush_pairs", int, 2_000_000, minimum=1,
                   doc="flush the pair dictionary past this many entries"),),
        cost=cost_naive, memory_bytes=mem_naive,
        bench_max_docs=1000, bench_caps={"fig2": 300, "scaling": 800},
        doc="document-order dictionary accumulation with flushing (§2)",
    ))
    register(MethodSpec(
        "list-pairs", count_list_pairs, "paper",
        cost=cost_list_pairs, memory_bytes=mem_list_pairs,
        bench_max_docs=100, bench_caps={"fig2": 300, "scaling": 200},
        doc="pair-order posting-list intersection (§2); quadratic in vocab",
    ))
    register(MethodSpec(
        "list-blocks", count_list_blocks, "paper",
        params=(_P("block_size", int, None, minimum=1, allow_none=True,
                   doc="lists per block; default ≈ √V (paper's choice)"),),
        cost=cost_list_blocks, memory_bytes=mem_list_blocks,
        bench_caps={"ingest": 2000},
        doc="block-pair-order traversal, b ≈ √V blocks (§2)",
    ))
    register(MethodSpec(
        "list-scan", count_list_scan, "paper",
        params=(_P("rows_per_batch", int, 64, minimum=1,
                   doc="primaries per batched bincount histogram"),),
        cost=cost_list_scan, memory_bytes=mem_list_scan,
        doc="term-order inverted+forward traversal (§2); best asymptotics, "
            "batched-histogram hot loop",
    ))
    register(MethodSpec(
        "multi-scan", count_multi_scan, "paper",
        params=(_P("accumulators", int, 100, minimum=1,
                   doc="primary keys claimed per forward pass (paper: 100)"),),
        cost=cost_multi_scan, memory_bytes=mem_multi_scan,
        bench_max_docs=300, bench_caps={"scaling": 400},
        doc="repeated forward scans, a primaries per pass (§2)",
    ))
    register(MethodSpec(
        "list-pairs-bitpacked", count_list_pairs_bitpacked, "tpu",
        params=(_P("block", int, 256, minimum=1), use_kernel),
        cost=_tpu_discount(cost_list_pairs), memory_bytes=mem_list_pairs,
        bench_max_docs=100,
        doc="LIST-PAIRS via blocked AND+popcount bitmaps (VPU)",
    ))
    register(MethodSpec(
        "list-blocks-gram", count_list_blocks_gram, "tpu",
        params=(_P("vocab_tile", int, 512, minimum=1),
                _P("doc_tile", int, 2048, minimum=1), use_kernel),
        cost=_tpu_discount(cost_list_blocks), memory_bytes=mem_list_blocks,
        doc="LIST-BLOCKS as tiled Gram matmul on 0/1 incidence (MXU)",
    ))
    register(MethodSpec(
        "list-scan-segment", count_list_scan_segment, "tpu",
        params=(_P("rows_per_batch", int, 64, minimum=1), use_kernel),
        cost=_tpu_discount(cost_list_scan), memory_bytes=mem_list_scan,
        bench_overrides={"use_kernel": False},
        bench_caps={"ingest": 500},  # segment_sum oracle is slow off-TPU
        doc="LIST-SCAN as batched segment histograms",
    ))
    register(MethodSpec(
        "multi-scan-matmul", count_multi_scan_matmul, "tpu",
        params=(_P("accumulators", int, 128, minimum=1),
                _P("doc_tile", int, 2048, minimum=1), use_kernel),
        cost=_tpu_discount(cost_multi_scan), memory_bytes=mem_multi_scan,
        bench_overrides={"use_kernel": False, "accumulators": 256},
        doc="MULTI-SCAN as skinny Gram matmuls per pass",
    ))
    register(MethodSpec(
        "freq-split", count_freq_split, "hybrid",
        params=(_P("head", int, 1024, minimum=0,
                   doc="dense-head vocabulary rank split point"),
                _P("doc_tile", int, 2048, minimum=1), use_kernel),
        cost=cost_freq_split, memory_bytes=mem_freq_split,
        needs_df_descending=True, needs_emit_col=True,
        bench_overrides={"head": 512, "use_kernel": False},
        doc="dense-head Gram × sparse-tail LIST-SCAN hybrid (beyond paper)",
    ))


_build_registry()
