"""Output plumbing for co-occurrence counting.

The paper's output format (§2 NAÏVE): "a primary key followed by multiple
tuples of secondary keys and counts" — used for the final output of all
methods. A ``PairSink`` receives rows in that exact shape; implementations
either materialize a dense matrix (tests, small vocab), stream aggregate
statistics (benchmarks at large vocab), or write the paper's binary format.
"""

from __future__ import annotations

import struct
from typing import Protocol

import numpy as np


class PairSink(Protocol):
    def emit_row(self, primary: int, secondaries: np.ndarray, counts: np.ndarray) -> None:
        """Emit all nonzero pairs (primary, s) with primary < s, counts >= 1."""
        ...


class DenseSink:
    """Accumulates into a dense strict-upper-triangular int64 matrix."""

    def __init__(self, vocab_size: int):
        self.mat = np.zeros((vocab_size, vocab_size), dtype=np.int64)

    def emit_row(self, primary, secondaries, counts):
        self.mat[primary, secondaries] += counts.astype(np.int64)

    def emit_col(self, secondary, primaries, counts):
        """Column-order emission (used by the FREQ-SPLIT tail path, which
        discovers pairs one *secondary* at a time)."""
        self.mat[primaries, secondary] += counts.astype(np.int64)


class StatsSink:
    """Aggregate statistics only — distinct pairs, total count mass, the most
    frequent pair (the paper's "to"–"the" observation), and output bytes under
    the paper's format (4B primary + 8B per (secondary, count) tuple)."""

    def __init__(self):
        self.distinct_pairs = 0
        self.total_count = 0
        self.max_count = -1
        self.max_pair = (-1, -1)
        self.output_bytes = 0
        self.rows = 0

    def emit_row(self, primary, secondaries, counts):
        n = len(secondaries)
        if n == 0:
            return
        self.rows += 1
        self.distinct_pairs += n
        self.total_count += int(counts.sum())
        k = int(np.argmax(counts))
        if counts[k] > self.max_count:
            self.max_count = int(counts[k])
            self.max_pair = (int(primary), int(secondaries[k]))
        self.output_bytes += 4 + 8 * n

    def emit_col(self, secondary, primaries, counts):
        n = len(primaries)
        if n == 0:
            return
        self.distinct_pairs += n
        self.total_count += int(counts.sum())
        k = int(np.argmax(counts))
        if counts[k] > self.max_count:
            self.max_count = int(counts[k])
            self.max_pair = (int(primaries[k]), int(secondary))
        self.output_bytes += 8 * n  # column entries join existing rows


class FileSink:
    """The paper's on-disk format: primary key (u32) + count n (u32) + n
    tuples of (secondary u32, count u32)."""

    def __init__(self, path: str):
        self.f = open(path, "wb")

    def emit_row(self, primary, secondaries, counts):
        n = len(secondaries)
        if n == 0:
            return
        self.f.write(struct.pack("<II", primary, n))
        buf = np.empty(2 * n, dtype=np.uint32)
        buf[0::2] = secondaries.astype(np.uint32)
        buf[1::2] = counts.astype(np.uint32)
        self.f.write(buf.tobytes())

    def close(self):
        self.f.close()

    def __enter__(self) -> "FileSink":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


def iter_pair_file(path: str):
    """Stream (primary, secondaries, counts) rows from a FileSink-format
    file without loading it whole (the store's run-merge reads spill files
    through this)."""
    with open(path, "rb") as f:
        while True:
            hdr = f.read(8)
            if not hdr:
                return
            primary, n = struct.unpack("<II", hdr)
            buf = np.frombuffer(f.read(8 * n), dtype=np.uint32)
            yield primary, buf[0::2].copy(), buf[1::2].copy()


def read_pair_file(path: str):
    """Inverse of FileSink, for round-trip tests."""
    return list(iter_pair_file(path))


def group_bounds(sorted_arr: np.ndarray) -> np.ndarray:
    """Boundaries of equal-value groups in a sorted array: ``[0, start_1,
    ..., start_{g-1}, n]`` — group i spans ``bounds[i]:bounds[i+1]``. The one
    grouping idiom behind key aggregation, row splitting, and the symmetric
    scatter (callers slice ``[:-1]`` when they only need starts)."""
    n = len(sorted_arr)
    if n == 0:
        return np.zeros(1, dtype=np.int64)
    return np.concatenate(
        [[0], np.nonzero(sorted_arr[1:] != sorted_arr[:-1])[0] + 1, [n]]
    )


def emit_dense_rows(
    mat: np.ndarray, sink: PairSink, row_lo: int = 0, col_lo: int = 0
) -> None:
    """Stream the nonzero strict-upper (global j > global i) entries of a
    dense count tile whose [0,0] element is global (row_lo, col_lo).

    One tile-level ``nonzero`` + per-row split — the emission hot loop of
    every dense-accumulating method runs O(nnz) work, not O(rows · cols).
    """
    rs, cs = np.nonzero(mat)
    keep = cs + col_lo > rs + row_lo  # strict upper triangle only
    rs, cs = rs[keep], cs[keep]
    if len(rs) == 0:
        return
    vals = mat[rs, cs]
    # np.nonzero is row-major: rs is sorted, so rows are contiguous segments
    bounds = group_bounds(rs)
    for s, e in zip(bounds[:-1], bounds[1:]):
        sink.emit_row(row_lo + int(rs[s]), cs[s:e] + col_lo, vals[s:e])
