"""LIST-PAIRS (paper §2): pair-order posting-list intersection.

Build the inverted index in a first pass, then consider every ordered term
pair (i < j) and compute |postings(i) ∩ postings(j)|. Each pair is touched
exactly once and needs a single scalar accumulator — but the approach is
quadratic in vocabulary and almost all intersections are empty (the paper's
stated disadvantage, visible in our Figure-1 benchmark).

The TPU adaptation of this traversal is the bit-packed AND+popcount kernel
(kernels/bitpair.py): 32 documents per uint32 word, intersection size =
Σ popcount(w_i & w_j) — see count_list_pairs_bitpacked.
"""

from __future__ import annotations

import numpy as np

from repro.core.types import PairSink, emit_dense_rows
from repro.data.corpus import Collection
from repro.data.index import build_inverted_index, incidence_bitpacked


def _intersect_size_sorted(a: np.ndarray, b: np.ndarray) -> int:
    """|a ∩ b| for sorted unique int arrays (galloping-free linear merge)."""
    return int(np.intersect1d(a, b, assume_unique=True).size)


def count_list_pairs(c: Collection, sink: PairSink) -> dict:
    inv = build_inverted_index(c)
    V = c.vocab_size
    df = inv.df()
    live = np.nonzero(df)[0]
    intersections = 0
    for ii, i in enumerate(live):
        pi = inv.postings(i)
        sec, cnt = [], []
        for j in live[ii + 1:]:
            intersections += 1
            n = _intersect_size_sorted(pi, inv.postings(j))
            if n:
                sec.append(j)
                cnt.append(n)
        if sec:
            sink.emit_row(int(i), np.asarray(sec), np.asarray(cnt))
    return {"intersections": intersections, "live_terms": int(len(live))}


def count_list_pairs_bitpacked(
    c: Collection, sink: PairSink, *, block: int = 256, use_kernel: bool = True
) -> dict:
    """TPU-adapted LIST-PAIRS: blocked bit-packed intersection counting.

    Processes vocab blocks (I, J) with I <= J; each block pair is one
    popcount-matmul over uint32 bitmaps (Pallas kernel on TPU; jnp oracle
    otherwise). Still pair-order traversal — every pair computed exactly
    once — but vectorized 32 docs/word and (block × block) pairs per call.
    """
    from repro.kernels import ops as kops

    V = c.vocab_size
    bits = incidence_bitpacked(c)  # (V, W) uint32
    nblk = (V + block - 1) // block
    block_pairs = 0
    for bi in range(nblk):
        ilo, ihi = bi * block, min((bi + 1) * block, V)
        rows_i = bits[ilo:ihi]
        for bj in range(bi, nblk):
            jlo, jhi = bj * block, min((bj + 1) * block, V)
            tile = np.asarray(
                kops.bitpair_popcount(rows_i, bits[jlo:jhi], use_kernel=use_kernel)
            ).astype(np.int64)
            block_pairs += 1
            _emit_tile(tile, ilo, jlo, sink)
    return {"block_pairs": block_pairs, "bitmap_bytes": int(bits.nbytes)}


def _emit_tile(tile: np.ndarray, row_lo: int, col_lo: int, sink: PairSink) -> None:
    """One tile-level nonzero + per-row split (was a per-row Python loop)."""
    emit_dense_rows(tile, sink, row_lo=row_lo, col_lo=col_lo)
