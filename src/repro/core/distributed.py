"""Distributed Gram accumulation: C = Σ_s B_sᵀ B_s over document shards.

Device layout (launch/mesh.py):
  * documents shard over ("pod", "data")  — rows of B,
  * vocabulary shards over "model"        — columns of B and of C.

Each device holds B_local of shape (D_local, V_local). To form its strip of
C it needs every other model-rank's column block as the right operand. Two
schedules are provided:

* ``gram_allgather`` — paper-faithful LIST-BLOCKS schedule: materialize the
  full right operand with one all-gather over "model", one big matmul, then
  reduce-scatter partials over the document axes. Simple, but the all-gather
  is a bandwidth burst that cannot overlap the matmul.

* ``gram_ring`` — beyond-paper schedule: rotate column blocks around the
  "model" axis with collective-permute, accumulating one (V_local × V_local)
  block-product per step. Communication of step k+1 overlaps the matmul of
  step k (the compiler can double-buffer the permute), peak memory drops from
  O(V) to O(V_local) per device, and total bytes moved are identical.
  This is the schedule hill-climbed in EXPERIMENTS.md §Perf.

Both return the device-local strip of the *global* Gram matrix: shape
(V_local, V) rows scattered over the document axes for the final write-out.
Exactness: f32 accumulation, exact for per-shard doc counts < 2²⁴.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# pre-0.5 releases keep shard_map under jax.experimental and have no pvary
# (there, unmapped constants are already treated as varying)
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def _local_gram_allgather(B_local: jax.Array, *, model_axis: str, doc_axes) -> jax.Array:
    B_all = jax.lax.all_gather(B_local, model_axis, axis=1, tiled=True)  # (D_loc, V)
    partial = jnp.einsum(
        "di,dj->ij", B_local, B_all, preferred_element_type=jnp.float32
    )  # (V_loc, V)
    return jax.lax.psum_scatter(partial, doc_axes, scatter_dimension=0, tiled=True)


def _local_gram_ring(
    B_local: jax.Array, *, model_axis: str, doc_axes, n: int
) -> jax.Array:
    my = jax.lax.axis_index(model_axis)
    v_loc = B_local.shape[1]

    # STATIC python loop (n is a trace-time constant): every ring step
    # appears in the HLO — cost analysis counts all n block-matmuls (a
    # fori_loop body would be counted once), and the compiler can pipeline
    # step k's permute against step k+1's matmul
    acc = jnp.zeros((v_loc, v_loc * n), dtype=jnp.float32)
    acc = _pvary(acc, tuple(doc_axes) + (model_axis,))
    buf = B_local
    for k in range(n):
        src = (my + k) % n  # global block id currently held in buf
        part = jnp.einsum(
            "di,dj->ij", B_local, buf, preferred_element_type=jnp.float32
        )
        acc = jax.lax.dynamic_update_slice(acc, part, (0, src * v_loc))
        if k + 1 < n:
            # pass buf one hop left so rank r receives block (r + k + 1) next
            buf = jax.lax.ppermute(
                buf, model_axis, perm=[((i + 1) % n, i) for i in range(n)]
            )
    return jax.lax.psum_scatter(acc, doc_axes, scatter_dimension=0, tiled=True)


def make_distributed_gram(
    mesh: Mesh,
    *,
    schedule: str = "ring",
    model_axis: str = "model",
):
    """Build a jit'd distributed Gram op over ``mesh``.

    Input: global incidence matrix B (D, V) sharded (doc_axes, model).
    Output: global C (V, V) with rows sharded over doc_axes and columns
    over nothing (each row strip is fully accumulated).
    """
    doc_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    fn = {"allgather": _local_gram_allgather, "ring": _local_gram_ring}[schedule]
    kwargs = dict(model_axis=model_axis, doc_axes=doc_axes)
    if schedule == "ring":
        # ring length must be a trace-time constant (static python loop)
        kwargs["n"] = dict(mesh.shape)[model_axis]
    local = functools.partial(fn, **kwargs)

    shard = _shard_map(
        local,
        mesh=mesh,
        in_specs=(P(doc_axes, model_axis),),
        out_specs=P((model_axis,) + doc_axes, None),
    )
    return jax.jit(shard)


def gram_reference(B: jnp.ndarray) -> jnp.ndarray:
    """Single-device oracle for the distributed schedules."""
    return jnp.einsum("di,dj->ij", B, B, preferred_element_type=jnp.float32)
