"""LIST-SCAN (paper §2): term-order traversal over inverted + forward index.

For each term i (the primary key), scan its posting list; for every document
referenced, load the forward document and increment an accumulator for every
secondary key j > i it contains. When the posting list is exhausted the row is
complete and written out. Each document is inspected at most once per
contained term → O(Σ_d len_d²) total, linear in collection size for bounded
document lengths (the paper's best asymptotic method, 1.69M docs in ~20h).

Observation used by the TPU path: the accumulator row is a *histogram* —
C[i, :] = Σ_{d ∈ postings(i)} B[d, :] — i.e. a bincount over the concatenated
forward documents of postings(i), masked to j > i. That maps directly onto
``jax.ops.segment_sum`` / one-hot scatter (kernels/segment_cooc.py).
"""

from __future__ import annotations

import numpy as np

from repro.core.types import PairSink
from repro.data.corpus import Collection
from repro.data.index import build_inverted_index


def count_list_scan(c: Collection, sink: PairSink) -> dict:
    inv = build_inverted_index(c)
    V = c.vocab_size
    docs_scanned = 0
    acc = np.zeros(V, dtype=np.int64)  # reused row accumulator
    for i in range(V):
        post = inv.postings(i)
        if len(post) == 0:
            continue
        acc[:] = 0
        for d in post:
            ts = c.doc(int(d))
            # per-doc terms are sorted: secondaries are the suffix after i
            sec = ts[np.searchsorted(ts, i) + 1:]
            acc[sec] += 1
            docs_scanned += 1
        nz = np.nonzero(acc)[0]
        if len(nz):
            sink.emit_row(i, nz, acc[nz])
    return {"docs_scanned": docs_scanned}


def count_list_scan_segment(
    c: Collection, sink: PairSink, *, rows_per_batch: int = 64, use_kernel: bool = True
) -> dict:
    """TPU-adapted LIST-SCAN: batched histogram accumulation.

    Gathers the forward documents for a batch of primary terms, flattens them
    into (ids, segment) streams and performs one batched histogram per batch
    via kernels/segment_cooc.py (Pallas onehot-matmul histogram on TPU;
    segment_sum oracle with ``use_kernel=False``). Work is proportional to
    actual postings (no empty tiles), which is why this path wins on the
    hyper-sparse vocabulary tail — see core/hybrid.py.
    """
    from repro.kernels import ops as kops

    inv = build_inverted_index(c)
    V = c.vocab_size
    batches = 0
    for lo in range(0, V, rows_per_batch):
        hi = min(lo + rows_per_batch, V)
        ids_chunks, seg_chunks = [], []
        for slot, i in enumerate(range(lo, hi)):
            post = inv.postings(i)
            if len(post) == 0:
                continue
            ts = np.concatenate([c.doc(int(d)) for d in post])
            ts = ts[ts > i]  # strict-upper secondaries only
            if len(ts):
                ids_chunks.append(ts.astype(np.int32))
                seg_chunks.append(np.full(len(ts), slot, dtype=np.int32))
        if not ids_chunks:
            continue
        ids = np.concatenate(ids_chunks)
        seg = np.concatenate(seg_chunks)
        counts = np.asarray(
            kops.segment_hist(
                ids, seg, num_rows=hi - lo, vocab=V, use_kernel=use_kernel
            )
        )
        batches += 1
        for slot in range(hi - lo):
            nz = np.nonzero(counts[slot])[0]
            if len(nz):
                sink.emit_row(lo + slot, nz, counts[slot][nz].astype(np.int64))
    return {"row_batches": batches}
