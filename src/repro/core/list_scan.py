"""LIST-SCAN (paper §2): term-order traversal over inverted + forward index.

For each term i (the primary key), scan its posting list; for every document
referenced, load the forward document and increment an accumulator for every
secondary key j > i it contains. When the posting list is exhausted the row is
complete and written out. Each document is inspected at most once per
contained term → O(Σ_d len_d²) total, linear in collection size for bounded
document lengths (the paper's best asymptotic method, 1.69M docs in ~20h).

Observation used by the TPU path: the accumulator row is a *histogram* —
C[i, :] = Σ_{d ∈ postings(i)} B[d, :] — i.e. a bincount over the concatenated
forward documents of postings(i), masked to j > i. That maps directly onto
``jax.ops.segment_sum`` / one-hot scatter (kernels/segment_cooc.py).

The CPU hot path uses the same observation: primaries are processed in
batches, their forward documents gathered into one flat token stream with a
fancy-index (no per-document Python loop), and the whole batch is counted by
a single ``np.bincount`` over packed (slot, token) keys. The pre-vectorization
per-document loop survives as ``count_list_scan_loop`` — the ingest
benchmark's baseline and the byte-identity oracle for the batched path.
"""

from __future__ import annotations

import numpy as np

from repro.core.types import PairSink, emit_dense_rows, group_bounds
from repro.data.corpus import Collection
from repro.data.index import InvertedIndex, build_inverted_index


def _batch_tokens(
    c: Collection, inv: InvertedIndex, lo: int, hi: int
) -> tuple[np.ndarray, np.ndarray, int]:
    """Flat strict-upper token stream for primaries [lo, hi).

    Returns ``(tokens, owners, docs_gathered)``: for every posting of every
    primary in the batch, the suffix of the forward document *after* the
    primary itself (per-doc terms are sorted unique, so the suffix is exactly
    the secondaries j > i). ``owners[k]`` is the primary that pulled
    ``tokens[k]`` in. One fancy-index gather — no per-document Python loop,
    no post-hoc masking; memory is O(batch pair occurrences).
    """
    t0, t1 = inv.term_ptr[lo], inv.term_ptr[hi]
    docs = inv.docs[t0:t1].astype(np.int64)
    owners = np.repeat(
        np.arange(lo, hi, dtype=np.int32), np.diff(inv.term_ptr[lo:hi + 1])
    )
    starts = inv.positions[t0:t1] + 1  # one past the primary's own slot
    lens = c.doc_ptr[docs + 1] - starts
    offs = np.zeros(len(docs) + 1, dtype=np.int64)
    np.cumsum(lens, out=offs[1:])
    # flat[k] walks each doc's suffix slice back-to-back; int32 throughout —
    # the gather feeds ~len² tokens per doc, so halving element width is a
    # straight halving of the hot loop's memory traffic
    # int32 needs every flat index AND the batch token count in range: the
    # arange runs over offs[-1] (gathered tokens this batch), the offsets
    # over c.terms positions — both must fit
    idx_dtype = (
        np.int32
        if int(c.doc_ptr[-1]) < 2**31 and int(offs[-1]) < 2**31
        else np.int64
    )
    flat = np.arange(offs[-1], dtype=idx_dtype) + np.repeat(
        (starts - offs[:-1]).astype(idx_dtype), lens
    )
    tokens = c.terms[flat]  # stays int32
    return tokens, np.repeat(owners, lens), len(docs)


def count_list_scan(
    c: Collection, sink: PairSink, *, rows_per_batch: int = 64
) -> dict:
    """Vectorized LIST-SCAN: one batched histogram per primary batch.

    Each batch's flat (primary, token) stream is aggregated in one shot —
    ``np.bincount`` over packed keys when the batch grid is dense enough to
    pay for an O(rows · V) histogram, otherwise a single sort over the packed
    keys (work proportional to the batch's pair occurrences, not to V — the
    winning regime on the hyper-sparse vocabulary tail).

    Byte-identical to ``count_list_scan_loop`` (asserted in tests and by the
    ingest benchmark); the traversal order and emitted rows are exactly the
    paper's, only the per-document accumulation is batched.
    """
    inv = build_inverted_index(c)
    V = c.vocab_size
    docs_scanned = 0
    # sinks exposing the batch fast path (SpillSink) take each batch's
    # aggregated packed keys whole — no per-row splitting at all
    emit_keys = getattr(sink, "emit_keys", None)
    for lo in range(0, V, rows_per_batch):
        hi = min(lo + rows_per_batch, V)
        tokens, owners, n_docs = _batch_tokens(c, inv, lo, hi)
        docs_scanned += n_docs
        if len(tokens) == 0:
            continue
        if (hi - lo) * V < 2**31:
            # batch-relative keys fit int32: half the sort/bincount traffic
            keys = (owners - np.int32(lo)) * np.int32(V) + tokens
        else:
            keys = (owners.astype(np.int64) - lo) * V + tokens
        if len(keys) * 4 >= (hi - lo) * V:
            # dense batch: one bincount histogram over the (rows, V) grid
            counts = np.bincount(keys, minlength=(hi - lo) * V).astype(
                np.int64, copy=False
            )
            if emit_keys is not None:
                nz = np.nonzero(counts)[0]
                emit_keys(nz + np.int64(lo) * V, counts[nz])
            else:
                emit_dense_rows(counts.reshape(hi - lo, V), sink, row_lo=lo)
        else:
            # sparse batch: sort-aggregate the packed keys, skip the grid
            keys.sort()
            bounds = group_bounds(keys)
            uniq = keys[bounds[:-1]]
            counts = np.diff(bounds)
            if emit_keys is not None:
                emit_keys(uniq.astype(np.int64) + np.int64(lo) * V, counts)
                continue
            rows = uniq // V
            rb = group_bounds(rows)
            for s, e in zip(rb[:-1], rb[1:]):
                sink.emit_row(lo + int(rows[s]), uniq[s:e] % V, counts[s:e])
    return {"docs_scanned": docs_scanned}


def count_list_scan_loop(c: Collection, sink: PairSink) -> dict:
    """Pre-vectorization reference: per-document ``acc[sec] += 1`` loop.

    Kept (unregistered) as the ingest benchmark's docs/hour baseline and as
    the byte-identity oracle for the batched histogram path above.
    """
    inv = build_inverted_index(c)
    V = c.vocab_size
    docs_scanned = 0
    acc = np.zeros(V, dtype=np.int64)  # reused row accumulator
    for i in range(V):
        post = inv.postings(i)
        if len(post) == 0:
            continue
        acc[:] = 0
        for d in post:
            ts = c.doc(int(d))
            # per-doc terms are sorted: secondaries are the suffix after i
            sec = ts[np.searchsorted(ts, i) + 1:]
            acc[sec] += 1
            docs_scanned += 1
        nz = np.nonzero(acc)[0]
        if len(nz):
            sink.emit_row(i, nz, acc[nz])
    return {"docs_scanned": docs_scanned}


def count_list_scan_segment(
    c: Collection, sink: PairSink, *, rows_per_batch: int = 64, use_kernel: bool = True
) -> dict:
    """TPU-adapted LIST-SCAN: batched histogram accumulation.

    Gathers the forward documents for a batch of primary terms (same flat
    ``_batch_tokens`` gather as the CPU path), flattens them into
    (ids, segment) streams and performs one batched histogram per batch
    via kernels/segment_cooc.py (Pallas onehot-matmul histogram on TPU;
    segment_sum oracle with ``use_kernel=False``). Work is proportional to
    actual postings (no empty tiles), which is why this path wins on the
    hyper-sparse vocabulary tail — see core/hybrid.py.
    """
    from repro.kernels import ops as kops

    inv = build_inverted_index(c)
    V = c.vocab_size
    batches = 0
    for lo in range(0, V, rows_per_batch):
        hi = min(lo + rows_per_batch, V)
        tokens, owners, _ = _batch_tokens(c, inv, lo, hi)
        if len(tokens) == 0:
            continue
        ids = tokens
        seg = (owners - np.int32(lo)).astype(np.int32)
        counts = np.asarray(
            kops.segment_hist(
                ids, seg, num_rows=hi - lo, vocab=V, use_kernel=use_kernel
            )
        )
        batches += 1
        emit_dense_rows(counts.astype(np.int64), sink, row_lo=lo)
    return {"row_batches": batches}
