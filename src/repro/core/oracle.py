"""Brute-force oracle: the definition, with no cleverness.

Used by every test as ground truth. O(Σ len_d²) time, dense O(V²) memory —
fine for test-sized corpora only.
"""

from __future__ import annotations

import numpy as np

from repro.data.corpus import Collection


def brute_force_counts(c: Collection) -> np.ndarray:
    """Dense strict-upper-triangular int64 (V, V) co-occurrence counts."""
    V = c.vocab_size
    out = np.zeros((V, V), dtype=np.int64)
    for d in range(c.num_docs):
        ts = c.doc(d)
        if len(ts) < 2:
            continue
        # ts is sorted ascending and unique: all (i<j) pairs are upper pairs
        out[np.repeat(ts, len(ts)), np.tile(ts, len(ts))] += 1
    # the loop added the full outer product incl. diagonal; keep strict upper
    return np.triu(out, k=1)
