"""Core: the paper's contribution — exact term co-occurrence counting.

Five paper-faithful methods (naive, list-pairs, list-blocks, list-scan,
multi-scan), their TPU adaptations (MXU Gram / bit-packed popcount /
segment-sum), the beyond-paper FREQ-SPLIT hybrid, the distributed
(multi-pod) Gram accumulation — and the typed counting-plan API
(``specs``/``plan``): MethodSpec registry with §3 cost models, the Planner
(``method="auto"``), and the shared shard/merge PlanExecutor.

Entry points (see docs/architecture.md and docs/methods.md)::

    # planned, exact, resumable — the path every driver uses
    res = execute_job(CountJob(collection=c, output="store",
                               out_path="/data/store", method="auto"))

    # seed-API shims (validated kwargs, byte-identical output)
    count("list-scan", c, sink)
    mat = dense_counts("naive", c)            # strict-upper oracle
    store, seg = count_to_store("auto", c, "/data/store")
"""

from repro.core.cooc import METHODS, count, count_to_store, dense_counts
from repro.core.oracle import brute_force_counts
from repro.core.plan import (
    CountJob,
    ExecutionResult,
    Plan,
    PlanExecutor,
    Planner,
    execute_job,
)
from repro.core.specs import REGISTRY, MethodSpec, Param, get_spec, method_names
from repro.core.types import DenseSink, FileSink, StatsSink, read_pair_file

__all__ = [
    "METHODS",
    "REGISTRY",
    "MethodSpec",
    "Param",
    "get_spec",
    "method_names",
    "CountJob",
    "Plan",
    "Planner",
    "PlanExecutor",
    "ExecutionResult",
    "execute_job",
    "count",
    "count_to_store",
    "dense_counts",
    "brute_force_counts",
    "DenseSink",
    "FileSink",
    "StatsSink",
    "read_pair_file",
]
