"""Core: the paper's contribution — exact term co-occurrence counting.

Five paper-faithful methods (naive, list-pairs, list-blocks, list-scan,
multi-scan), their TPU adaptations (MXU Gram / bit-packed popcount /
segment-sum), the beyond-paper FREQ-SPLIT hybrid, and the distributed
(multi-pod) Gram accumulation.
"""

from repro.core.cooc import METHODS, count, dense_counts
from repro.core.oracle import brute_force_counts
from repro.core.types import DenseSink, FileSink, StatsSink, read_pair_file

__all__ = [
    "METHODS",
    "count",
    "dense_counts",
    "brute_force_counts",
    "DenseSink",
    "FileSink",
    "StatsSink",
    "read_pair_file",
]
