"""Counting plans: typed jobs → cost-model planning → one execution path.

This is the single entry point every driver, benchmark, and the store
builder share (ISSUE 2 tentpole):

    job  = CountJob(collection=c, output="pairs-file", out_path=...,
                    method="auto", num_shards=16)
    plan = Planner().plan(job)        # cost models pick the method + sinks
    res  = plan.execute(out_dir=...)  # sharded, checkpointed, exact

``Planner`` selects the counting method with the §3 cost models over
:class:`CollectionStats` (``method="auto"``), and selects the merge policy:

* **dense**  — vocab ≤ ``dense_vocab_cap``: per-shard DenseSink, additive
  dense accumulator (exact);
* **spill**  — larger vocabularies: per-shard SpillSink runs on disk,
  k-way-merged exactly at finalization within O(memory budget) — replacing
  the old lossy "StatsSink upper bound across shards" fallback of
  ``launch/cooc_run``;
* **stats**  — only when the job explicitly opts out of exactness
  (``exact=False`` with ``output="stats"``): per-shard aggregate statistics,
  ``distinct_pairs`` becomes an upper bound.

``PlanExecutor`` owns the shard/merge orchestration that used to be
hard-coded in ``launch/cooc_run``: WorkTracker leases with straggler
re-enqueue, idempotent completion, checkpoint/resume every ``ckpt_every``
shards (for the spill policy the on-disk run files double as checkpoint
state), and the final merge into the requested output target
(``dense`` | ``stats`` | ``pairs-file`` | ``store``).
"""

from __future__ import annotations

import dataclasses
import glob
import os
import shutil
import tempfile
import time
from typing import Mapping

import numpy as np

from repro import obs
from repro.core.specs import REGISTRY, MethodSpec, get_spec
from repro.core.types import DenseSink, FileSink, StatsSink
from repro.data.corpus import Collection, CollectionStats

OUTPUTS = ("dense", "stats", "pairs-file", "store")
SINK_POLICIES = ("dense", "spill", "stats")


def _default_use_kernel() -> bool:
    """Pallas kernels only by default on real accelerators."""
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax always present in this repo
        return False


# ---------------------------------------------------------------------------
# CountJob
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CountJob:
    """A validated counting request (what to count, how exact, where to).

    Validation happens at construction: unknown outputs, missing paths,
    ill-typed method kwargs, and df-order prerequisites all raise here, not
    halfway through a multi-hour run.

    Example::

        job = CountJob(collection=c, output="store", out_path="/data/store",
                       method="auto", num_shards=8)
        res = Planner().plan(job).execute()
    """

    collection: Collection
    output: str = "stats"                  # dense | stats | pairs-file | store
    method: str = "auto"                   # registry name or "auto"
    out_path: str | None = None            # pairs-file path / store directory
    exact: bool = True                     # False permits the stats fast path
    memory_budget_pairs: int = 4 << 20     # spill budget (buffered pairs)
    num_shards: int = 1
    dense_vocab_cap: int = 4096            # dense-merge threshold
    df_descending: bool = False            # term IDs are df-descending
    use_kernel: bool | None = None         # None → auto (TPU backend only)
    method_kwargs: Mapping = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not isinstance(self.collection, Collection):
            raise ValueError(
                f"collection must be a Collection, got {type(self.collection).__name__}"
            )
        if self.output not in OUTPUTS:
            raise ValueError(f"unknown output {self.output!r}; have {OUTPUTS}")
        if self.output in ("pairs-file", "store") and not self.out_path:
            raise ValueError(f"output={self.output!r} requires out_path")
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if self.memory_budget_pairs < 1:
            raise ValueError("memory_budget_pairs must be >= 1")
        if self.dense_vocab_cap < 1:
            raise ValueError("dense_vocab_cap must be >= 1")
        if self.method == "auto":
            if self.method_kwargs:
                raise ValueError(
                    "method_kwargs requires an explicit method "
                    "(auto-selected methods run with planner-resolved params)"
                )
        else:
            try:
                spec = get_spec(self.method)
            except KeyError as e:
                raise ValueError(str(e)) from None
            try:
                spec.validate_kwargs(self.method_kwargs)
            except (TypeError, ValueError) as e:
                raise ValueError(f"invalid method_kwargs: {e}") from None
            if spec.needs_df_descending and not self.df_descending:
                raise ValueError(
                    f"method {self.method!r} requires df-descending term IDs "
                    "(remap with data.preprocess.remap_df_descending and set "
                    "df_descending=True)"
                )


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    """An executable counting plan (what the Planner decided, and why).

    Carries full provenance: the chosen method and kwargs, the sink/merge
    policy, cost estimates, and the complete candidate ranking — so a
    driver can log *why* this method ran (``describe()``) and a benchmark
    can compare the model against measured time.

    Example::

        plan = Planner().plan(job)
        plan.method, plan.sink_policy       # ('list-blocks', 'spill')
        plan.describe()["ranking"]          # best-first (method, cost) pairs
        res = plan.execute(out_dir="/tmp/run", ckpt_every=4)
    """

    job: CountJob
    method: str
    method_kwargs: Mapping
    sink_policy: str                       # dense | spill | stats
    exact: bool
    estimated_cost: float                  # cost-model work units
    estimated_method_bytes: float          # method working-set estimate
    collection_stats: CollectionStats
    ranking: tuple = ()                    # ((method, cost), ...) best-first

    @property
    def spec(self) -> MethodSpec:
        return REGISTRY[self.method]

    def describe(self) -> dict:
        """JSON-serializable provenance, embedded in driver results."""
        return {
            "method": self.method,
            "method_kwargs": {k: v for k, v in self.method_kwargs.items()},
            "sink_policy": self.sink_policy,
            "exact": self.exact,
            "estimated_cost": round(float(self.estimated_cost), 1),
            "estimated_method_mb": round(self.estimated_method_bytes / 2**20, 2),
            "ranking": [(m, round(float(c), 1)) for m, c in self.ranking],
        }

    def execute(self, **kwargs) -> "ExecutionResult":
        return PlanExecutor().execute(self, **kwargs)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


class Planner:
    """Turns a CountJob into a Plan using the MethodSpec cost models.

    ``method="auto"`` ranks every eligible paper/hybrid method by its §3
    cost model over the collection's statistics (docs/methods.md walks the
    regimes); an explicit method skips ranking but still gets validated
    kwargs and a merge policy.

    Example::

        plan = Planner().plan(CountJob(collection=c, output="stats"))
        plan.ranking[0][0] == plan.method   # best-ranked method won
    """

    def __init__(self, registry: Mapping[str, MethodSpec] = REGISTRY):
        self.registry = registry

    def candidates(self, job: CountJob) -> list[MethodSpec]:
        if job.method != "auto":
            return [self.registry[job.method]]
        out = []
        for spec in self.registry.values():
            if spec.kind == "tpu":
                # equal-traversal accelerator adaptations: explicit opt-in
                continue
            if spec.needs_df_descending and not job.df_descending:
                continue
            out.append(spec)
        return out

    def resolve_kwargs(
        self, spec: MethodSpec, job: CountJob, stats: CollectionStats
    ) -> dict:
        """Spec defaults + job overrides + planner-tuned knobs."""
        kw = spec.resolve_kwargs(job.method_kwargs if job.method != "auto" else None)
        if "head" in kw and job.method == "auto":
            kw["head"] = min(kw["head"], stats.vocab_size)
        if "use_kernel" in kw and "use_kernel" not in job.method_kwargs:
            kw["use_kernel"] = (
                job.use_kernel if job.use_kernel is not None else _default_use_kernel()
            )
        return kw

    def rank(
        self, job: CountJob, stats: CollectionStats | None = None
    ) -> list[tuple[float, str, dict]]:
        """All candidate methods as (cost, name, resolved_kwargs), best first."""
        stats = stats or CollectionStats.from_collection(job.collection)
        ranked = []
        for spec in self.candidates(job):
            kw = self.resolve_kwargs(spec, job, stats)
            ranked.append((float(spec.cost(stats, kw)), spec.name, kw))
        ranked.sort(key=lambda t: (t[0], t[1]))
        return ranked

    def sink_policy(self, job: CountJob) -> str:
        if job.output == "dense":
            return "dense"
        V = job.collection.vocab_size
        # dense merge only if the V×V int64 accumulator fits the declared
        # memory budget (~16 bytes per buffered spill pair)
        if V <= job.dense_vocab_cap and 8 * V * V <= 16 * job.memory_budget_pairs:
            return "dense"
        if job.output == "stats" and not job.exact:
            return "stats"
        return "spill"

    def plan(self, job: CountJob) -> Plan:
        stats = CollectionStats.from_collection(job.collection)
        ranked = self.rank(job, stats)
        cost, name, kwargs = ranked[0]
        policy = self.sink_policy(job)
        spec = self.registry[name]
        return Plan(
            job=job,
            method=name,
            method_kwargs=kwargs,
            sink_policy=policy,
            exact=policy != "stats",
            estimated_cost=cost,
            estimated_method_bytes=float(spec.memory_bytes(stats, kwargs)),
            collection_stats=stats,
            ranking=tuple((n, c) for c, n, _ in ranked),
        )


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExecutionResult:
    """What a plan produced. ``summary`` is JSON-serializable; the heavier
    artifacts ride alongside depending on the job's output target.

    Example::

        res = plan.execute()
        res.summary["exact"], res.summary["distinct_pairs"]
        res.store     # output="store": an open repro.store.Store
        res.counts    # output="dense": strict-upper int64 matrix
    """

    summary: dict
    counts: np.ndarray | None = None       # output="dense" (strict upper)
    pairs_path: str | None = None          # output="pairs-file"
    store: object | None = None            # output="store" (repro.store.Store)
    segment: object | None = None          # output="store" (CSRSegment)


class PlanExecutor:
    """Shard/merge orchestration shared by every driver.

    Work units are document shards behind a WorkTracker (leases, straggler
    re-enqueue, idempotent completion). The merge strategy follows the plan's
    sink policy; checkpoint/resume works for all of them — under the spill
    policy, completed shards' sorted run files in ``out_dir/spill/`` *are*
    the bulk checkpoint state, so only tracker + aggregate dicts go through
    the checkpointer.

    Example::

        res = PlanExecutor(verbose=True).execute(
            plan, out_dir="/tmp/run", ckpt_every=4)
        # later, after a crash:
        res = PlanExecutor().execute(plan, out_dir="/tmp/run", resume=True)
    """

    def __init__(self, worker: str = "worker0", verbose: bool = False):
        self.worker = worker
        self.verbose = verbose

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg)

    # ------------------------------------------------------------------
    def execute(
        self,
        plan: Plan,
        *,
        out_dir: str | None = None,
        ckpt_every: int = 0,
        resume: bool = False,
    ) -> ExecutionResult:
        # warm the lazy imports before the root span opens: first-use import
        # cost (checkpoint machinery, sharding, sinks) is process setup, not
        # ingest stage time — with it inside the span, a fresh process's
        # stage spans could not tile the root span's wall time
        from repro import checkpoint  # noqa: F401
        from repro.data import preprocess  # noqa: F401
        from repro.runtime import fault  # noqa: F401
        from repro.store import builder  # noqa: F401

        # the root ingest span: every stage span (count/spill/bucket_merge/
        # segment_write/refresh — see docs/observability.md) nests under it,
        # so a trace shows where one run's wall time went
        with obs.get_registry().span(
            "ingest/execute",
            method=plan.method,
            sink=plan.sink_policy,
            output=plan.job.output,
            shards=plan.job.num_shards,
            docs=plan.job.collection.num_docs,
            resume=resume,
        ):
            return self._execute(
                plan, out_dir=out_dir, ckpt_every=ckpt_every, resume=resume
            )

    def _execute(
        self,
        plan: Plan,
        *,
        out_dir: str | None,
        ckpt_every: int,
        resume: bool,
    ) -> ExecutionResult:
        from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
        from repro.data.preprocess import shard_documents
        from repro.runtime.fault import WorkTracker
        from repro.store.builder import SpillSink

        job = plan.job
        c = job.collection
        V = c.vocab_size
        own_workdir = out_dir is None
        workdir = out_dir or tempfile.mkdtemp(prefix="cooc_plan_")
        os.makedirs(workdir, exist_ok=True)
        spill_root = os.path.join(workdir, "spill")
        ckpt_dir = os.path.join(workdir, "ckpt")
        t0 = time.time()

        dense = plan.sink_policy == "dense"
        spill = plan.sink_policy == "spill"
        shards = shard_documents(c, job.num_shards)
        tracker = WorkTracker([(s,) for s in range(job.num_shards)])
        acc = np.zeros((V, V), dtype=np.int64) if dense else None
        agg = {"distinct_pairs": 0, "total_count": 0, "output_bytes": 0}

        step0 = latest_step(ckpt_dir) if resume else None
        if step0 is not None:
            like = {"acc": acc} if dense else {"acc": np.zeros(1)}
            restored, extra = restore_checkpoint(ckpt_dir, step0, like)
            if dense:
                acc = np.array(restored["acc"])  # writable copy
            agg = extra["agg"]
            tracker = WorkTracker.from_state(extra["tracker"])
            self._log(f"[resume] from step {step0}: {len(tracker.done)} shards done")
        if spill:
            # Only completed shards of THIS run may contribute run files: a
            # fresh run wipes the spill root; a resumed run prunes directories
            # that don't correspond to a completed shard (e.g. left by an
            # earlier run with different sharding in the same out_dir).
            if step0 is None:
                shutil.rmtree(spill_root, ignore_errors=True)
            else:
                done_ids = {u[0] for u in tracker.done}
                for d in glob.glob(os.path.join(spill_root, "shard_*")):
                    idx = int(os.path.basename(d).split("_")[1])
                    if idx not in done_ids or idx >= job.num_shards:
                        shutil.rmtree(d, ignore_errors=True)

        reg = obs.get_registry()
        done_since_ckpt = 0
        while not tracker.finished:
            unit = tracker.claim(self.worker, time.monotonic())
            if unit is None:
                tracker.expire(time.monotonic())
                continue
            (s,) = unit
            # per-shard count span: covers sink setup (the spill buffers are
            # a real allocation), produce, AND the completion flush, with
            # nested ingest/spill spans (mid-count and flush-time) — its
            # inclusive time is the shard's whole cost before merging
            with reg.span(
                "ingest/count", shard=s, method=plan.method,
                docs=shards[s].num_docs,
            ):
                if dense:
                    sink = DenseSink(V)
                elif spill:
                    shard_dir = os.path.join(spill_root, f"shard_{s:05d}")
                    if os.path.isdir(shard_dir):
                        shutil.rmtree(shard_dir)  # partials from a dead lease
                    sink = SpillSink(
                        V,
                        memory_budget_pairs=job.memory_budget_pairs,
                        spill_dir=shard_dir,
                    )
                else:
                    sink = StatsSink()
                plan.spec.fn(shards[s], sink, **plan.method_kwargs)
                if tracker.complete(unit, self.worker):
                    if dense:
                        acc += sink.mat
                    elif spill:
                        sink.flush()  # run files persist: the checkpoint
                    else:
                        agg["distinct_pairs"] += sink.distinct_pairs  # upper
                        agg["total_count"] += sink.total_count
                        agg["output_bytes"] += sink.output_bytes
                    done_since_ckpt += 1
            reg.counter("ingest.docs_counted").inc(shards[s].num_docs)
            reg.counter("ingest.shards_done").inc()
            if ckpt_every and done_since_ckpt >= ckpt_every:
                save_checkpoint(
                    ckpt_dir,
                    len(tracker.done),
                    {"acc": acc if dense else np.zeros(1)},
                    extra={"agg": agg, "tracker": tracker.state()},
                )
                done_since_ckpt = 0
                self._log(f"[ckpt] {len(tracker.done)}/{job.num_shards} shards")

        elapsed = time.time() - t0
        summary = {
            "num_docs": c.num_docs,
            "vocab_size": V,
            "method": plan.method,
            "output": job.output,
            "num_shards": job.num_shards,
            "exact": plan.exact,
            "elapsed_s": round(elapsed, 2),
            "docs_per_hour": round(c.num_docs / max(elapsed, 1e-9) * 3600),
            "plan": plan.describe(),
        }
        result = ExecutionResult(summary=summary)

        if dense:
            self._finalize_dense(plan, np.triu(acc, 1), workdir, result)
        elif spill:
            self._finalize_spill(plan, spill_root, result)
        else:
            summary["total_count"] = agg["total_count"]  # additive → exact
            summary["distinct_pairs_upper_bound"] = agg["distinct_pairs"]
            summary["output_bytes_upper_bound"] = agg["output_bytes"]

        if own_workdir:
            shutil.rmtree(workdir, ignore_errors=True)
        return result

    # ------------------------------------------------------------------
    def _finalize_dense(
        self, plan: Plan, upper: np.ndarray, workdir: str, result: ExecutionResult
    ) -> None:
        from repro.core.stats import top_k_pairs

        job = plan.job
        summary = result.summary
        summary["distinct_pairs"] = int((upper > 0).sum())
        summary["total_count"] = int(upper.sum())
        summary["top_pairs"] = top_k_pairs(upper, 5)
        if job.output == "dense" or job.output == "stats":
            result.counts = upper
        if job.output == "pairs-file":
            with obs.get_registry().span("ingest/pairs_write"), FileSink(
                job.out_path
            ) as sink:
                for primary, secs, cnts in _dense_rows(upper):
                    sink.emit_row(primary, secs, cnts)
            result.pairs_path = job.out_path
        elif job.output == "store":
            _write_store(plan, _dense_rows(upper), result)

    def _finalize_spill(
        self, plan: Plan, spill_root: str, result: ExecutionResult
    ) -> None:
        from repro.store.builder import (
            _iter_run,
            discover_bucket_runs,
            merge_bucket_runs,
            merge_row_streams,
        )

        job = plan.job
        # bucket runs (run_<spill>_b<bucket>.bin) cover disjoint ascending
        # primary ranges: merge bucket by bucket — in memory when the bucket
        # fits the merge cap, via a heap spanning only that bucket's runs
        # across shards otherwise — never a global k-way over every run file
        by_bucket, legacy = discover_bucket_runs(spill_root)
        if legacy:
            # unbucketed runs span the whole primary range: only a global
            # k-way merge is order-correct for them
            merged = merge_row_streams([_iter_run(p) for p in by_bucket[-1]])
        else:
            merged = merge_bucket_runs(
                by_bucket, plan.job.collection.vocab_size,
                cap_pairs=4 * job.memory_budget_pairs,
            )
        _emit_merged_rows(plan, merged, result)
        # run files are deliberately kept in user-provided out_dirs: together
        # with the tracker checkpoint they make the run resumable even across
        # a crash during (or after) this merge; temp workdirs are removed
        # wholesale by execute().


def _dense_rows(upper: np.ndarray):
    """(primary, secondaries, counts) rows of a strict-upper dense matrix."""
    for i in range(upper.shape[0]):
        nz = np.nonzero(upper[i])[0]
        if len(nz):
            yield i, nz, upper[i][nz]


def _emit_merged_rows(
    plan: Plan, merged, result: ExecutionResult,
    *, single_commit: bool = False,
) -> None:
    """Drive the fully merged row stream into the job's output target,
    tallying exact distinct-pair/total counts on the way through (shared by
    the serial and parallel finalize paths — their byte-identity contract
    ends here, at the same writer over the same rows)."""
    job = plan.job
    tally = {"distinct_pairs": 0, "total_count": 0}

    def tallied(rows):
        for primary, secs, cnts in rows:
            tally["distinct_pairs"] += len(secs)
            tally["total_count"] += int(cnts.sum())
            yield primary, secs, cnts

    if job.output == "pairs-file":
        with obs.get_registry().span("ingest/pairs_write"), FileSink(
            job.out_path
        ) as sink:
            for primary, secs, cnts in tallied(merged):
                sink.emit_row(primary, secs, cnts)
        result.pairs_path = job.out_path
    elif job.output == "store":
        _write_store(
            plan, tallied(merged), result, single_commit=single_commit
        )
    else:  # exact stats via the same merge, no materialization
        for _ in tallied(merged):
            pass
    result.summary["distinct_pairs"] = tally["distinct_pairs"]
    result.summary["total_count"] = tally["total_count"]


def _write_store(
    plan: Plan, rows, result: ExecutionResult,
    *, single_commit: bool = False,
) -> None:
    from repro.store import Store

    job = plan.job
    c = job.collection
    if Store.exists(job.out_path):
        store = Store.open(job.out_path)
        if store.vocab_size != c.vocab_size:
            raise ValueError(
                f"store vocab {store.vocab_size} != collection vocab "
                f"{c.vocab_size}"
            )
    else:
        store = Store.create(job.out_path, c.vocab_size)
    # a second handle opened before the commit: the refresh span below
    # measures visibility — the time until an independent (serving-side)
    # reader observes the new segment, exactly what ingest_bench gates
    reader = Store.open(job.out_path)
    df = np.bincount(c.terms, minlength=c.vocab_size).astype(np.int64)
    seg = store.add_segment_from_rows(
        rows, df=df, num_docs=c.num_docs, source=f"plan:{plan.method}",
        single_commit=single_commit,
    )
    with obs.get_registry().span("ingest/refresh") as sp:
        sp.set(visible=reader.refresh())
    result.store = store
    result.segment = seg
    result.summary.setdefault("distinct_pairs", int(seg.nnz))
    result.summary["segment"] = os.path.basename(seg.path)


# ---------------------------------------------------------------------------
# parallel ingest (spawned spill-shard workers + parallel bucket merge)
# ---------------------------------------------------------------------------

# below this much total run data the bucket-merge pool isn't spawned at all:
# a fresh spawned interpreter costs ~0.5s before its first merge, which only
# amortizes once the merge work is tens of MB
_POOL_MIN_MERGE_BYTES = 48 << 20


def _maybe_stall(workdir: str, worker: str, shard: int) -> None:
    """Test-only injection point: ``REPRO_TEST_SPILL_STALL`` (a JSON object
    ``{"worker": .., "shard": .., "seconds": ..}``) makes the matching worker
    publish its pid to ``workdir/stall_<worker>.pid`` and sleep mid-shard —
    after counting, before the completing flush — so a fault test can SIGKILL
    it while it verifiably holds a lease with unpromoted spill output."""
    spec = os.environ.get("REPRO_TEST_SPILL_STALL")
    if not spec:
        return
    import json

    cfg = json.loads(spec)
    if cfg.get("worker") is not None and cfg["worker"] != worker:
        return
    if cfg.get("shard") is not None and int(cfg["shard"]) != shard:
        return
    marker = os.path.join(workdir, f"stall_{worker}.pid")
    with open(marker + ".tmp", "w") as f:
        f.write(str(os.getpid()))
    os.replace(marker + ".tmp", marker)
    deadline = time.time() + float(cfg.get("seconds", 60.0))
    while time.time() < deadline:
        time.sleep(0.05)


def _spill_claim_loop(
    tracker, spill_root, shards, method_name, fn, kwargs, V, budget_pairs,
    worker, reg, workdir,
) -> None:
    """Claim → count → promote loop one participant runs against the shared
    tracker (spawned workers and the parent's inline drain share it).

    Each claimed shard is counted into a private ``wip_<worker>_<shard>``
    directory (invisible to run discovery) while a heartbeat thread renews
    the lease; the finished directory is promoted to ``shard_<shard>`` by an
    atomic rename executed *under the tracker lock* as the completion's
    commit — so a promoted directory and its done-record are never observed
    apart, and a lost race (a backup task finished first) just discards the
    duplicate attempt."""
    import threading

    from repro.store.builder import SpillSink, shard_dir_name, wip_dir_name

    lease = tracker.lease_seconds
    while True:
        unit = tracker.claim(worker)
        if unit is None:
            if tracker.finished:
                return
            # another worker holds the last lease(s): wait for completion or
            # expiry (claim() reclaims expired leases on the next attempt)
            time.sleep(min(0.2, lease / 4.0))
            continue
        (s,) = unit
        wip = os.path.join(spill_root, wip_dir_name(s, worker))
        shutil.rmtree(wip, ignore_errors=True)
        stop = threading.Event()

        def _heartbeat(unit=unit):
            while not stop.wait(lease / 3.0):
                if not tracker.renew(unit, worker):
                    return  # lease lost: completion would be ignored anyway

        hb = threading.Thread(target=_heartbeat, daemon=True)
        hb.start()
        try:
            with reg.span(
                "ingest/count", shard=s, method=method_name,
                docs=int(shards[s].num_docs), worker=worker,
            ):
                sink = SpillSink(
                    V, memory_budget_pairs=budget_pairs, spill_dir=wip
                )
                fn(shards[s], sink, **kwargs)
                _maybe_stall(workdir, worker, s)
                sink.flush()
        finally:
            stop.set()
            hb.join(timeout=lease)
        final = os.path.join(spill_root, shard_dir_name(s))

        def _promote(wip=wip, final=final):
            shutil.rmtree(final, ignore_errors=True)
            os.replace(wip, final)

        if tracker.complete(unit, worker, commit=_promote):
            reg.counter("ingest.docs_counted").inc(int(shards[s].num_docs))
            reg.counter("ingest.shards_done").inc()
        else:
            shutil.rmtree(wip, ignore_errors=True)  # backup task lost


def _dump_obs(reg, obs_dir: str, name: str) -> None:
    """Persist a worker's full telemetry snapshot (metrics + span events)
    for the parent to absorb into one cross-process trace."""
    import json

    path = os.path.join(obs_dir, f"{name}.json")
    with open(path + ".tmp", "w") as f:
        json.dump(reg.snapshot(include_events=True), f)
    os.replace(path + ".tmp", path)


def _spill_worker_main(workdir, worker, params, telemetry, ready_sem,
                       start_evt) -> None:
    """Spawn entry point for one parallel spill worker.

    The corpus arrives via ``workdir/corpus.npz`` (not pickled args — spawn
    re-imports everything anyway, and the file is shared by all workers);
    sharding is recomputed locally and is deterministic, so every process
    agrees on shard boundaries. The ready semaphore / start event pair lets
    the parent exclude per-process setup (imports, corpus load) from
    steady-state timing."""
    from repro.core.specs import get_spec
    from repro.data.corpus import Collection
    from repro.data.preprocess import shard_documents
    from repro.runtime.fault import SharedWorkTracker

    reg = obs.configure(enabled=True) if telemetry else obs.get_registry()
    data = np.load(os.path.join(workdir, "corpus.npz"))
    c = Collection(data["doc_ptr"], data["terms"], int(data["vocab"]))
    shards = shard_documents(c, int(params["num_shards"]))
    spec = get_spec(params["method"])
    tracker = SharedWorkTracker.open(
        os.path.join(workdir, "claims.json"),
        lease_seconds=float(params["lease_seconds"]),
    )
    ready_sem.release()
    start_evt.wait(300.0)
    _spill_claim_loop(
        tracker, os.path.join(workdir, "spill"), shards, params["method"],
        spec.fn, dict(params["method_kwargs"]), c.vocab_size,
        int(params["memory_budget_pairs"]), worker, reg, workdir,
    )
    if telemetry:
        _dump_obs(reg, os.path.join(workdir, "obs"), worker)


def _merge_bucket_files(tasks, V, cap_pairs, reg, fail_after=None) -> None:
    """Merge each task's bucket runs into one run-format file via an atomic
    tmp + rename — a finished bucket file is the resumable unit, so a crashed
    finalizer redoes only unfinished buckets. ``fail_after`` is the test-only
    crash injection (raise after N fresh merges)."""
    from repro.store.builder import merge_bucket_runs, write_rows_run

    fresh = 0
    for b, paths, out in tasks:
        if os.path.exists(out):
            continue
        if fail_after is not None and fresh >= fail_after:
            raise RuntimeError(
                f"injected finalizer crash after {fresh} bucket merges"
            )
        with reg.span("ingest/bucket_merge_file", bucket=b, runs=len(paths)):
            rows = merge_bucket_runs({b: paths}, V, cap_pairs=cap_pairs)
            tmp = f"{out}.tmp-{os.getpid()}"
            write_rows_run(tmp, rows, V)
            os.replace(tmp, out)
        fresh += 1


def _bucket_merge_main(obs_dir, name, tasks, V, cap_pairs, telemetry) -> None:
    """Spawn entry point for one bucket-merge pool worker."""
    reg = obs.configure(enabled=True) if telemetry else obs.get_registry()
    _merge_bucket_files(tasks, V, cap_pairs, reg)
    if telemetry:
        _dump_obs(reg, obs_dir, name)


class ParallelExecutor:
    """N-process parallel ingest for spill-policy plans.

    The document shards PlanExecutor walks serially become a shared work
    queue: ``num_workers`` spawned processes claim shards through a
    :class:`repro.runtime.fault.SharedWorkTracker` (flock'd lease table with
    TTL + heartbeat renewal), count each claimed shard into a private wip
    directory, and promote it atomically on completion — so a SIGKILL'd
    worker's shard is reclaimed after its lease expires and re-done by a
    survivor (or, if every worker dies, drained inline by the parent).
    Finalization merges the radix buckets — already independent by
    construction — across a process pool into resumable per-bucket run
    files, then streams them (ascending bucket = ascending primary range)
    into the same output writers the serial path uses, committing a store
    segment under one flock'd manifest commit.

    The result is **byte-identical** to ``PlanExecutor`` for the same plan:
    shard boundaries are deterministic, promoted run files are exactly what
    the serial executor would have spilled, and the per-bucket merge output
    depends only on the bucket's key→count map.

    Example::

        res = ParallelExecutor(num_workers=4).execute(plan, out_dir="/d/run")
        # crashed mid-run? the same out_dir resumes: counted shards and
        # merged buckets are skipped
        res = ParallelExecutor(num_workers=4).execute(
            plan, out_dir="/d/run", resume=True)
    """

    def __init__(
        self,
        num_workers: int = 2,
        *,
        lease_seconds: float = 15.0,
        merge_workers: int | None = None,
        ready_timeout: float = 180.0,
        verbose: bool = False,
    ):
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        self.num_workers = num_workers
        self.lease_seconds = float(lease_seconds)
        # None → num_workers, but only once the spilled data is big enough
        # to amortize pool spawn cost (explicit values always get a pool)
        self.merge_workers = merge_workers
        self.ready_timeout = ready_timeout
        self.verbose = verbose

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg)

    # ------------------------------------------------------------------
    def execute(
        self,
        plan: Plan,
        *,
        out_dir: str | None = None,
        resume: bool = False,
        on_ready=None,
    ) -> ExecutionResult:
        if plan.sink_policy != "spill":
            # dense/stats merges are in-memory cheap: single process wins
            self._log("[parallel] non-spill policy; delegating to serial")
            return PlanExecutor(verbose=self.verbose).execute(
                plan, out_dir=out_dir, resume=resume
            )
        with obs.get_registry().span(
            "ingest/execute",
            method=plan.method,
            sink=plan.sink_policy,
            output=plan.job.output,
            shards=plan.job.num_shards,
            docs=plan.job.collection.num_docs,
            resume=resume,
            workers=self.num_workers,
        ):
            return self._execute(
                plan, out_dir=out_dir, resume=resume, on_ready=on_ready
            )

    def _execute(self, plan, *, out_dir, resume, on_ready) -> ExecutionResult:
        from repro.data.preprocess import shard_documents
        from repro.runtime.fault import SharedWorkTracker
        from repro.store.builder import (
            _iter_run,
            discover_bucket_runs,
            merge_row_streams,
        )
        from repro.store.spawn import spawn_friendly_env

        job = plan.job
        c = job.collection
        V = c.vocab_size
        own_workdir = out_dir is None
        workdir = out_dir or tempfile.mkdtemp(prefix="cooc_par_")
        spill_root = os.path.join(workdir, "spill")
        merge_dir = os.path.join(workdir, "merge")
        obs_dir = os.path.join(workdir, "obs")
        claims = os.path.join(workdir, "claims.json")
        t0 = time.time()
        reg = obs.get_registry()

        if not resume:
            for d in (spill_root, merge_dir, obs_dir):
                shutil.rmtree(d, ignore_errors=True)
            for f in (claims, claims + ".lock"):
                if os.path.exists(f):
                    os.remove(f)
        for d in (workdir, spill_root, merge_dir, obs_dir):
            os.makedirs(d, exist_ok=True)

        corpus_path = os.path.join(workdir, "corpus.npz")
        if not (resume and os.path.exists(corpus_path)):
            np.savez(
                corpus_path, doc_ptr=c.doc_ptr, terms=c.terms,
                vocab=np.int64(V),
            )

        shards = shard_documents(c, job.num_shards)
        if resume and os.path.exists(claims):
            tracker = SharedWorkTracker.open(
                claims, lease_seconds=self.lease_seconds
            )
            self._heal_resumed(tracker, spill_root, job.num_shards)
        else:
            tracker = SharedWorkTracker.create(
                claims,
                [(s,) for s in range(job.num_shards)],
                lease_seconds=self.lease_seconds,
            )

        telemetry = reg.enabled
        t_ready = time.time()
        if not tracker.finished:
            params = {
                "method": plan.method,
                "method_kwargs": dict(plan.method_kwargs),
                "num_shards": job.num_shards,
                "memory_budget_pairs": job.memory_budget_pairs,
                "lease_seconds": self.lease_seconds,
            }
            with spawn_friendly_env() as ctx:
                ready = ctx.Semaphore(0)
                start = ctx.Event()
                procs = []
                for i in range(self.num_workers):
                    p = ctx.Process(
                        target=_spill_worker_main,
                        args=(workdir, f"w{i}", params, telemetry, ready,
                              start),
                        daemon=True,
                    )
                    p.start()
                    procs.append(p)
            # ready barrier: workers signal after import + corpus load, so
            # timing from t_ready measures steady-state counting, not spawn
            deadline = time.time() + self.ready_timeout
            ready_n = 0
            for _ in range(self.num_workers):
                if ready.acquire(timeout=max(0.0, deadline - time.time())):
                    ready_n += 1
            t_ready = time.time()
            if on_ready is not None:
                on_ready()
            start.set()
            self._log(
                f"[parallel] {ready_n}/{self.num_workers} workers ready in "
                f"{t_ready - t0:.2f}s"
            )
            while any(p.is_alive() for p in procs):
                time.sleep(0.05)
            for p in procs:
                p.join(timeout=5.0)
            if not tracker.finished:
                # every worker exited with work outstanding (crash storm or
                # spawn failure): the parent drains the remaining shards
                # through the same claim loop — progress is never hostage to
                # worker liveness
                self._log("[parallel] workers gone, work left; parent drains")
                _spill_claim_loop(
                    tracker, spill_root, shards, plan.method, plan.spec.fn,
                    dict(plan.method_kwargs), V, job.memory_budget_pairs,
                    "parent", reg, workdir,
                )
            if telemetry:
                self._absorb_obs(reg, obs_dir)
        t_counted = time.time()

        by_bucket, legacy = discover_bucket_runs(spill_root)
        if legacy:  # pre-bucketing runs: only a global k-way merge is correct
            merged = merge_row_streams([_iter_run(p) for p in by_bucket[-1]])
        else:
            merged = self._merged_rows_parallel(
                by_bucket, V, job, merge_dir, obs_dir, reg, telemetry
            )

        summary = {
            "num_docs": c.num_docs,
            "vocab_size": V,
            "method": plan.method,
            "output": job.output,
            "num_shards": job.num_shards,
            "exact": plan.exact,
            "ingest_workers": self.num_workers,
            "reclaimed_shards": tracker.reclaims,
            "plan": plan.describe(),
        }
        result = ExecutionResult(summary=summary)
        _emit_merged_rows(plan, merged, result, single_commit=True)

        end = time.time()
        summary.update(
            {
                "elapsed_s": round(end - t0, 2),
                "ready_wait_s": round(min(t_ready, end) - t0, 2),
                "count_s": round(t_counted - min(t_ready, t_counted), 2),
                "finalize_s": round(end - t_counted, 2),
                # steady-state work time: everything after the ready barrier
                # (what the scaling gates compare across worker counts)
                "work_s": round(end - min(t_ready, end), 2),
                "docs_per_hour": round(
                    c.num_docs / max(end - t0, 1e-9) * 3600
                ),
                "docs_per_hour_work": round(
                    c.num_docs / max(end - t_ready, 1e-9) * 3600
                ),
            }
        )
        if own_workdir:
            shutil.rmtree(workdir, ignore_errors=True)
        return result

    # ------------------------------------------------------------------
    @staticmethod
    def _heal_resumed(tracker, spill_root: str, num_shards: int) -> None:
        """Reconcile the lease table with what actually survived on disk:
        wip partials and out-of-range/undone shard directories are pruned
        (they must not contribute runs), and a done-recorded shard whose
        promoted directory vanished is forced back to pending."""
        from repro.store.builder import SHARD_DIR_RE

        done = {u[0] for u in tracker.done_units()}
        present: set[int] = set()
        for d in glob.glob(os.path.join(spill_root, "*")):
            base = os.path.basename(d)
            m = SHARD_DIR_RE.match(base)
            if m is None:
                if base.startswith("wip_"):
                    shutil.rmtree(d, ignore_errors=True)
                continue
            idx = int(m.group(1))
            if idx in done and idx < num_shards:
                present.add(idx)
            else:
                shutil.rmtree(d, ignore_errors=True)
        for idx in sorted(done - present):
            tracker.requeue((idx,))

    @staticmethod
    def _absorb_obs(reg, obs_dir: str) -> None:
        """Fold every worker's dumped snapshot into the parent registry —
        counters add, histograms merge, and span events land re-based on the
        parent timeline, so one ``--trace-out`` file shows every process."""
        import json

        for p in sorted(glob.glob(os.path.join(obs_dir, "*.json"))):
            try:
                with open(p) as f:
                    snap = json.load(f)
            except (OSError, ValueError):  # half-written by a killed worker
                continue
            os.replace(p, p + ".absorbed")  # never double-absorbed on resume
            # one absorb per worker so its spans carry proc=<worker name>
            reg.absorb(snap, source=os.path.splitext(os.path.basename(p))[0])

    def _merged_rows_parallel(
        self, by_bucket, V, job, merge_dir, obs_dir, reg, telemetry
    ):
        """Merge each bucket's runs into ``merge_dir/bucket_*.run`` across a
        process pool (buckets are independent by construction), then stream
        the finished files back in ascending bucket order — primaries ascend
        across buckets, so the concatenation is the globally merged stream."""
        from repro.store.builder import _iter_run
        from repro.store.spawn import spawn_friendly_env

        cap = 4 * job.memory_budget_pairs
        fail_after = os.environ.get("REPRO_TEST_FAIL_AFTER_MERGES")
        fail_after = int(fail_after) if fail_after else None
        outs, tasks = [], []
        task_bytes = 0
        for b in sorted(by_bucket):
            out = os.path.join(merge_dir, f"bucket_{b:04d}.run")
            outs.append(out)
            if not os.path.exists(out):  # resume: finished buckets skipped
                tasks.append((b, by_bucket[b], out))
                task_bytes += sum(os.path.getsize(p) for p in by_bucket[b])
        n_pool = min(self.merge_workers or self.num_workers, len(tasks))
        if self.merge_workers is None:
            # spawn cost (interpreter + imports per pool process) dwarfs the
            # merge itself on small spills, and pool processes time-slice
            # rather than parallelize without cores to run on: merge inline
            # in either case (an explicit merge_workers= overrides both)
            try:
                cores = len(os.sched_getaffinity(0))
            except AttributeError:
                cores = os.cpu_count() or 1
            if task_bytes < _POOL_MIN_MERGE_BYTES or cores < 2:
                n_pool = min(n_pool, 1)
        with reg.span(
            "ingest/bucket_merge_pool", buckets=len(tasks),
            workers=max(n_pool, 1),
        ):
            if tasks and n_pool > 1 and fail_after is None:
                with spawn_friendly_env() as ctx:
                    procs = [
                        ctx.Process(
                            target=_bucket_merge_main,
                            args=(obs_dir, f"m{i}", tasks[i::n_pool], V, cap,
                                  telemetry),
                            daemon=True,
                        )
                        for i in range(n_pool)
                    ]
                    for p in procs:
                        p.start()
                for p in procs:
                    p.join()
                # buckets a dead pool worker left behind finish inline
                left = [t for t in tasks if not os.path.exists(t[2])]
                if left:
                    self._log(f"[parallel] {len(left)} buckets redone inline")
                    _merge_bucket_files(left, V, cap, reg)
                if telemetry:
                    self._absorb_obs(reg, obs_dir)
            elif tasks:
                _merge_bucket_files(tasks, V, cap, reg, fail_after=fail_after)

        def stream():
            for out in outs:
                yield from _iter_run(out)

        return stream()


# ---------------------------------------------------------------------------
# one-call convenience
# ---------------------------------------------------------------------------


def execute_job(job: CountJob, **execute_kwargs) -> ExecutionResult:
    """Plan + execute in one call (drivers that don't inspect the plan).

    Example::

        res = execute_job(CountJob(collection=c, output="dense"))
        res.counts.sum()    # total co-occurrence mass, exactly
    """
    return Planner().plan(job).execute(**execute_kwargs)
