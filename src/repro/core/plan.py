"""Counting plans: typed jobs → cost-model planning → one execution path.

This is the single entry point every driver, benchmark, and the store
builder share (ISSUE 2 tentpole):

    job  = CountJob(collection=c, output="pairs-file", out_path=...,
                    method="auto", num_shards=16)
    plan = Planner().plan(job)        # cost models pick the method + sinks
    res  = plan.execute(out_dir=...)  # sharded, checkpointed, exact

``Planner`` selects the counting method with the §3 cost models over
:class:`CollectionStats` (``method="auto"``), and selects the merge policy:

* **dense**  — vocab ≤ ``dense_vocab_cap``: per-shard DenseSink, additive
  dense accumulator (exact);
* **spill**  — larger vocabularies: per-shard SpillSink runs on disk,
  k-way-merged exactly at finalization within O(memory budget) — replacing
  the old lossy "StatsSink upper bound across shards" fallback of
  ``launch/cooc_run``;
* **stats**  — only when the job explicitly opts out of exactness
  (``exact=False`` with ``output="stats"``): per-shard aggregate statistics,
  ``distinct_pairs`` becomes an upper bound.

``PlanExecutor`` owns the shard/merge orchestration that used to be
hard-coded in ``launch/cooc_run``: WorkTracker leases with straggler
re-enqueue, idempotent completion, checkpoint/resume every ``ckpt_every``
shards (for the spill policy the on-disk run files double as checkpoint
state), and the final merge into the requested output target
(``dense`` | ``stats`` | ``pairs-file`` | ``store``).
"""

from __future__ import annotations

import dataclasses
import glob
import os
import shutil
import tempfile
import time
from typing import Mapping

import numpy as np

from repro import obs
from repro.core.specs import REGISTRY, MethodSpec, get_spec
from repro.core.types import DenseSink, FileSink, StatsSink
from repro.data.corpus import Collection, CollectionStats

OUTPUTS = ("dense", "stats", "pairs-file", "store")
SINK_POLICIES = ("dense", "spill", "stats")


def _default_use_kernel() -> bool:
    """Pallas kernels only by default on real accelerators."""
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax always present in this repo
        return False


# ---------------------------------------------------------------------------
# CountJob
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CountJob:
    """A validated counting request (what to count, how exact, where to).

    Validation happens at construction: unknown outputs, missing paths,
    ill-typed method kwargs, and df-order prerequisites all raise here, not
    halfway through a multi-hour run.

    Example::

        job = CountJob(collection=c, output="store", out_path="/data/store",
                       method="auto", num_shards=8)
        res = Planner().plan(job).execute()
    """

    collection: Collection
    output: str = "stats"                  # dense | stats | pairs-file | store
    method: str = "auto"                   # registry name or "auto"
    out_path: str | None = None            # pairs-file path / store directory
    exact: bool = True                     # False permits the stats fast path
    memory_budget_pairs: int = 4 << 20     # spill budget (buffered pairs)
    num_shards: int = 1
    dense_vocab_cap: int = 4096            # dense-merge threshold
    df_descending: bool = False            # term IDs are df-descending
    use_kernel: bool | None = None         # None → auto (TPU backend only)
    method_kwargs: Mapping = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not isinstance(self.collection, Collection):
            raise ValueError(
                f"collection must be a Collection, got {type(self.collection).__name__}"
            )
        if self.output not in OUTPUTS:
            raise ValueError(f"unknown output {self.output!r}; have {OUTPUTS}")
        if self.output in ("pairs-file", "store") and not self.out_path:
            raise ValueError(f"output={self.output!r} requires out_path")
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if self.memory_budget_pairs < 1:
            raise ValueError("memory_budget_pairs must be >= 1")
        if self.dense_vocab_cap < 1:
            raise ValueError("dense_vocab_cap must be >= 1")
        if self.method == "auto":
            if self.method_kwargs:
                raise ValueError(
                    "method_kwargs requires an explicit method "
                    "(auto-selected methods run with planner-resolved params)"
                )
        else:
            try:
                spec = get_spec(self.method)
            except KeyError as e:
                raise ValueError(str(e)) from None
            try:
                spec.validate_kwargs(self.method_kwargs)
            except (TypeError, ValueError) as e:
                raise ValueError(f"invalid method_kwargs: {e}") from None
            if spec.needs_df_descending and not self.df_descending:
                raise ValueError(
                    f"method {self.method!r} requires df-descending term IDs "
                    "(remap with data.preprocess.remap_df_descending and set "
                    "df_descending=True)"
                )


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Plan:
    """An executable counting plan (what the Planner decided, and why).

    Carries full provenance: the chosen method and kwargs, the sink/merge
    policy, cost estimates, and the complete candidate ranking — so a
    driver can log *why* this method ran (``describe()``) and a benchmark
    can compare the model against measured time.

    Example::

        plan = Planner().plan(job)
        plan.method, plan.sink_policy       # ('list-blocks', 'spill')
        plan.describe()["ranking"]          # best-first (method, cost) pairs
        res = plan.execute(out_dir="/tmp/run", ckpt_every=4)
    """

    job: CountJob
    method: str
    method_kwargs: Mapping
    sink_policy: str                       # dense | spill | stats
    exact: bool
    estimated_cost: float                  # cost-model work units
    estimated_method_bytes: float          # method working-set estimate
    collection_stats: CollectionStats
    ranking: tuple = ()                    # ((method, cost), ...) best-first

    @property
    def spec(self) -> MethodSpec:
        return REGISTRY[self.method]

    def describe(self) -> dict:
        """JSON-serializable provenance, embedded in driver results."""
        return {
            "method": self.method,
            "method_kwargs": {k: v for k, v in self.method_kwargs.items()},
            "sink_policy": self.sink_policy,
            "exact": self.exact,
            "estimated_cost": round(float(self.estimated_cost), 1),
            "estimated_method_mb": round(self.estimated_method_bytes / 2**20, 2),
            "ranking": [(m, round(float(c), 1)) for m, c in self.ranking],
        }

    def execute(self, **kwargs) -> "ExecutionResult":
        return PlanExecutor().execute(self, **kwargs)


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------


class Planner:
    """Turns a CountJob into a Plan using the MethodSpec cost models.

    ``method="auto"`` ranks every eligible paper/hybrid method by its §3
    cost model over the collection's statistics (docs/methods.md walks the
    regimes); an explicit method skips ranking but still gets validated
    kwargs and a merge policy.

    Example::

        plan = Planner().plan(CountJob(collection=c, output="stats"))
        plan.ranking[0][0] == plan.method   # best-ranked method won
    """

    def __init__(self, registry: Mapping[str, MethodSpec] = REGISTRY):
        self.registry = registry

    def candidates(self, job: CountJob) -> list[MethodSpec]:
        if job.method != "auto":
            return [self.registry[job.method]]
        out = []
        for spec in self.registry.values():
            if spec.kind == "tpu":
                # equal-traversal accelerator adaptations: explicit opt-in
                continue
            if spec.needs_df_descending and not job.df_descending:
                continue
            out.append(spec)
        return out

    def resolve_kwargs(
        self, spec: MethodSpec, job: CountJob, stats: CollectionStats
    ) -> dict:
        """Spec defaults + job overrides + planner-tuned knobs."""
        kw = spec.resolve_kwargs(job.method_kwargs if job.method != "auto" else None)
        if "head" in kw and job.method == "auto":
            kw["head"] = min(kw["head"], stats.vocab_size)
        if "use_kernel" in kw and "use_kernel" not in job.method_kwargs:
            kw["use_kernel"] = (
                job.use_kernel if job.use_kernel is not None else _default_use_kernel()
            )
        return kw

    def rank(
        self, job: CountJob, stats: CollectionStats | None = None
    ) -> list[tuple[float, str, dict]]:
        """All candidate methods as (cost, name, resolved_kwargs), best first."""
        stats = stats or CollectionStats.from_collection(job.collection)
        ranked = []
        for spec in self.candidates(job):
            kw = self.resolve_kwargs(spec, job, stats)
            ranked.append((float(spec.cost(stats, kw)), spec.name, kw))
        ranked.sort(key=lambda t: (t[0], t[1]))
        return ranked

    def sink_policy(self, job: CountJob) -> str:
        if job.output == "dense":
            return "dense"
        V = job.collection.vocab_size
        # dense merge only if the V×V int64 accumulator fits the declared
        # memory budget (~16 bytes per buffered spill pair)
        if V <= job.dense_vocab_cap and 8 * V * V <= 16 * job.memory_budget_pairs:
            return "dense"
        if job.output == "stats" and not job.exact:
            return "stats"
        return "spill"

    def plan(self, job: CountJob) -> Plan:
        stats = CollectionStats.from_collection(job.collection)
        ranked = self.rank(job, stats)
        cost, name, kwargs = ranked[0]
        policy = self.sink_policy(job)
        spec = self.registry[name]
        return Plan(
            job=job,
            method=name,
            method_kwargs=kwargs,
            sink_policy=policy,
            exact=policy != "stats",
            estimated_cost=cost,
            estimated_method_bytes=float(spec.memory_bytes(stats, kwargs)),
            collection_stats=stats,
            ranking=tuple((n, c) for c, n, _ in ranked),
        )


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExecutionResult:
    """What a plan produced. ``summary`` is JSON-serializable; the heavier
    artifacts ride alongside depending on the job's output target.

    Example::

        res = plan.execute()
        res.summary["exact"], res.summary["distinct_pairs"]
        res.store     # output="store": an open repro.store.Store
        res.counts    # output="dense": strict-upper int64 matrix
    """

    summary: dict
    counts: np.ndarray | None = None       # output="dense" (strict upper)
    pairs_path: str | None = None          # output="pairs-file"
    store: object | None = None            # output="store" (repro.store.Store)
    segment: object | None = None          # output="store" (CSRSegment)


class PlanExecutor:
    """Shard/merge orchestration shared by every driver.

    Work units are document shards behind a WorkTracker (leases, straggler
    re-enqueue, idempotent completion). The merge strategy follows the plan's
    sink policy; checkpoint/resume works for all of them — under the spill
    policy, completed shards' sorted run files in ``out_dir/spill/`` *are*
    the bulk checkpoint state, so only tracker + aggregate dicts go through
    the checkpointer.

    Example::

        res = PlanExecutor(verbose=True).execute(
            plan, out_dir="/tmp/run", ckpt_every=4)
        # later, after a crash:
        res = PlanExecutor().execute(plan, out_dir="/tmp/run", resume=True)
    """

    def __init__(self, worker: str = "worker0", verbose: bool = False):
        self.worker = worker
        self.verbose = verbose

    def _log(self, msg: str) -> None:
        if self.verbose:
            print(msg)

    # ------------------------------------------------------------------
    def execute(
        self,
        plan: Plan,
        *,
        out_dir: str | None = None,
        ckpt_every: int = 0,
        resume: bool = False,
    ) -> ExecutionResult:
        # warm the lazy imports before the root span opens: first-use import
        # cost (checkpoint machinery, sharding, sinks) is process setup, not
        # ingest stage time — with it inside the span, a fresh process's
        # stage spans could not tile the root span's wall time
        from repro import checkpoint  # noqa: F401
        from repro.data import preprocess  # noqa: F401
        from repro.runtime import fault  # noqa: F401
        from repro.store import builder  # noqa: F401

        # the root ingest span: every stage span (count/spill/bucket_merge/
        # segment_write/refresh — see docs/observability.md) nests under it,
        # so a trace shows where one run's wall time went
        with obs.get_registry().span(
            "ingest/execute",
            method=plan.method,
            sink=plan.sink_policy,
            output=plan.job.output,
            shards=plan.job.num_shards,
            docs=plan.job.collection.num_docs,
            resume=resume,
        ):
            return self._execute(
                plan, out_dir=out_dir, ckpt_every=ckpt_every, resume=resume
            )

    def _execute(
        self,
        plan: Plan,
        *,
        out_dir: str | None,
        ckpt_every: int,
        resume: bool,
    ) -> ExecutionResult:
        from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
        from repro.data.preprocess import shard_documents
        from repro.runtime.fault import WorkTracker
        from repro.store.builder import SpillSink

        job = plan.job
        c = job.collection
        V = c.vocab_size
        own_workdir = out_dir is None
        workdir = out_dir or tempfile.mkdtemp(prefix="cooc_plan_")
        os.makedirs(workdir, exist_ok=True)
        spill_root = os.path.join(workdir, "spill")
        ckpt_dir = os.path.join(workdir, "ckpt")
        t0 = time.time()

        dense = plan.sink_policy == "dense"
        spill = plan.sink_policy == "spill"
        shards = shard_documents(c, job.num_shards)
        tracker = WorkTracker([(s,) for s in range(job.num_shards)])
        acc = np.zeros((V, V), dtype=np.int64) if dense else None
        agg = {"distinct_pairs": 0, "total_count": 0, "output_bytes": 0}

        step0 = latest_step(ckpt_dir) if resume else None
        if step0 is not None:
            like = {"acc": acc} if dense else {"acc": np.zeros(1)}
            restored, extra = restore_checkpoint(ckpt_dir, step0, like)
            if dense:
                acc = np.array(restored["acc"])  # writable copy
            agg = extra["agg"]
            tracker = WorkTracker.from_state(extra["tracker"])
            self._log(f"[resume] from step {step0}: {len(tracker.done)} shards done")
        if spill:
            # Only completed shards of THIS run may contribute run files: a
            # fresh run wipes the spill root; a resumed run prunes directories
            # that don't correspond to a completed shard (e.g. left by an
            # earlier run with different sharding in the same out_dir).
            if step0 is None:
                shutil.rmtree(spill_root, ignore_errors=True)
            else:
                done_ids = {u[0] for u in tracker.done}
                for d in glob.glob(os.path.join(spill_root, "shard_*")):
                    idx = int(os.path.basename(d).split("_")[1])
                    if idx not in done_ids or idx >= job.num_shards:
                        shutil.rmtree(d, ignore_errors=True)

        reg = obs.get_registry()
        done_since_ckpt = 0
        while not tracker.finished:
            unit = tracker.claim(self.worker, time.monotonic())
            if unit is None:
                tracker.expire(time.monotonic())
                continue
            (s,) = unit
            # per-shard count span: covers sink setup (the spill buffers are
            # a real allocation), produce, AND the completion flush, with
            # nested ingest/spill spans (mid-count and flush-time) — its
            # inclusive time is the shard's whole cost before merging
            with reg.span(
                "ingest/count", shard=s, method=plan.method,
                docs=shards[s].num_docs,
            ):
                if dense:
                    sink = DenseSink(V)
                elif spill:
                    shard_dir = os.path.join(spill_root, f"shard_{s:05d}")
                    if os.path.isdir(shard_dir):
                        shutil.rmtree(shard_dir)  # partials from a dead lease
                    sink = SpillSink(
                        V,
                        memory_budget_pairs=job.memory_budget_pairs,
                        spill_dir=shard_dir,
                    )
                else:
                    sink = StatsSink()
                plan.spec.fn(shards[s], sink, **plan.method_kwargs)
                if tracker.complete(unit, self.worker):
                    if dense:
                        acc += sink.mat
                    elif spill:
                        sink.flush()  # run files persist: the checkpoint
                    else:
                        agg["distinct_pairs"] += sink.distinct_pairs  # upper
                        agg["total_count"] += sink.total_count
                        agg["output_bytes"] += sink.output_bytes
                    done_since_ckpt += 1
            reg.counter("ingest.docs_counted").inc(shards[s].num_docs)
            reg.counter("ingest.shards_done").inc()
            if ckpt_every and done_since_ckpt >= ckpt_every:
                save_checkpoint(
                    ckpt_dir,
                    len(tracker.done),
                    {"acc": acc if dense else np.zeros(1)},
                    extra={"agg": agg, "tracker": tracker.state()},
                )
                done_since_ckpt = 0
                self._log(f"[ckpt] {len(tracker.done)}/{job.num_shards} shards")

        elapsed = time.time() - t0
        summary = {
            "num_docs": c.num_docs,
            "vocab_size": V,
            "method": plan.method,
            "output": job.output,
            "num_shards": job.num_shards,
            "exact": plan.exact,
            "elapsed_s": round(elapsed, 2),
            "docs_per_hour": round(c.num_docs / max(elapsed, 1e-9) * 3600),
            "plan": plan.describe(),
        }
        result = ExecutionResult(summary=summary)

        if dense:
            self._finalize_dense(plan, np.triu(acc, 1), workdir, result)
        elif spill:
            self._finalize_spill(plan, spill_root, result)
        else:
            summary["total_count"] = agg["total_count"]  # additive → exact
            summary["distinct_pairs_upper_bound"] = agg["distinct_pairs"]
            summary["output_bytes_upper_bound"] = agg["output_bytes"]

        if own_workdir:
            shutil.rmtree(workdir, ignore_errors=True)
        return result

    # ------------------------------------------------------------------
    def _finalize_dense(
        self, plan: Plan, upper: np.ndarray, workdir: str, result: ExecutionResult
    ) -> None:
        from repro.core.stats import top_k_pairs

        job = plan.job
        summary = result.summary
        summary["distinct_pairs"] = int((upper > 0).sum())
        summary["total_count"] = int(upper.sum())
        summary["top_pairs"] = top_k_pairs(upper, 5)
        if job.output == "dense" or job.output == "stats":
            result.counts = upper
        if job.output == "pairs-file":
            with obs.get_registry().span("ingest/pairs_write"), FileSink(
                job.out_path
            ) as sink:
                for primary, secs, cnts in _dense_rows(upper):
                    sink.emit_row(primary, secs, cnts)
            result.pairs_path = job.out_path
        elif job.output == "store":
            self._write_store(plan, _dense_rows(upper), result)

    def _finalize_spill(
        self, plan: Plan, spill_root: str, result: ExecutionResult
    ) -> None:
        from repro.store.builder import merge_bucket_runs

        job = plan.job
        runs = sorted(glob.glob(os.path.join(spill_root, "shard_*", "run_*.bin")))
        # bucket runs (run_<spill>_b<bucket>.bin) cover disjoint ascending
        # primary ranges: merge bucket by bucket — in memory when the bucket
        # fits the merge cap, via a heap spanning only that bucket's runs
        # across shards otherwise — never a global k-way over every run file
        by_bucket: dict[int, list[str]] = {}
        legacy = False
        for p in runs:
            name = os.path.basename(p)
            if "_b" not in name:
                legacy = True  # pre-bucketing run file (resumed old spill dir)
                break
            b = int(name.rsplit("_b", 1)[1].split(".")[0])
            by_bucket.setdefault(b, []).append(p)
        if legacy:
            # unbucketed runs span the whole primary range: only a global
            # k-way merge is order-correct for them
            from repro.store.builder import _iter_run, merge_row_streams

            merged = merge_row_streams([_iter_run(p) for p in runs])
        else:
            merged = merge_bucket_runs(
                by_bucket, plan.job.collection.vocab_size,
                cap_pairs=4 * job.memory_budget_pairs,
            )

        tally = {"distinct_pairs": 0, "total_count": 0}

        def tallied(rows):
            for primary, secs, cnts in rows:
                tally["distinct_pairs"] += len(secs)
                tally["total_count"] += int(cnts.sum())
                yield primary, secs, cnts

        if job.output == "pairs-file":
            with obs.get_registry().span("ingest/pairs_write"), FileSink(
                job.out_path
            ) as sink:
                for primary, secs, cnts in tallied(merged):
                    sink.emit_row(primary, secs, cnts)
            result.pairs_path = job.out_path
        elif job.output == "store":
            self._write_store(plan, tallied(merged), result)
        else:  # exact stats via the same merge, no materialization
            for _ in tallied(merged):
                pass
        result.summary["distinct_pairs"] = tally["distinct_pairs"]
        result.summary["total_count"] = tally["total_count"]
        # run files are deliberately kept in user-provided out_dirs: together
        # with the tracker checkpoint they make the run resumable even across
        # a crash during (or after) this merge; temp workdirs are removed
        # wholesale by execute().

    def _write_store(self, plan: Plan, rows, result: ExecutionResult) -> None:
        from repro.store import Store

        job = plan.job
        c = job.collection
        if Store.exists(job.out_path):
            store = Store.open(job.out_path)
            if store.vocab_size != c.vocab_size:
                raise ValueError(
                    f"store vocab {store.vocab_size} != collection vocab "
                    f"{c.vocab_size}"
                )
        else:
            store = Store.create(job.out_path, c.vocab_size)
        # a second handle opened before the commit: the refresh span below
        # measures visibility — the time until an independent (serving-side)
        # reader observes the new segment, exactly what ingest_bench gates
        reader = Store.open(job.out_path)
        df = np.bincount(c.terms, minlength=c.vocab_size).astype(np.int64)
        seg = store.add_segment_from_rows(
            rows, df=df, num_docs=c.num_docs, source=f"plan:{plan.method}"
        )
        with obs.get_registry().span("ingest/refresh") as sp:
            sp.set(visible=reader.refresh())
        result.store = store
        result.segment = seg
        result.summary.setdefault("distinct_pairs", int(seg.nnz))
        result.summary["segment"] = os.path.basename(seg.path)


def _dense_rows(upper: np.ndarray):
    """(primary, secondaries, counts) rows of a strict-upper dense matrix."""
    for i in range(upper.shape[0]):
        nz = np.nonzero(upper[i])[0]
        if len(nz):
            yield i, nz, upper[i][nz]


# ---------------------------------------------------------------------------
# one-call convenience
# ---------------------------------------------------------------------------


def execute_job(job: CountJob, **execute_kwargs) -> ExecutionResult:
    """Plan + execute in one call (drivers that don't inspect the plan).

    Example::

        res = execute_job(CountJob(collection=c, output="dense"))
        res.counts.sum()    # total co-occurrence mass, exactly
    """
    return Planner().plan(job).execute(**execute_kwargs)
