"""FREQ-SPLIT (beyond paper): dense-head / sparse-tail hybrid.

Requires df-descending term IDs (data/preprocess.remap_df_descending). Split
the vocabulary at rank H:

* head × head  (both IDs < H): dense tiled Gram matmul on the MXU — with
  Zipfian statistics the top-left of C is dense, so the matmul does almost no
  wasted work;
* everything else: tail-side LIST-SCAN — for each tail term t (df is small by
  construction), one histogram over the forward documents of postings(t)
  restricted to IDs < t yields the whole column C[:t, t]. Work is
  Σ_{t ≥ H} df_t · avg_len, i.e. proportional to actual postings; no empty
  intersections (LIST-PAIRS' waste) and no all-zero tiles (LIST-BLOCKS'
  waste at the tail).

Exactness is preserved: both paths compute exact integer counts and cover a
disjoint partition of the strict upper triangle.
"""

from __future__ import annotations

import numpy as np

from repro.core.types import PairSink, emit_dense_rows
from repro.data.corpus import Collection
from repro.data.index import build_inverted_index, incidence_dense


def count_freq_split(
    c: Collection,
    sink: PairSink,
    *,
    head: int = 1024,
    doc_tile: int = 2048,
    use_kernel: bool = True,
) -> dict:
    """``sink`` must support emit_col (DenseSink / StatsSink do)."""
    from repro.kernels import ops as kops

    V, D = c.vocab_size, c.num_docs
    H = min(head, V)

    # --- head × head: dense Gram over document tiles (MXU path) ---
    matmuls = 0
    acc = np.zeros((H, H), dtype=np.int64)
    for dlo in range(0, D, doc_tile):
        dhi = min(dlo + doc_tile, D)
        tile = incidence_dense(c, dlo, dhi, 0, H)
        acc += np.asarray(kops.cooc_gram(tile, tile, use_kernel=use_kernel)).astype(np.int64)
        matmuls += 1
    emit_dense_rows(acc, sink, row_lo=0, col_lo=0)

    # --- tail columns: tail-side LIST-SCAN histograms ---
    inv = build_inverted_index(c)
    tail_postings = 0
    col = np.zeros(V, dtype=np.int64)
    for t in range(H, V):
        post = inv.postings(t)
        if len(post) == 0:
            continue
        col[:t] = 0
        for d in post:
            ts = c.doc(int(d))
            lower = ts[: np.searchsorted(ts, t)]  # strictly smaller IDs
            col[lower] += 1
            tail_postings += 1
        nz = np.nonzero(col[:t])[0]
        if len(nz):
            sink.emit_col(t, nz, col[nz])
    return {
        "head": H,
        "head_matmuls": matmuls,
        "tail_postings_scanned": tail_postings,
    }
