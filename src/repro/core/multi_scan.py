"""MULTI-SCAN (paper §2): repeated forward scans, a primary keys per pass.

Only the forward index is needed. Pass p claims the next ``a`` term IDs as
primary keys (the paper used a = 100) and scans all forward documents; for
each primary key found in a document, every term with a higher ID in that
document increments the primary's accumulator table. Because per-document
terms are sorted ascending and primaries are claimed in ascending ID order,
documents whose largest term ID is below the pass window are skipped entirely
("after just a few passes many of the documents will have been fully
processed") — we reproduce that skip.
"""

from __future__ import annotations

import numpy as np

from repro.core.types import PairSink
from repro.data.corpus import Collection


def count_multi_scan(c: Collection, sink: PairSink, *, accumulators: int = 100) -> dict:
    V = c.vocab_size
    a = accumulators
    passes = 0
    docs_scanned = 0
    max_term = np.array([c.doc(d)[-1] if len(c.doc(d)) else -1 for d in range(c.num_docs)])
    live = np.arange(c.num_docs)[np.diff(c.doc_ptr) > 0]

    for lo in range(0, V, a):
        hi = min(lo + a, V)
        passes += 1
        acc = np.zeros((hi - lo, V), dtype=np.int64)
        touched = np.zeros(hi - lo, dtype=bool)
        # skip fully-processed documents: their max term is below the window
        live = live[max_term[live] >= lo]
        for d in live:
            ts = c.doc(int(d))
            docs_scanned += 1
            # primaries of this window present in the document
            s = np.searchsorted(ts, lo)
            e = np.searchsorted(ts, hi)
            if s == e:
                continue
            prims = ts[s:e]
            for p in prims:
                sec = ts[np.searchsorted(ts, p) + 1:]
                if len(sec):
                    acc[p - lo, sec] += 1
                    touched[p - lo] = True
        for slot in np.nonzero(touched)[0]:
            nz = np.nonzero(acc[slot])[0]
            sink.emit_row(lo + slot, nz, acc[slot][nz])
    return {"passes": passes, "docs_scanned": docs_scanned, "accumulators": a}


def count_multi_scan_matmul(
    c: Collection, sink: PairSink, *, accumulators: int = 128, doc_tile: int = 2048,
    use_kernel: bool = True,
) -> dict:
    """TPU-adapted MULTI-SCAN: each pass is a skinny Gram matmul
    C[P, :] = B[:, P]ᵀ B for the pass's primary slice P, streamed over
    document tiles through the same MXU kernel as LIST-BLOCKS. The pass
    structure (and its memory bound) is the paper's; the scan becomes a
    matmul with the primary slice as the 128-aligned M dimension.
    """
    from repro.data.index import incidence_dense
    from repro.kernels import ops as kops

    V, D = c.vocab_size, c.num_docs
    a = accumulators
    passes = 0
    for lo in range(0, V, a):
        hi = min(lo + a, V)
        passes += 1
        acc = np.zeros((hi - lo, V), dtype=np.int64)
        for dlo in range(0, D, doc_tile):
            dhi = min(dlo + doc_tile, D)
            prim = incidence_dense(c, dlo, dhi, lo, hi)
            full = incidence_dense(c, dlo, dhi, 0, V)
            acc += np.asarray(kops.cooc_gram(prim, full, use_kernel=use_kernel)).astype(np.int64)
        for slot in range(hi - lo):
            row = acc[slot]
            nz = np.nonzero(row)[0]
            nz = nz[nz > lo + slot]
            if len(nz):
                sink.emit_row(lo + slot, nz, row[nz])
    return {"passes": passes, "accumulators": a}
