"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-block quantization: grads are quantized before the cross-pod
all-reduce (4× wire bytes saved at bf16, 2× at f32→int8+scale), and the
quantization error is carried in an error-feedback buffer added to the next
step's gradient (Seide et al. 2014 / EF-SGD) so convergence is preserved.

``compressed_psum`` is the shard_map building block: quantize → psum of int32
accumulators → dequantize. Used for the slow inter-pod axis only ("pod"
bandwidth << intra-pod ICI); intra-pod reductions stay full-precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, block: int = 256):
    """Per-block symmetric int8: returns (q int8, scales f32)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, block: int = 256):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def ef_compress_update(grad: jax.Array, error: jax.Array, block: int = 256):
    """Error-feedback compression of one tensor: returns
    (decompressed_grad, new_error). decompressed = Q(grad + error);
    new_error = (grad + error) - decompressed."""
    target = grad.astype(jnp.float32) + error
    q, s = quantize_int8(target, block)
    deq = dequantize_int8(q, s, grad.shape, block)
    return deq.astype(grad.dtype), target - deq


def compressed_psum(x: jax.Array, axis_name: str, block: int = 256) -> jax.Array:
    """psum with int8 wire format (inside shard_map): each participant
    quantizes, the int8 payloads are summed in int32, then dequantized with
    the max scale. Exactness is NOT preserved (that is the point of EF)."""
    q, s = quantize_int8(x, block)
    s_max = jax.lax.pmax(s, axis_name)
    # rescale local payload to the common scale so the int sum is coherent
    q_common = jnp.round(
        q.astype(jnp.float32) * (s / s_max)[:, None]
    ).astype(jnp.int32)
    total = jax.lax.psum(q_common, axis_name)
    return dequantize_int8(total, s_max, x.shape, block)
