"""AdamW / Adafactor / SGD — optax-like minimal interface.

Memory policy for 100B+ models (DESIGN.md): AdamW supports bf16 moments
(halves optimizer HBM); Adafactor factors the second moment into row/col
statistics (O(n+m) instead of O(nm)) — used for the 340B/671B configs so
params+grads+state fit 16 GB/chip on the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable  # params -> state
    update: Callable  # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def _sched(lr, count):
    return lr(count) if callable(lr) else lr


def sgd(lr, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(jnp.zeros_like, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        count = state["count"] + 1
        step = _sched(lr, count)
        updates = jax.tree.map(lambda m: -step * m, mu)
        return updates, {"mu": mu, "count": count}

    return Optimizer(init, update)


def adamw(
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        m = jax.tree.map(
            lambda m_, g: (b1 * m_.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(moment_dtype),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: (b2 * v_.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(moment_dtype),
            state["v"], grads,
        )
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        step = _sched(lr, count)

        def u(m_, v_, p):
            mhat = m_.astype(jnp.float32) / c1
            vhat = v_.astype(jnp.float32) / c2
            return -step * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(u, m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def adafactor(
    lr,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018): matrices keep
    per-row + per-col statistics only. 1-D params fall back to full AdaGrad-
    style accumulators."""

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def leaf(p):
            if _factored(p):
                return {
                    "r": jnp.zeros(p.shape[:-1], jnp.float32),     # row stats
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "stats": jax.tree.map(leaf, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay
        step = _sched(lr, count)

        def leaf(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                r = beta * s["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * s["c"] + (1 - beta) * g2.mean(axis=-2)
                denom = (
                    r[..., None]
                    / jnp.maximum(r.mean(axis=-1, keepdims=True), eps)[..., None]
                ) * c[..., None, :]
                upd = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                new_s = {"r": r, "c": c}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # relative update clipping (Adafactor's RMS clip)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            upd = -step * (upd + weight_decay * p.astype(jnp.float32))
            return upd, new_s

        flat_g, treedef = jax.tree.flatten(grads)
        flat_s = treedef.flatten_up_to(state["stats"])
        flat_p = treedef.flatten_up_to(params)
        out = [leaf(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        updates = treedef.unflatten([o[0] for o in out])
        stats = treedef.unflatten([o[1] for o in out])
        return updates, {"stats": stats, "count": count}

    return Optimizer(init, update)


def make_optimizer(name: str, lr, **kwargs) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgd": sgd}[name](lr, **kwargs)
