"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32) if hasattr(count, "astype") else float(count)
        warm = peak_lr * c / max(warmup_steps, 1)
        progress = jnp.clip(
            (c - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(c < warmup_steps, warm, cos)

    return lr
