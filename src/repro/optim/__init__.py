"""Optimizers + distributed-optimization tricks (no external deps)."""

from repro.optim.optimizers import adamw, adafactor, sgd, apply_updates, Optimizer
from repro.optim.schedule import warmup_cosine
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compress import quantize_int8, dequantize_int8, ef_compress_update

__all__ = [
    "adamw",
    "adafactor",
    "sgd",
    "apply_updates",
    "Optimizer",
    "warmup_cosine",
    "clip_by_global_norm",
    "global_norm",
    "quantize_int8",
    "dequantize_int8",
    "ef_compress_update",
]
