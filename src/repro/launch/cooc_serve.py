"""Co-occurrence query serving driver (the statistic's serving side).

    PYTHONPATH=src python -m repro.launch.cooc_serve --docs 5000 --vocab 4096 \
        --method auto --queries 2000 --batch 64 --topk 10 --score pmi \
        --workers 4 --clients 4 --batch-window-ms 2 --kernel pallas

Builds (or opens, with --store) a persistent co-occurrence store, then
replays a Zipf-skewed query workload — the access pattern of real serving
traffic, where popular terms dominate — and reports build throughput plus
per-request latency percentiles (p50/p95/p99) and QPS as JSON.

Two serving topologies:

* ``--workers 0`` (default) — in-process: one QueryEngine, batched calls
  from a single thread (the PR-1 behaviour).
* ``--workers N`` — the multi-process layer (store/serving.py): N spawned
  workers share the store's mmap'd segments, ``--clients`` concurrent
  client threads submit typed requests (store/requests.py — the wire
  protocol), and each worker coalesces concurrent requests into batched
  kernel launches within ``--batch-window-ms``.

``--routing`` enables hot-term routing: the client-side QueryPlanner hashes
each term to the worker that owns its cache row, so per-worker LRU caches
partition the vocabulary instead of duplicating the Zipf head (the stats
JSON reports the aggregate and per-worker ``cache_hit_rate``).

``--store-format v2`` builds block-compressed segments (codecs + bloom
filter, docs/formats.md) instead of the raw v1 arrays; query results are
byte-identical either way. ``--build-segments N`` shards the build into N
segments, and ``--compact`` launches a background size-tiered compaction
(``Store.compact_background``) once serving is up, merging those segments
in a separate process *while the workers answer queries* — the stats JSON
gains a ``compaction`` key with the merge result, and multi-worker stats
include the ``storage`` codec counters (blocks decoded, block-cache hit
rate, bloom negatives).

``--follow FEED`` tails a feed file (repro.stream: one document per line
of space-separated term IDs) into the store *while the workload runs*,
sealing micro-segments under the ``--max-lag-ms`` visibility budget, and
``--refresh-interval-ms`` makes idle workers refresh the manifest
periodically so a server with no traffic still surfaces each seal — the
stats JSON gains a ``stream`` key (cursor position, visibility-lag
percentiles) and multi-worker stats a ``freshness`` block (manifest
generation, segment census, seconds since last append).

``--max-inflight`` bounds each worker's request queue (overflow is shed
as typed ``ServerOverloaded`` and reported under ``"shed"`` instead of
queueing without limit), ``--deadline-ms`` propagates the client timeout
in the request envelope so workers skip expired requests
(``"deadline_timeouts"``), and ``--max-respawns`` sets the supervisor's
replacement budget for dead workers — the fault-tolerance layer of
docs/serving.md#degradation--recovery, surfaced in the stats JSON's
``serving.resilience`` block.

``--kernel`` picks the score-and-select backend for either topology:
``numpy`` (jitted reference) or ``pallas`` (fused top-k gather kernel;
interpreter mode off-TPU). Results are bit-identical between the two.

Latency is reported from **both sides of the queue**: the ``topk_p*_ms`` /
``pair_p*_ms`` keys are client-side wall percentiles (submit → response,
including queue transport), while ``server_timing`` (multi-process runs)
breaks the same traffic down server-side — queue-wait vs execute vs total
request latency, from worker histograms merged across processes (see
docs/observability.md). ``--trace-out`` writes the driver-side span trace
(the store build's ingest stages and in-process query spans);
``--metrics-interval S`` dumps Prometheus-text metrics to stderr every S
seconds and sets the workers' snapshot cadence.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import threading
import time

import numpy as np

from repro import obs
from repro.core.cooc import count_to_store
from repro.data.corpus import _zipf_probs, synthetic_zipf_collection
from repro.store import CoocServer, QueryEngine, ServerOverloaded, Store


def _percentiles(lat_s: list[float]) -> dict:
    """Client-side wall percentiles (queue transport included) — compare
    with the server-side ``server_timing`` histograms."""
    if not lat_s:  # everything shed/expired: no admitted latencies
        return {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0}
    a = np.asarray(lat_s) * 1e3
    return {
        "p50_ms": round(float(np.percentile(a, 50)), 3),
        "p95_ms": round(float(np.percentile(a, 95)), 3),
        "p99_ms": round(float(np.percentile(a, 99)), 3),
    }


def _build_or_open(
    docs: int,
    vocab: int,
    method: str,
    store_path: str | None,
    budget_pairs: int,
    seed: int,
    *,
    segment_version: int | None = None,
    build_segments: int = 1,
) -> tuple[Store, str, float]:
    if store_path and Store.exists(store_path):
        return Store.open(store_path), store_path, 0.0
    store_path = store_path or os.path.join(
        tempfile.mkdtemp(prefix="cooc_store_"), "store"
    )
    c = synthetic_zipf_collection(docs, vocab=vocab, mean_len=40, seed=seed)
    t0 = time.perf_counter()
    if segment_version is not None or build_segments > 1:
        # pre-create so the manifest pins the segment format; every append
        # (count_to_store opens an existing store) inherits it
        store = Store.create(
            store_path, c.vocab_size, segment_version=segment_version
        )
    if build_segments > 1:
        # shard the corpus into several appends: a multi-segment store is
        # what --compact merges while serving runs against it
        from repro.data.preprocess import shard_documents

        for shard in shard_documents(c, build_segments):
            store.append_collection(
                shard, method=method, memory_budget_pairs=budget_pairs
            )
        seg = store.segments[-1]
    else:
        store, seg = count_to_store(
            method, c, store_path, memory_budget_pairs=budget_pairs
        )
    build_s = time.perf_counter() - t0
    print(
        f"[build] {seg.nnz} pairs from {docs} docs via "
        f"{seg.meta.get('source', method)} in {build_s:.2f}s "
        f"({docs / build_s * 3600:.0f} docs/hour) -> {store_path} "
        f"(format v{store.segment_version}, "
        f"{len(store.segment_names)} segment(s))"
    )
    return store, store_path, build_s


def _zipf_sampler(store: Store, seed: int):
    """Zipf-skewed term draws: hot (high-df) terms get most of the traffic."""
    V = store.vocab_size
    probs = _zipf_probs(V, 1.0)
    df_order = np.argsort(-store.df(), kind="stable")

    def draw(rng, n):
        return df_order[rng.choice(V, size=n, p=probs)]

    return draw


# ---------------------------------------------------------------- topologies
def _serve_inprocess(
    store: Store, draw, queries, batch, topk, score, kernel, seed,
    cache_rows=4096,
) -> dict:
    engine = QueryEngine(store, kernel=kernel, cache_rows=cache_rows)
    rng = np.random.default_rng(seed + 1)
    n_batches = max(queries // batch, 1)
    engine.topk(draw(rng, batch), k=topk, score=score)  # jit warm-up
    lat = []
    for _ in range(n_batches):
        terms = draw(rng, batch)
        t0 = time.perf_counter()
        engine.topk(terms, k=topk, score=score)
        lat.append(time.perf_counter() - t0)
    topk_stats = _percentiles(lat)
    topk_qps = round(n_batches * batch / sum(lat))

    lat_pc = []
    for _ in range(n_batches):
        pairs = np.stack([draw(rng, batch), draw(rng, batch)], axis=1)
        t0 = time.perf_counter()
        engine.pair_counts(pairs)
        lat_pc.append(time.perf_counter() - t0)
    return {
        "topk_qps": topk_qps,
        **{f"topk_{k}": v for k, v in topk_stats.items()},
        "pair_qps": round(n_batches * batch / sum(lat_pc)),
        **{f"pair_{k}": v for k, v in _percentiles(lat_pc).items()},
        "row_cache": dict(engine.stats),
    }


def _start_compaction(store: Store):
    """Kick off the background merge ``--compact`` asks for: every current
    segment when several exist (None when there is nothing to merge)."""
    names = store.segment_names
    return store.compact_background(names=names) if len(names) > 1 else None


def _serve_multiprocess(
    store_path, draw, queries, batch, topk, score,
    workers, clients, batch_window_ms, kernel, seed,
    routing=False, cache_rows=4096, metrics_interval=0.0,
    keep_metrics=False, compact_store=None, refresh_interval_ms=0.0,
    max_inflight=0, max_respawns=2, deadline_ms=0.0,
) -> dict:
    """Two phases (all-clients top-k, then all-clients pair lookups),
    barrier-aligned so each workload's QPS is measured against its own
    wall-clock — directly comparable to the in-process numbers.

    ``compact_store`` (from ``--compact``) starts a background compaction
    right after the workers spawn: the merge commits mid-workload and the
    workers pick the new manifest up via their between-batch refresh().

    ``max_inflight`` / ``deadline_ms`` turn on admission control: a
    request shed at a full queue (typed ``ServerOverloaded``) or expired
    past its deadline (``TimeoutError``) is counted — under ``shed`` /
    ``deadline_timeouts`` — instead of aborting the workload, and drops
    out of the latency percentiles (they cover admitted requests)."""
    per_client = max(queries // (batch * clients), 1)
    timeout_s = deadline_ms / 1e3 if deadline_ms > 0 else 60.0
    lat_topk: list[float] = []
    lat_pair: list[float] = []
    rejected = {"shed": 0, "deadline_timeouts": 0}
    spans: dict[str, list[tuple[float, float]]] = {"topk": [], "pair": []}
    errors: list[Exception] = []
    lock = threading.Lock()
    barrier = threading.Barrier(clients)

    server = CoocServer(
        store_path, workers=workers, batch_window_ms=batch_window_ms,
        kernel=kernel, routing=routing, cache_rows=cache_rows,
        stats_interval_s=metrics_interval,
        refresh_interval_ms=refresh_interval_ms,
        max_inflight=max_inflight, max_respawns=max_respawns,
    ).start()
    compact_handle = _start_compaction(compact_store) if compact_store else None

    stop_dump = threading.Event()
    dumper = None
    if metrics_interval > 0:
        def _dump():
            # Live fleet view: workers publish registry snapshots every
            # stats_interval_s; stats() merges the freshest per worker.
            while not stop_dump.wait(metrics_interval):
                snap = server.stats().get("metrics")
                if snap:
                    print(obs.prometheus_text(snap), file=sys.stderr, flush=True)
        dumper = threading.Thread(target=_dump, daemon=True)
        dumper.start()

    def client_loop(idx: int):
        try:
            client = server.client()
            rng = np.random.default_rng(seed + 1 + idx)
            rej = {"shed": 0, "deadline_timeouts": 0}

            def call(fn, *a, **kw):
                try:
                    t0 = time.perf_counter()
                    fn(*a, timeout=timeout_s, **kw)
                    return time.perf_counter() - t0
                except ServerOverloaded:
                    rej["shed"] += 1
                except TimeoutError:
                    rej["deadline_timeouts"] += 1
                return None

            call(client.topk, draw(rng, batch), k=topk, score=score)  # warm-up
            call(client.pair_counts,
                 np.stack([draw(rng, batch), draw(rng, batch)], axis=1))

            barrier.wait()
            phase0 = time.perf_counter()
            ltk = []
            for _ in range(per_client):
                dt = call(client.topk, draw(rng, batch), k=topk, score=score)
                if dt is not None:
                    ltk.append(dt)
            topk_span = (phase0, time.perf_counter())

            barrier.wait()
            phase0 = time.perf_counter()
            lpc = []
            for _ in range(per_client):
                pairs = np.stack([draw(rng, batch), draw(rng, batch)], axis=1)
                dt = call(client.pair_counts, pairs)
                if dt is not None:
                    lpc.append(dt)
            pair_span = (phase0, time.perf_counter())

            with lock:
                lat_topk.extend(ltk)
                lat_pair.extend(lpc)
                rejected["shed"] += rej["shed"]
                rejected["deadline_timeouts"] += rej["deadline_timeouts"]
                spans["topk"].append(topk_span)
                spans["pair"].append(pair_span)
        except Exception as e:  # pragma: no cover - surfaced below
            barrier.abort()
            with lock:
                errors.append(e)

    threads = [
        threading.Thread(target=client_loop, args=(i,)) for i in range(clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop_dump.set()
    if dumper is not None:
        dumper.join(timeout=5)
    sstats = server.stop()
    if errors:
        raise errors[0]

    def phase_wall(name: str) -> float:
        starts, ends = zip(*spans[name])
        return max(ends) - min(starts)

    # ``server_timing`` is hoisted to the top of the result; the raw merged
    # metrics snapshot is bulky, so it only stays when telemetry was asked for.
    serving = {
        k: v for k, v in sstats.items()
        if k != "server_timing" and (keep_metrics or k != "metrics")
    }
    total_topk = len(lat_topk) * batch
    total_pair = len(lat_pair) * batch
    out = {
        "clients": clients,
        "topk_qps": round(total_topk / phase_wall("topk")),
        **{f"topk_{k}": v for k, v in _percentiles(lat_topk).items()},
        "pair_qps": round(total_pair / phase_wall("pair")),
        **{f"pair_{k}": v for k, v in _percentiles(lat_pair).items()},
        "server_timing": sstats.get("server_timing", {}),
        "workers_lost": sstats.get("workers_lost", 0),
        "shed": rejected["shed"],
        "deadline_timeouts": rejected["deadline_timeouts"],
        "serving": serving,
    }
    if compact_handle is not None:
        out["compaction"] = compact_handle.join(timeout=300)
    return out


def serve(
    docs: int = 5_000,
    vocab: int = 4_096,
    method: str = "auto",
    store_path: str | None = None,
    budget_pairs: int = 1 << 20,
    queries: int = 2_000,
    batch: int = 64,
    topk: int = 10,
    score: str = "count",
    seed: int = 0,
    workers: int = 0,
    clients: int = 2,
    batch_window_ms: float = 2.0,
    kernel: str = "numpy",
    routing: bool = False,
    cache_rows: int = 4096,
    json_out: str | None = None,
    trace_out: str | None = None,
    metrics_interval: float = 0.0,
    store_format: str | None = None,
    build_segments: int = 1,
    compact: bool = False,
    follow: str | None = None,
    refresh_interval_ms: float = 0.0,
    max_lag_ms: float = 2_000.0,
    max_inflight: int = 0,
    max_respawns: int = 2,
    deadline_ms: float = 0.0,
) -> dict:
    """Build/open a store and replay a Zipf workload; returns the stats dict
    (and writes it as JSON to ``json_out`` if given).

    ``store_format`` ("v1" raw / "v2" compressed) pins the segment format of
    a freshly built store; ``build_segments`` shards the corpus into that
    many appended segments; ``compact`` merges them in a background process
    **while the workload runs** (the serving workers pick up the swap via
    refresh()) and reports the result under ``"compaction"``.

    ``follow`` tails a feed file (repro.stream format: one document per
    line of space-separated term IDs) into the store **while serving**,
    sealing micro-segments under a ``max_lag_ms`` visibility budget —
    pair ``--workers N`` with ``refresh_interval_ms`` so even idle workers
    see each seal; the ingest summary lands under ``"stream"``.

    ``max_inflight`` bounds each worker's request queue (overflow is shed
    as typed ``ServerOverloaded`` and reported under ``"shed"``);
    ``deadline_ms`` makes the client timeout travel in the request
    envelope so workers skip expired requests; ``max_respawns`` is the
    supervisor's replacement budget per dead worker (multi-process
    topology only — docs/serving.md#degradation--recovery)."""
    telemetry = bool(trace_out) or metrics_interval > 0
    reg = obs.configure(enabled=True) if telemetry else obs.get_registry()
    segment_version = (
        None if store_format is None else int(store_format.lstrip("v"))
    )
    store, store_path, build_s = _build_or_open(
        docs, vocab, method, store_path, budget_pairs, seed,
        segment_version=segment_version, build_segments=build_segments,
    )
    draw = _zipf_sampler(store, seed)

    ingestor = None
    if follow:
        from repro.stream import FileTailSource, StreamConfig, StreamIngestor

        # tail the feed into the serving store while the workload runs;
        # the cursor lives in the store manifest, so re-running with the
        # same feed resumes instead of re-ingesting
        ingestor = StreamIngestor(
            store,
            FileTailSource(follow),
            StreamConfig(max_visibility_lag_ms=max_lag_ms),
            source_id=os.path.abspath(follow),
        ).start()

    if workers <= 0:
        compact_handle = _start_compaction(store) if compact else None
        stop_dump = threading.Event()
        dumper = None
        if metrics_interval > 0:
            def _dump():
                while not stop_dump.wait(metrics_interval):
                    print(reg.prometheus_text(), file=sys.stderr, flush=True)
            dumper = threading.Thread(target=_dump, daemon=True)
            dumper.start()
        try:
            served = _serve_inprocess(
                store, draw, queries, batch, topk, score, kernel, seed,
                cache_rows=cache_rows,
            )
        finally:
            stop_dump.set()
            if dumper is not None:
                dumper.join(timeout=5)
        if compact_handle is not None:
            served["compaction"] = compact_handle.join(timeout=300)
    else:
        served = _serve_multiprocess(
            store_path, draw, queries, batch, topk, score,
            workers, clients, batch_window_ms, kernel, seed,
            routing=routing, cache_rows=cache_rows,
            metrics_interval=metrics_interval, keep_metrics=telemetry,
            compact_store=store if compact else None,
            refresh_interval_ms=refresh_interval_ms,
            max_inflight=max_inflight, max_respawns=max_respawns,
            deadline_ms=deadline_ms,
        )

    if ingestor is not None:
        # don't raise: serving stats are still valid even if ingest died —
        # but the failure must be loud, not a silently stale cursor
        ingestor.stop(raise_on_error=False)
        served["stream"] = ingestor.summary()
        if not ingestor.healthy:
            print(
                f"[stream] ingest FAILED, feed tailing stopped early: "
                f"{served['stream']['error']}",
                file=sys.stderr,
            )

    store.refresh()  # a background compaction may have swapped segments
    stats = {
        "store": store_path,
        "store_format": f"v{store.segment_version}",
        "segments": len(store.segment_names),
        "num_docs": store.num_docs,
        "build_s": round(build_s, 2),
        "score": score,
        "batch": batch,
        "workers": workers,
        "kernel": kernel,
        "routing": bool(routing and workers > 1),
        **served,
    }
    if telemetry:
        build_stages = reg.stage_totals("ingest/")
        if build_stages:
            stats["build_stage_seconds"] = {
                name.split("/", 1)[1]: round(secs, 4)
                for name, secs in sorted(build_stages.items())
            }
        if trace_out:
            reg.write_trace(trace_out)
            print(f"[trace] {len(reg.span_events())} spans -> {trace_out}")
    print(json.dumps(stats))
    if json_out:
        with open(json_out, "w") as f:
            json.dump(stats, f, indent=2)
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=5_000)
    ap.add_argument("--vocab", type=int, default=4_096)
    ap.add_argument(
        "--method", default="auto",
        help='counting method for the build ("auto" = cost-model planner)',
    )
    ap.add_argument("--store", default=None, help="reuse/persist a store dir")
    ap.add_argument("--budget-pairs", type=int, default=1 << 20)
    ap.add_argument("--queries", type=int, default=2_000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--score", default="count", choices=["count", "pmi", "dice"])
    ap.add_argument(
        "--workers", type=int, default=0,
        help="shared-mmap worker processes (0 = in-process engine)",
    )
    ap.add_argument(
        "--clients", type=int, default=2,
        help="concurrent client threads (only with --workers >= 1)",
    )
    ap.add_argument(
        "--batch-window-ms", type=float, default=2.0,
        help="micro-batch latency budget per worker",
    )
    ap.add_argument(
        "--kernel", default="numpy", choices=["numpy", "pallas"],
        help="score-and-select backend (bit-identical results)",
    )
    ap.add_argument(
        "--routing", action="store_true",
        help="hot-term routing: hash terms to workers so per-worker LRU "
             "caches partition the vocabulary (only with --workers >= 2)",
    )
    ap.add_argument(
        "--cache-rows", type=int, default=4096,
        help="per-engine/per-worker LRU row-cache capacity",
    )
    ap.add_argument("--json", default=None, help="also write stats JSON here")
    ap.add_argument(
        "--trace-out", default=None,
        help="write a Chrome trace_event JSON of driver-side spans here "
             "(enables telemetry)",
    )
    ap.add_argument(
        "--metrics-interval", type=float, default=0.0,
        help="dump Prometheus-text metrics to stderr every S seconds; also "
             "the workers' stats-snapshot cadence (enables telemetry)",
    )
    ap.add_argument(
        "--store-format", default=None, choices=["v1", "v2"],
        help="segment format for a freshly built store: v1 raw arrays, "
             "v2 block-compressed + bloom (byte-identical queries)",
    )
    ap.add_argument(
        "--build-segments", type=int, default=1,
        help="shard the corpus into N appended segments (gives --compact "
             "something to merge)",
    )
    ap.add_argument(
        "--compact", action="store_true",
        help="merge segments in a background process while the workload "
             "runs; serving picks the swap up live via refresh()",
    )
    ap.add_argument(
        "--follow", default=None, metavar="FEED",
        help="tail this feed file (one doc per line of term IDs) into the "
             "store while serving; resumes from the manifest stream cursor",
    )
    ap.add_argument(
        "--refresh-interval-ms", type=float, default=0.0,
        help="serving workers refresh the manifest this often even with no "
             "traffic, so an idle server still sees streamed segments "
             "(0 = refresh only between micro-batches)",
    )
    ap.add_argument(
        "--max-lag-ms", type=float, default=2_000.0,
        help="visibility-lag budget for --follow: every tailed doc should "
             "be queryable within this long of arriving",
    )
    ap.add_argument(
        "--max-inflight", type=int, default=0,
        help="admission control: bound each worker's request queue; "
             "overflow is shed as typed ServerOverloaded and counted "
             "(0 = unbounded)",
    )
    ap.add_argument(
        "--deadline-ms", type=float, default=0.0,
        help="per-request deadline: the client timeout travels in the "
             "request envelope, so workers skip requests that expired in "
             "the queue (0 = the 60s client default)",
    )
    ap.add_argument(
        "--max-respawns", type=int, default=2,
        help="how many times the supervisor replaces a dead worker before "
             "routing around its slot permanently",
    )
    args = ap.parse_args()
    serve(
        args.docs,
        args.vocab,
        args.method,
        args.store,
        args.budget_pairs,
        args.queries,
        args.batch,
        args.topk,
        args.score,
        workers=args.workers,
        clients=args.clients,
        batch_window_ms=args.batch_window_ms,
        kernel=args.kernel,
        routing=args.routing,
        cache_rows=args.cache_rows,
        json_out=args.json,
        trace_out=args.trace_out,
        metrics_interval=args.metrics_interval,
        store_format=args.store_format,
        build_segments=args.build_segments,
        compact=args.compact,
        follow=args.follow,
        refresh_interval_ms=args.refresh_interval_ms,
        max_lag_ms=args.max_lag_ms,
        max_inflight=args.max_inflight,
        max_respawns=args.max_respawns,
        deadline_ms=args.deadline_ms,
    )


if __name__ == "__main__":
    main()
