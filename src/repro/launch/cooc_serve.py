"""Co-occurrence query serving driver (the statistic's serving side).

    PYTHONPATH=src python -m repro.launch.cooc_serve --docs 5000 --vocab 4096 \
        --method auto --queries 2000 --batch 64 --topk 10 --score pmi

Builds (or opens, with --store) a persistent co-occurrence store, then
replays a Zipf-skewed query workload — the access pattern of real serving
traffic, where popular terms dominate — through the batched QueryEngine.
Reports build throughput plus per-batch latency percentiles and QPS for
both top-k and pair-count queries, mirroring launch/serve.py's role for the
LM stack.
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from repro.core.cooc import count_to_store
from repro.data.corpus import _zipf_probs, synthetic_zipf_collection
from repro.store import QueryEngine, Store


def _percentiles(lat_s: list[float]) -> dict:
    a = np.asarray(lat_s) * 1e3
    return {
        "p50_ms": round(float(np.percentile(a, 50)), 3),
        "p95_ms": round(float(np.percentile(a, 95)), 3),
        "p99_ms": round(float(np.percentile(a, 99)), 3),
    }


def serve(
    docs: int = 5_000,
    vocab: int = 4_096,
    method: str = "auto",
    store_path: str | None = None,
    budget_pairs: int = 1 << 20,
    queries: int = 2_000,
    batch: int = 64,
    topk: int = 10,
    score: str = "count",
    seed: int = 0,
) -> dict:
    # ------------------------------------------------------------ build/open
    if store_path and Store.exists(store_path):
        store = Store.open(store_path)
        build_s = 0.0
    else:
        store_path = store_path or os.path.join(
            tempfile.mkdtemp(prefix="cooc_store_"), "store"
        )
        c = synthetic_zipf_collection(docs, vocab=vocab, mean_len=40, seed=seed)
        t0 = time.perf_counter()
        store, seg = count_to_store(
            method, c, store_path, memory_budget_pairs=budget_pairs
        )
        build_s = time.perf_counter() - t0
        print(
            f"[build] {seg.nnz} pairs from {docs} docs via "
            f"{seg.meta.get('source', method)} in {build_s:.2f}s "
            f"({docs / build_s * 3600:.0f} docs/hour) -> {store_path}"
        )

    engine = QueryEngine(store)
    V = store.vocab_size
    rng = np.random.default_rng(seed + 1)
    # Zipf-skewed term popularity: hot terms get most of the traffic
    probs = _zipf_probs(V, 1.0)
    df_order = np.argsort(-store.df(), kind="stable")

    def draw_terms(n):
        return df_order[rng.choice(V, size=n, p=probs)]

    # ------------------------------------------------------------- top-k
    n_batches = max(queries // batch, 1)
    # warm up the jit cache before timing
    engine.topk(draw_terms(batch), k=topk, score=score)
    lat = []
    for _ in range(n_batches):
        terms = draw_terms(batch)
        t0 = time.perf_counter()
        engine.topk(terms, k=topk, score=score)
        lat.append(time.perf_counter() - t0)
    topk_stats = _percentiles(lat)
    topk_qps = round(n_batches * batch / sum(lat))

    # -------------------------------------------------------- pair counts
    lat_pc = []
    for _ in range(n_batches):
        pairs = np.stack([draw_terms(batch), draw_terms(batch)], axis=1)
        t0 = time.perf_counter()
        engine.pair_counts(pairs)
        lat_pc.append(time.perf_counter() - t0)
    pair_stats = _percentiles(lat_pc)
    pair_qps = round(n_batches * batch / sum(lat_pc))

    stats = {
        "store": store_path,
        "segments": len(store.segment_names),
        "num_docs": store.num_docs,
        "build_s": round(build_s, 2),
        "score": score,
        "batch": batch,
        "topk_qps": topk_qps,
        **{f"topk_{k}": v for k, v in topk_stats.items()},
        "pair_qps": pair_qps,
        **{f"pair_{k}": v for k, v in pair_stats.items()},
        "row_cache": dict(engine.stats),
    }
    print(stats)
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=5_000)
    ap.add_argument("--vocab", type=int, default=4_096)
    ap.add_argument(
        "--method", default="auto",
        help='counting method for the build ("auto" = cost-model planner)',
    )
    ap.add_argument("--store", default=None, help="reuse/persist a store dir")
    ap.add_argument("--budget-pairs", type=int, default=1 << 20)
    ap.add_argument("--queries", type=int, default=2_000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--score", default="count", choices=["count", "pmi", "dice"])
    args = ap.parse_args()
    serve(
        args.docs,
        args.vocab,
        args.method,
        args.store,
        args.budget_pairs,
        args.queries,
        args.batch,
        args.topk,
        args.score,
    )


if __name__ == "__main__":
    main()
