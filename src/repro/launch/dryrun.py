import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape) cell on the
production meshes and extract memory / cost / collective evidence.

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmoe-1b-7b \
        --shape train_4k --mesh single          # 16×16 (256 chips)
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi   # 2×16×16

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init) — hence its position before the docstring
imports. Results append as JSON lines to --out (default
experiments/dryrun.jsonl)."""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.runtime.sharding import set_mesh_compat as _set_mesh  # noqa: E402


def _compile(cell, mesh):
    # set_mesh (not just `with mesh`) so in-model with_sharding_constraint
    # (maybe_shard) sees the abstract mesh during tracing
    with _set_mesh(mesh):
        jitted = jax.jit(cell.fn, donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        return lowered.compile()


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_path: str, extra=None, cell_kw=None) -> dict:
    cell_kw = cell_kw or {}
    from repro.configs import get_spec
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
    }
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh, **cell_kw)
        if extra:
            rec.update(extra)
        t_lower = time.time()
        compiled = _compile(cell, mesh)
        t_compile = time.time()
        mem = rl.memory_stats(compiled)
        roof = rl.analyze(compiled, n_chips, cell.model_flops)
        if get_spec(arch).family == "lm":
            # XLA counts the layer-scan body ONCE → extrapolate exact costs
            # from unrolled 1-layer and 2-layer compiles (homogeneous stack):
            # cost(L) = cost(1) + (L-1)·(cost(2) − cost(1))
            L = get_spec(arch).model.n_layers
            r1 = rl.analyze(
                _compile(build_cell(arch, shape_name, mesh, n_layers=1, unroll=True, **cell_kw), mesh),
                n_chips, cell.model_flops,
            )
            r2 = rl.analyze(
                _compile(build_cell(arch, shape_name, mesh, n_layers=2, unroll=True, **cell_kw), mesh),
                n_chips, cell.model_flops,
            )
            lerp = lambda a, b: max(a + (L - 1) * (b - a), a)
            roof = rl.Roofline(
                flops=lerp(r1.flops, r2.flops),
                bytes_accessed=lerp(r1.bytes_accessed, r2.bytes_accessed),
                coll_bytes=lerp(r1.coll_bytes, r2.coll_bytes),
                coll_breakdown={
                    k: lerp(r1.coll_breakdown[k], r2.coll_breakdown[k])
                    for k in r1.coll_breakdown
                },
                n_chips=n_chips,
                model_flops=cell.model_flops,
                hbm_resident_bytes=roof.hbm_resident_bytes,
            )
            rec["layer_extrapolated"] = True
        from repro.launch.specs import sharded_arg_bytes

        args_pc = sharded_arg_bytes(cell.args, mesh)
        act_pc = cell.act_bytes / n_chips
        rec["analytic"] = {
            "args_gb_per_chip": round(args_pc / 2**30, 3),
            "act_gb_per_chip": round(act_pc / 2**30, 3),
            "fits_16gb": bool((args_pc + act_pc) < 16 * 2**30),
        }
        rec.update(
            kind=cell.kind,
            notes=cell.notes,
            ok=True,
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            memory=mem,
            roofline=roof.to_dict(),
        )
        print(
            f"[OK] {arch}/{shape_name} mesh={rec['mesh']} "
            f"hbm={mem.get('total_hbm_bytes', 0)/2**30:.2f}GiB "
            f"t_comp={roof.t_compute*1e3:.2f}ms t_mem={roof.t_memory*1e3:.2f}ms "
            f"t_coll={roof.t_collective*1e3:.2f}ms bound={roof.bottleneck} "
            f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)"
        )
    except Exception as e:
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        print(f"[FAIL] {arch}/{shape_name} mesh={rec['mesh']}: {rec['error']}")
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    from repro.launch.specs import all_cells

    cells = all_cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            key = (arch, shape, "2x16x16" if mp else "16x16")
            if key in done:
                continue
            rec = run_cell(arch, shape, mp, args.out)
            failures += 0 if rec.get("ok") else 1
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
