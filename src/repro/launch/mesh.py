"""Production mesh: 16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import; tests and
benchmarks see exactly one device)."""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for subprocess tests (8 placeholder devices)."""
    n = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
