"""Continuous-ingest driver: tail a feed into a store, bounded lag.

    PYTHONPATH=src python -m repro.launch.cooc_stream \
        --feed /tmp/feed.txt --store /tmp/store --vocab 4096 \
        --max-lag-ms 2000 --seal-docs 512 --compact --idle-timeout-s 5

Runs a :class:`repro.stream.StreamIngestor` against ``--feed`` (one
document per line of space-separated term IDs; see repro.stream.source):
documents are buffered and sealed into micro-segments so each is queryable
within the ``--max-lag-ms`` visibility budget, committed through the same
flock'd manifest path every other writer uses. The stream cursor lives in
the store manifest and advances atomically with each seal, so re-running
this driver after *any* crash (including SIGKILL mid-seal) resumes
exactly-once — no document is ever counted twice or dropped.

``--compact`` runs the tier-pressure :class:`repro.store.CompactionDaemon`
alongside, folding the micro-segment tail back down (fanout ``--fanout``)
while ingest continues; the final summary reports its merge count.

``--gen-docs N`` spawns a paced synthetic producer thread appending N
Zipf documents to the feed at ``--gen-rate`` docs/s (0 = all at once) —
a self-contained way to exercise the tailer without an external producer;
the CI smoke job and benchmarks/streaming_bench.py drive it this way.

The run summary (docs/seals committed, cursor position, visibility-lag
and seal-cost percentiles, compaction merges, final segment count) prints
as JSON; ``--json`` also writes it to a file. ``--trace-out`` /
``--metrics-interval`` enable ``stream/*`` span + counter telemetry
exactly like the other launch drivers.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

from repro import obs
from repro.store import CompactionDaemon, CompactionPolicy, Store
from repro.stream import FileTailSource, StreamConfig, StreamIngestor, write_feed


def _producer(feed: str, docs: int, vocab: int, rate: float, seed: int,
              mean_len: float) -> threading.Thread:
    """Append ``docs`` synthetic Zipf documents to ``feed``, paced at
    ``rate`` docs/s (0 = one burst), from a daemon thread."""
    from repro.data.corpus import synthetic_zipf_collection

    c = synthetic_zipf_collection(docs, vocab=vocab, mean_len=mean_len,
                                  seed=seed)

    def run():
        if rate <= 0:
            write_feed(feed, (c.doc(d) for d in range(c.num_docs)))
            return
        t0 = time.monotonic()
        written = 0
        while written < c.num_docs:
            # how many docs the pace says should exist by now
            due = min(int((time.monotonic() - t0) * rate) + 1, c.num_docs)
            if due > written:
                write_feed(feed, (c.doc(d) for d in range(written, due)))
                written = due
            else:
                time.sleep(min(0.01, 1.0 / rate))

    t = threading.Thread(target=run, name="stream-producer", daemon=True)
    t.start()
    return t


def stream(
    feed: str,
    store_path: str,
    *,
    vocab: int | None = None,
    method: str = "list-scan",
    seal_docs: int = 512,
    max_lag_ms: float = 2_000.0,
    max_docs: int | None = None,
    idle_timeout_s: float | None = None,
    budget_pairs: int = 1 << 20,
    source_id: str | None = None,
    compact: bool = False,
    fanout: int = 4,
    gen_docs: int = 0,
    gen_rate: float = 0.0,
    gen_mean_len: float = 12.0,
    seed: int = 0,
    json_out: str | None = None,
    trace_out: str | None = None,
    metrics_interval: float = 0.0,
) -> dict:
    """Tail ``feed`` into ``store_path`` until done (max_docs reached, or
    idle for idle_timeout_s); returns the run summary dict."""
    telemetry = bool(trace_out) or metrics_interval > 0
    reg = obs.configure(enabled=True) if telemetry else obs.get_registry()

    if Store.exists(store_path):
        store = Store.open(store_path, registry=reg)
    else:
        if vocab is None:
            raise SystemExit("--vocab is required to create a new store")
        store = Store.create(store_path, vocab, registry=reg)

    producer = None
    if gen_docs > 0:
        producer = _producer(feed, gen_docs, store.vocab_size, gen_rate,
                             seed, gen_mean_len)

    ingestor = StreamIngestor(
        store,
        FileTailSource(feed),
        StreamConfig(
            method=method,
            seal_docs=seal_docs,
            max_visibility_lag_ms=max_lag_ms,
            memory_budget_pairs=budget_pairs,
            max_docs=max_docs,
            idle_timeout_s=idle_timeout_s,
        ),
        source_id=source_id or os.path.abspath(feed),
        registry=reg,
    )

    daemon = None
    if compact:
        daemon = CompactionDaemon(
            store, CompactionPolicy(fanout=fanout), registry=reg
        ).start()

    stop_dump = threading.Event()
    dumper = None
    if metrics_interval > 0:
        def _dump():
            while not stop_dump.wait(metrics_interval):
                print(reg.prometheus_text(), file=sys.stderr, flush=True)
        dumper = threading.Thread(target=_dump, daemon=True)
        dumper.start()

    t0 = time.perf_counter()
    try:
        summary = ingestor.run()
    finally:
        stop_dump.set()
        if dumper is not None:
            dumper.join(timeout=5)
        if daemon is not None:
            daemon.stop()
    wall_s = time.perf_counter() - t0
    if producer is not None:
        producer.join(timeout=30)

    store.refresh()
    summary.update(
        store=store_path,
        wall_s=round(wall_s, 3),
        docs_per_hour=round(summary["docs_this_run"] / wall_s * 3600)
        if wall_s > 0 else 0,
        segments=len(store.segment_names),
        num_docs=store.num_docs,
    )
    if daemon is not None:
        summary["compaction"] = daemon.summary()
    if telemetry and trace_out:
        reg.write_trace(trace_out)
        print(f"[trace] {len(reg.span_events())} spans -> {trace_out}")
    print(json.dumps(summary))
    if json_out:
        with open(json_out, "w") as f:
            json.dump(summary, f, indent=2)
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--feed", required=True,
                    help="feed file to tail (one doc per line of term IDs)")
    ap.add_argument("--store", required=True, help="store dir (created if new)")
    ap.add_argument("--vocab", type=int, default=None,
                    help="vocab size when creating a new store")
    ap.add_argument("--method", default="list-scan",
                    help="counting method for each seal")
    ap.add_argument("--seal-docs", type=int, default=512,
                    help="seal a micro-segment after this many docs")
    ap.add_argument("--max-lag-ms", type=float, default=2_000.0,
                    help="visibility-lag budget: docs should be queryable "
                         "within this long of arriving")
    ap.add_argument("--max-docs", type=int, default=None,
                    help="stop after committing this many docs")
    ap.add_argument("--idle-timeout-s", type=float, default=None,
                    help="stop after the feed is idle this long")
    ap.add_argument("--budget-pairs", type=int, default=1 << 20)
    ap.add_argument("--source-id", default=None,
                    help="cursor key in the manifest (default: feed abspath)")
    ap.add_argument("--compact", action="store_true",
                    help="run the tier-pressure compaction daemon alongside")
    ap.add_argument("--fanout", type=int, default=4,
                    help="compaction tier fanout (with --compact)")
    ap.add_argument("--gen-docs", type=int, default=0,
                    help="spawn a producer thread appending this many "
                         "synthetic Zipf docs to the feed")
    ap.add_argument("--gen-rate", type=float, default=0.0,
                    help="producer pace in docs/s (0 = one burst)")
    ap.add_argument("--gen-mean-len", type=float, default=12.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="also write summary JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome trace_event JSON (enables telemetry)")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="dump Prometheus-text metrics to stderr every S "
                         "seconds (enables telemetry)")
    args = ap.parse_args()
    stream(
        args.feed,
        args.store,
        vocab=args.vocab,
        method=args.method,
        seal_docs=args.seal_docs,
        max_lag_ms=args.max_lag_ms,
        max_docs=args.max_docs,
        idle_timeout_s=args.idle_timeout_s,
        budget_pairs=args.budget_pairs,
        source_id=args.source_id,
        compact=args.compact,
        fanout=args.fanout,
        gen_docs=args.gen_docs,
        gen_rate=args.gen_rate,
        gen_mean_len=args.gen_mean_len,
        seed=args.seed,
        json_out=args.json,
        trace_out=args.trace_out,
        metrics_interval=args.metrics_interval,
    )


if __name__ == "__main__":
    main()
