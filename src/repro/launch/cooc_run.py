"""End-to-end co-occurrence driver (the paper's pipeline, production shape).

    PYTHONPATH=src python -m repro.launch.cooc_run --docs 20000 --vocab 50000 \
        --method freq-split --out /tmp/cooc_out

Pipeline: synthetic/loaded corpus → preprocess (dedup/sort, df-descending
IDs) → document shards as independent work units (WorkTracker: leases,
straggler re-enqueue, idempotent completion) → per-shard exact counting →
additive merge → paper-format output + Table-1 stats.

Checkpoint/restart: the accumulator + tracker state are checkpointed every
--ckpt-every completed shards; `--resume` continues a killed run without
recounting finished shards.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.cooc import count
from repro.core.stats import top_k_pairs
from repro.core.types import DenseSink, FileSink, StatsSink
from repro.data.corpus import collection_stats, synthetic_zipf_collection
from repro.data.preprocess import remap_df_descending, shard_documents
from repro.runtime.fault import WorkTracker


def run(
    num_docs: int = 20_000,
    vocab: int = 50_000,
    method: str = "freq-split",
    num_shards: int = 16,
    out_dir: str = "/tmp/cooc_out",
    ckpt_every: int = 4,
    resume: bool = False,
    dense_vocab_cap: int = 4096,
) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    c = synthetic_zipf_collection(num_docs, vocab=vocab, mean_len=60, seed=0)
    cd, _ = remap_df_descending(c)
    stats = collection_stats(cd)
    print(f"[corpus] {stats}")

    # Small vocabularies merge exactly via a dense accumulator; larger runs
    # stream per-shard StatsSink aggregates (exactness per shard, additive).
    dense = cd.vocab_size <= dense_vocab_cap

    shards = shard_documents(cd, num_shards)
    tracker = WorkTracker([(s,) for s in range(num_shards)])
    acc = np.zeros((cd.vocab_size, cd.vocab_size), dtype=np.int64) if dense else None
    agg = {"distinct_pairs": 0, "total_count": 0, "output_bytes": 0}

    ckpt_dir = os.path.join(out_dir, "ckpt")
    step0 = latest_step(ckpt_dir) if resume else None
    if step0 is not None:
        like = {"acc": acc} if dense else {"acc": np.zeros(1)}
        restored, extra = restore_checkpoint(ckpt_dir, step0, like)
        if dense:
            acc = np.array(restored["acc"])  # writable copy (jax arrays are RO)
        agg = extra["agg"]
        tracker = WorkTracker.from_state(extra["tracker"])
        print(f"[resume] from step {step0}: {len(tracker.done)} shards done")

    done_since_ckpt = 0
    while not tracker.finished:
        unit = tracker.claim("worker0", time.monotonic())
        if unit is None:
            tracker.expire(time.monotonic())
            continue
        (s,) = unit
        shard = shards[s]
        if dense:
            sink = DenseSink(cd.vocab_size)
        else:
            sink = StatsSink()
        kwargs = dict(head=min(1024, cd.vocab_size), use_kernel=False) if method == "freq-split" else {}
        count(method, shard, sink, **kwargs)
        if tracker.complete(unit, "worker0"):
            if dense:
                acc += sink.mat
            else:
                agg["distinct_pairs"] += sink.distinct_pairs  # upper bound across shards
                agg["total_count"] += sink.total_count
                agg["output_bytes"] += sink.output_bytes
            done_since_ckpt += 1
        if done_since_ckpt >= ckpt_every:
            save_checkpoint(
                ckpt_dir, len(tracker.done),
                {"acc": acc if dense else np.zeros(1)},
                extra={"agg": agg, "tracker": tracker.state()},
            )
            done_since_ckpt = 0
            print(f"[ckpt] {len(tracker.done)}/{num_shards} shards")

    elapsed = time.time() - t0
    result = {
        "num_docs": num_docs,
        "method": method,
        "elapsed_s": round(elapsed, 2),
        "docs_per_hour": round(num_docs / elapsed * 3600),
    }
    if dense:
        upper = np.triu(acc, 1)
        result["distinct_pairs"] = int((upper > 0).sum())
        result["total_count"] = int(upper.sum())
        result["top_pairs"] = top_k_pairs(upper, 5)
        # paper-format output file
        sink = FileSink(os.path.join(out_dir, "pairs.bin"))
        for i in range(cd.vocab_size):
            nz = np.nonzero(upper[i])[0]
            if len(nz):
                sink.emit_row(i, nz, upper[i][nz])
        sink.close()
    else:
        result["total_count"] = agg["total_count"]
    with open(os.path.join(out_dir, "result.json"), "w") as f:
        json.dump(result, f, indent=2)
    print(f"[done] {result}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=50_000)
    ap.add_argument("--method", default="freq-split")
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--out", default="/tmp/cooc_out")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    run(args.docs, args.vocab, args.method, args.shards, args.out, resume=args.resume)


if __name__ == "__main__":
    main()
