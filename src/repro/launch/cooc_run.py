"""End-to-end co-occurrence driver (the paper's pipeline, production shape).

    PYTHONPATH=src python -m repro.launch.cooc_run --docs 20000 --vocab 50000 \
        --method auto --out /tmp/cooc_out

Pipeline: synthetic/loaded corpus → preprocess (dedup/sort, df-descending
IDs) → CountJob → Planner (cost-model method selection with ``--method
auto``, sink policy) → PlanExecutor (document shards as independent work
units behind a WorkTracker: leases, straggler re-enqueue, idempotent
completion; per-shard exact counting; additive merge) → paper-format output
+ Table-1 stats.

Every run is **exact**, whatever the vocabulary size: small vocabularies
merge through a dense accumulator, larger ones spill per-shard sorted runs
and k-way-merge them within the memory budget (the old approximate
"StatsSink upper bound across shards" fallback is gone — the result dict's
``"exact"`` field records the guarantee).

Checkpoint/restart: tracker + accumulator state are checkpointed every
--ckpt-every completed shards (spill runs persist on disk per shard);
`--resume` continues a killed run without recounting finished shards.

Telemetry (off by default; see docs/observability.md): ``--trace-out FILE``
enables the obs registry and writes the run's span tree as a Chrome
``trace_event`` JSON (chrome://tracing / Perfetto) — with ``--output store``
the trace holds all five ingest stages (count, spill, bucket_merge,
segment_write, refresh). ``--metrics-interval S`` dumps a Prometheus-text
metrics snapshot to stderr every S seconds while the run executes. Either
flag also adds a per-stage ``stage_seconds`` breakdown to result.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading

from repro import obs
from repro.core.plan import CountJob, Planner
from repro.data.corpus import collection_stats, synthetic_zipf_collection
from repro.data.preprocess import remap_df_descending


def run(
    num_docs: int = 20_000,
    vocab: int = 50_000,
    method: str = "auto",
    num_shards: int = 16,
    out_dir: str = "/tmp/cooc_out",
    ckpt_every: int = 4,
    resume: bool = False,
    dense_vocab_cap: int = 4096,
    memory_budget_pairs: int = 4 << 20,
    output: str = "pairs-file",
    trace_out: str | None = None,
    metrics_interval: float = 0.0,
    ingest_workers: int = 1,
) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    telemetry = bool(trace_out) or metrics_interval > 0
    reg = obs.configure(enabled=True) if telemetry else obs.get_registry()
    c = synthetic_zipf_collection(num_docs, vocab=vocab, mean_len=60, seed=0)
    cd, _ = remap_df_descending(c)
    print(f"[corpus] {collection_stats(cd)}")

    out_path = os.path.join(
        out_dir, "store" if output == "store" else "pairs.bin"
    )
    job = CountJob(
        collection=cd,
        output=output,
        method=method,
        out_path=out_path,
        num_shards=num_shards,
        dense_vocab_cap=dense_vocab_cap,
        memory_budget_pairs=memory_budget_pairs,
        df_descending=True,   # remap_df_descending above
        use_kernel=False,     # host driver: jnp oracle paths
    )
    plan = Planner().plan(job)
    print(
        f"[plan] method={plan.method} sink={plan.sink_policy} "
        f"exact={plan.exact} ranking={plan.describe()['ranking']}"
    )

    stop_metrics = threading.Event()

    def _dump_metrics():
        while not stop_metrics.wait(metrics_interval):
            print(reg.prometheus_text(), file=sys.stderr, flush=True)

    dumper = None
    if metrics_interval > 0:
        dumper = threading.Thread(target=_dump_metrics, daemon=True)
        dumper.start()
    try:
        if ingest_workers > 1:
            # spawned spill-shard workers behind a shared lease tracker;
            # byte-identical output to the serial path (docs/architecture.md)
            from repro.core.plan import ParallelExecutor

            res = ParallelExecutor(
                num_workers=ingest_workers, verbose=True
            ).execute(plan, out_dir=out_dir, resume=resume)
        else:
            res = plan.execute(
                out_dir=out_dir, ckpt_every=ckpt_every, resume=resume
            )
    finally:
        stop_metrics.set()
        if dumper is not None:
            dumper.join(timeout=5)

    result = res.summary
    if telemetry:
        result["stage_seconds"] = {
            name.split("/", 1)[1]: round(secs, 4)
            for name, secs in sorted(reg.stage_totals("ingest/").items())
        }
        if trace_out:
            reg.write_trace(trace_out)
            print(f"[trace] {len(reg.span_events())} spans -> {trace_out}")
    with open(os.path.join(out_dir, "result.json"), "w") as f:
        json.dump(result, f, indent=2)
    print(f"[done] {result}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=20_000)
    ap.add_argument("--vocab", type=int, default=50_000)
    ap.add_argument("--method", default="auto")
    ap.add_argument("--shards", type=int, default=16)
    ap.add_argument("--out", default="/tmp/cooc_out")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--budget-pairs", type=int, default=4 << 20)
    ap.add_argument(
        "--output", default="pairs-file", choices=["pairs-file", "store"],
        help="paper-format pairs file, or a queryable CSR store "
             "(store runs exercise all five ingest stages)",
    )
    ap.add_argument(
        "--trace-out", default=None,
        help="write a Chrome trace_event JSON of the run's spans here "
             "(enables telemetry)",
    )
    ap.add_argument(
        "--metrics-interval", type=float, default=0.0,
        help="dump Prometheus-text metrics to stderr every S seconds "
             "(enables telemetry)",
    )
    ap.add_argument(
        "--ingest-workers", type=int, default=1,
        help="count spill shards across N spawned worker processes "
             "(byte-identical to serial; pays off once per-shard counting "
             "dominates spawn cost — see docs/methods.md)",
    )
    args = ap.parse_args()
    run(
        args.docs,
        args.vocab,
        args.method,
        args.shards,
        args.out,
        resume=args.resume,
        memory_budget_pairs=args.budget_pairs,
        output=args.output,
        trace_out=args.trace_out,
        metrics_interval=args.metrics_interval,
        ingest_workers=args.ingest_workers,
    )


if __name__ == "__main__":
    main()
