"""Generic train-step factories + optimizer-state sharding derivation.

Optimizer policy: ≥50B params → Adafactor (factored second moments — the only
way params+grads+state fit 16 GB/chip at 340B/671B); smaller models → AdamW
with bf16 moments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as lm_mod
from repro.optim import adamw, adafactor, apply_updates, clip_by_global_norm, warmup_cosine


def pick_optimizer(num_params: int):
    lr = warmup_cosine(3e-4, 2000, 100_000)
    if num_params >= 50e9:
        return adafactor(lr), "adafactor"
    return adamw(lr, moment_dtype=jnp.bfloat16), "adamw"


def opt_state_specs(opt_name: str, param_specs, shapes_tree):
    """PartitionSpecs for the optimizer state, derived from param specs."""
    if opt_name == "adamw":
        return {"m": param_specs, "v": param_specs, "count": P()}
    if opt_name == "sgd":
        return {"mu": param_specs, "count": P()}
    if opt_name == "adafactor":
        def leaf(spec, shape):
            # PartitionSpec normalizes trailing Nones — pad back to ndim
            parts = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
            if len(shape) >= 2:
                return {"r": P(*parts[:-1]), "c": P(*parts[:-2], parts[-1])}
            return {"v": spec}

        is_shape = lambda x: isinstance(x, tuple) and all(isinstance(i, int) for i in x)
        stats = jax.tree.map(
            leaf, param_specs, shapes_tree,
            is_leaf=lambda x: isinstance(x, P),
        )
        return {"stats": stats, "count": P()}
    raise ValueError(opt_name)


def make_lm_train_step(cfg, opt):
    def step(state, batch):
        params, opt_state = state

        def lf(p):
            return lm_mod.loss_fn(p, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), {**metrics, "loss": loss, "grad_norm": gnorm}

    return step


def make_gnn_full_graph_step(cfg, opt):
    def step(state, feats, edge_index, labels, mask):
        params, opt_state = state
        loss, grads = jax.value_and_grad(gnn_mod.loss_full_graph)(
            params, feats, edge_index, labels, mask, cfg
        )
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return step


def make_gnn_sampled_step(cfg, opt):
    def step(state, seed_feats, hop1, hop2, labels):
        params, opt_state = state
        loss, grads = jax.value_and_grad(gnn_mod.loss_sampled)(
            params, seed_feats, hop1, hop2, labels, cfg
        )
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return step


def make_gnn_batched_graphs_step(cfg, opt):
    def step(state, feats, edge_index, graph_ids, labels, n_graphs):
        params, opt_state = state
        loss, grads = jax.value_and_grad(gnn_mod.loss_batched_graphs)(
            params, feats, edge_index, graph_ids, labels, cfg, n_graphs
        )
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return step


def make_recsys_train_step(cfg, opt):
    def step(state, batch):
        params, opt_state = state
        loss, grads = jax.value_and_grad(recsys_mod.loss_fn)(params, batch, cfg)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return (params, opt_state), {"loss": loss, "grad_norm": gnorm}

    return step
