"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Terms (per chip, seconds):
    compute    = HLO_FLOPs / PEAK_FLOPS
    memory     = HLO_bytes / HBM_BW
    collective = Σ collective-operand-bytes / ICI_BW

``cost_analysis`` provides FLOPs/bytes of the partitioned (per-device)
module. Collective bytes are NOT in cost_analysis — we parse the optimized
HLO text and sum operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (per-device shapes, so the result is
bytes crossing this chip's links)."""

from __future__ import annotations

import dataclasses
import re

import numpy as np

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %x = bf16[16,512,128]{2,1,0} all-gather(...)   (also async "-start")
_COLL_ALT = "|".join(_COLLECTIVES)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\s(" + _COLL_ALT + r")(?:-start)?[\s(]"
)
# tuple-result collectives:  = (bf16[...], bf16[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*(" + _COLL_ALT + r")(?:-start)?[\s(]"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum of result-shape bytes per collective kind (per device)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-start" in line and "-done" not in line:
            pass  # count the -start (has the shapes); -done skipped below
        if "-done" in line:
            continue
        m = _TUPLE_RE.search(line)  # tuple-result form FIRST (N operands)
        if m:
            shapes, kind = m.groups()
            for dtype, dims in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: dict
    n_chips: int
    model_flops: float
    hbm_resident_bytes: float = 0.0  # args+outputs+temps from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        """Upper bound: raw HLO operand bytes (CPU backend has no TPU-style
        fusion, so every elementwise op's operands count — pessimistic)."""
        return self.bytes_accessed / HBM_BW

    @property
    def t_memory_fused(self) -> float:
        """Fusion-adjusted estimate: on TPU each HBM-resident byte is
        streamed O(1) times per step (read + write ≈ 2×). Lower bound."""
        return 2.0 * self.hbm_resident_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory_fused,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time = max of the three overlappable terms (memory
        uses the fused estimate — the raw CPU-HLO bytes are reported too)."""
        return max(self.t_compute, self.t_memory_fused, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total compiled FLOPs (remat/dispatch waste)."""
        total = self.flops * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on model-FLOPs utilization implied by the roofline."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / self.n_chips / PEAK_FLOPS) / self.t_bound

    def to_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "coll_bytes_per_chip": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_memory_fused_s": self.t_memory_fused,
            "t_collective_s": self.t_collective,
            "t_bound_s": self.t_bound,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def analyze(compiled, n_chips: int, model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = memory_stats(compiled)
    return Roofline(
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        n_chips=n_chips,
        model_flops=model_flops,
        hbm_resident_bytes=float(mem.get("total_hbm_bytes", 0)),
    )


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, k):
            out[k] = int(getattr(ma, k))
    if "argument_size_in_bytes" in out:
        out["total_hbm_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out
