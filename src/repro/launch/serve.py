"""Batched LM serving driver: continuous-batching-style prefill + decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-110b \
        --batch 4 --prompt-len 32 --gen 16

Uses the arch's reduced (smoke) config on CPU; the full configs are served
through the same code path on the production mesh (launch/specs.py lowers
exactly these functions for the prefill/decode dry-run cells).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_spec
from repro.models import transformer as T


def serve(arch: str, batch: int, prompt_len: int, gen: int, seed: int = 0):
    spec = get_spec(arch)
    cfg = spec.smoke()
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    max_seq = prompt_len + gen
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(batch, prompt_len)).astype(np.int32)
    )

    prefill = jax.jit(lambda p, t: T.prefill(p, t, cfg))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    t0 = time.time()
    logits, pre_cache = prefill(params, prompts)
    # place the prefill cache into the padded decode cache
    cache = T.init_cache(cfg, batch, max_seq)
    cache = jax.tree.map(
        lambda full, pre: jax.lax.dynamic_update_slice(
            full, pre.astype(full.dtype), (0,) * full.ndim
        ),
        cache,
        pre_cache,
    )
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    generated = [tokens]
    t0 = time.time()
    for step in range(gen - 1):
        logits, cache = decode(params, cache, tokens, jnp.int32(prompt_len + step))
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0

    out = np.concatenate([np.asarray(g) for g in generated], axis=1)
    stats = {
        "arch": arch,
        "batch": batch,
        "prefill_tokens_per_s": round(batch * prompt_len / max(t_prefill, 1e-9)),
        "decode_tokens_per_s": round(batch * (gen - 1) / max(t_decode, 1e-9)),
        "generated_shape": list(out.shape),
    }
    print(stats)
    return out, stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-110b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    serve(args.arch, args.batch, args.prompt_len, args.gen)


if __name__ == "__main__":
    main()
