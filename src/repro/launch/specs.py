"""Cell builder: (arch × input-shape × mesh) → the jit-able step function +
fully-sharded ShapeDtypeStruct inputs (no device allocation — the shannon/
kernels pattern). This is the single source of truth the dry-run, the
roofline analysis and the launchers all share."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, ShapeSpec, get_spec
from repro.launch import train as train_factories
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as lm_mod
from repro.runtime.sharding import (
    fsdp_axes,
    gnn_param_specs,
    lm_param_specs,
    recsys_param_specs,
)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    args: tuple
    donate: tuple = ()
    model_flops: float = 0.0   # analytic "useful" FLOPs per step (global)
    act_bytes: float = 0.0     # analytic GLOBAL activation working set
    notes: str = ""


def sharded_arg_bytes(args, mesh) -> float:
    """Per-chip bytes of all inputs, honoring each leaf's PartitionSpec
    (GSPMD pads non-divisible dims; we ignore padding — ≤1 tile)."""
    total = 0.0
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf_bytes(l):
        nonlocal total
        if not isinstance(l, jax.ShapeDtypeStruct):
            return
        ways = 1
        spec = getattr(l.sharding, "spec", None)
        if spec is not None:
            for entry in spec:
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    if ax is not None:
                        ways *= axis_size[ax]
        total += int(np.prod(l.shape)) * l.dtype.itemsize / ways

    jax.tree.map(leaf_bytes, args, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return total


def _fit_spec(shape, spec, mesh) -> P:
    """Drop sharding on dimensions the mesh extent does not divide (input
    layouts must tile exactly; GSPMD padding only applies to intermediates).
    E.g. MiniCPM's 73448-row vocab is not 16-way divisible → replicated."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        ways = int(np.prod([axis_size[a] for a in axes]))
        if dim % ways == 0:
            out.append(entry)
        else:
            out.append(None)
    return P(*out)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        tuple(shape), jnp.dtype(dtype),
        sharding=NamedSharding(mesh, _fit_spec(shape, spec, mesh)),
    )


def _attach(tree, specs, mesh):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(
            l.shape, l.dtype,
            sharding=NamedSharding(mesh, _fit_spec(l.shape, s, mesh)),
        ),
        tree,
        specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------
def _lm_params_sds(cfg, mesh):
    shapes_tree = lm_mod.param_shapes(cfg)
    specs = lm_param_specs(shapes_tree, mesh)
    params = jax.eval_shape(lambda: lm_mod.init_params(jax.random.PRNGKey(0), cfg))
    return _attach(params, specs, mesh), specs, shapes_tree


def _lm_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, *, n_layers: int | None = None,
    unroll: bool = False, overrides: dict | None = None,
) -> Cell:
    # Cost accounting: XLA counts while-loop (scan) bodies ONCE, so the
    # full-depth cell compiles the scan form (correct memory analysis, small
    # HLO), and the dry-run ALSO compiles n_layers∈{1,2} unrolled variants to
    # extrapolate exact per-layer FLOPs/bytes/collectives (homogeneous stack).
    repl = dict(overrides or {})
    repl["unroll_layers"] = unroll
    if n_layers is not None:
        repl["n_layers"] = n_layers
    cfg = dataclasses.replace(spec.model, **repl)
    dp = fsdp_axes(mesh)
    S, B = shape.sizes["seq_len"], shape.sizes["global_batch"]
    params_sds, param_specs, shapes_tree = _lm_params_sds(cfg, mesh)
    n_active = spec.model.num_active_params()  # FULL config for model_flops

    if shape.kind == "train":
        # optimizer choice must follow the FULL model size, not the L-override
        opt, opt_name = train_factories.pick_optimizer(spec.model.num_params())
        ostate = jax.eval_shape(opt.init, params_sds)
        ospecs = train_factories.opt_state_specs(opt_name, param_specs, shapes_tree)
        ostate = _attach(ostate, ospecs, mesh)
        tokens = _sds((B, S), jnp.int32, mesh, P(dp, None))
        fn = train_factories.make_lm_train_step(cfg, opt)
        act = (
            cfg.n_layers * B * S * cfg.d_model * 2      # remat carries (bf16)
            + B * S * cfg.vocab_size * 4                # logits (f32)
            + 6 * B * S * cfg.d_model * 4               # live working set
        )
        return Cell(
            spec.arch_id, shape.name, "train", fn,
            ((params_sds, ostate), {"tokens": tokens}),
            donate=(0,),
            model_flops=6.0 * n_active * B * S,
            act_bytes=act,
            notes=f"optimizer={opt_name}",
        )

    if shape.kind == "prefill":
        tokens = _sds((B, S), jnp.int32, mesh, P(dp, None))
        fn = functools.partial(lm_mod.prefill, cfg=cfg)
        cache_bytes = sum(
            int(np.prod(s_)) * 2 for s_ in lm_mod.cache_shapes(cfg, B, S).values()
        )
        act = cache_bytes + 6 * B * S * cfg.d_model * 2 + B * cfg.vocab_size * 4
        return Cell(
            spec.arch_id, shape.name, "prefill", fn, (params_sds, tokens),
            model_flops=2.0 * n_active * B * S,
            act_bytes=act,
        )

    # decode: one new token against a seq_len KV cache
    cache_shapes = lm_mod.cache_shapes(cfg, B, S)
    if B == 1:
        batch_spec, seq_axes = None, dp + ("model",)
    else:
        batch_spec, seq_axes = dp, ("model",)
    cache_specs = {
        k: P(None, batch_spec, seq_axes, *(None,) * (len(s) - 3))
        for k, s in cache_shapes.items()
    }
    cache = {
        k: _sds(s, cfg.jdtype, mesh, cache_specs[k]) for k, s in cache_shapes.items()
    }
    tokens = _sds((B, 1), jnp.int32, mesh, P(batch_spec, None))
    pos = _sds((), jnp.int32, mesh, P())
    fn = functools.partial(lm_mod.decode_step, cfg=cfg)
    # useful decode flops: param matmuls + attention over the cache
    # (per-POSITION cache dims: shapes are (L, B, S, ...) → prod over [3:],
    # then × S positions attended, × L layers via the leading dim)
    cache_elems = sum(
        s[0] * int(np.prod(s[3:])) for s in cache_shapes.values()
    )
    return Cell(
        spec.arch_id, shape.name, "decode", fn,
        (params_sds, cache, tokens, pos),
        donate=(1,),
        model_flops=2.0 * n_active * B + 2.0 * B * S * cache_elems,
        act_bytes=B * cfg.vocab_size * 4 + 4 * B * cfg.n_heads * S * 4,
    )


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------
def _gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    dp = fsdp_axes(mesh)
    sz = shape.sizes
    cfg = dataclasses.replace(
        spec.model,
        d_in=sz["d_feat"],
        n_classes=sz.get("n_classes", spec.model.n_classes),
    )
    params = jax.eval_shape(lambda: gnn_mod.init_params(jax.random.PRNGKey(0), cfg))
    specs = gnn_param_specs(gnn_mod.param_shapes(cfg), mesh)
    params_sds = _attach(params, specs, mesh)
    opt, opt_name = train_factories.pick_optimizer(0)
    ostate = _attach(
        jax.eval_shape(opt.init, params_sds),
        train_factories.opt_state_specs(opt_name, specs, gnn_mod.param_shapes(cfg)),
        mesh,
    )
    state = (params_sds, ostate)
    H = cfg.d_hidden
    dense_flops = 2 * (sz["d_feat"] * H * 2 + H * H * 2 + H * cfg.n_classes)

    if shape.kind == "full_graph":
        # pad node/edge counts to mesh multiples (isolated pad nodes with
        # mask=0 — harmless; the dry-run is shape-level anyway)
        pad = lambda n: int(-(-n // 1024) * 1024)
        N, E = pad(sz["n_nodes"]), pad(sz["n_edges"])
        feats = _sds((N, sz["d_feat"]), jnp.float32, mesh, P(dp, None))
        ei = _sds((2, E), jnp.int32, mesh, P(None, dp))
        labels = _sds((N,), jnp.int32, mesh, P(dp))
        mask = _sds((N,), jnp.float32, mesh, P(dp))
        fn = train_factories.make_gnn_full_graph_step(cfg, opt)
        return Cell(
            spec.arch_id, shape.name, "train", fn,
            (state, feats, ei, labels, mask), donate=(0,),
            model_flops=3.0 * (N * dense_flops + 2 * E * sz["d_feat"]),
        )
    if shape.kind == "sampled":
        Bn = sz["batch_nodes"]
        f1, f2 = sz["fanout"]
        F = sz["d_feat"]
        seed = _sds((Bn, F), jnp.float32, mesh, P(dp, None))
        hop1 = _sds((Bn, f1, F), jnp.float32, mesh, P(dp, None, None))
        hop2 = _sds((Bn, f1, f2, F), jnp.float32, mesh, P(dp, None, None, None))
        labels = _sds((Bn,), jnp.int32, mesh, P(dp))
        fn = train_factories.make_gnn_sampled_step(
            dataclasses.replace(cfg, sample_sizes=(f1, f2)), opt
        )
        return Cell(
            spec.arch_id, shape.name, "train", fn,
            (state, seed, hop1, hop2, labels), donate=(0,),
            model_flops=3.0 * Bn * (1 + f1 + f1 * f2) * dense_flops,
        )
    # batched_graphs (molecule)
    Bg, Nn, Ne = sz["batch"], sz["n_nodes"], sz["n_edges"]
    N, E = Bg * Nn, Bg * Ne
    feats = _sds((N, sz["d_feat"]), jnp.float32, mesh, P(dp, None))
    ei = _sds((2, E), jnp.int32, mesh, P(None, dp))
    gids = _sds((N,), jnp.int32, mesh, P(dp))
    labels = _sds((Bg,), jnp.int32, mesh, P(dp))
    base_step = train_factories.make_gnn_batched_graphs_step(cfg, opt)
    fn2 = lambda state, feats, ei, gids, labels: base_step(
        state, feats, ei, gids, labels, Bg
    )
    return Cell(
        spec.arch_id, shape.name, "train", fn2,
        (state, feats, ei, gids, labels), donate=(0,),
        model_flops=3.0 * (N * dense_flops + 2 * E * sz["d_feat"]),
    )


# --------------------------------------------------------------------------
# recsys cells
# --------------------------------------------------------------------------
def _recsys_batch_sds(cfg, B, mesh, with_label=True):
    dp = fsdp_axes(mesh)
    bspec = P(dp) if B > 1 else P(None)
    bspec2 = P(dp, None) if B > 1 else P(None, None)
    out = {}
    if cfg.kind == "dien":
        out = {
            "hist_items": _sds((B, cfg.seq_len), jnp.int32, mesh, bspec2),
            "hist_cats": _sds((B, cfg.seq_len), jnp.int32, mesh, bspec2),
            "target_item": _sds((B,), jnp.int32, mesh, bspec),
            "target_cat": _sds((B,), jnp.int32, mesh, bspec),
        }
    elif cfg.kind == "bert4rec":
        out = {
            "items": _sds((B, cfg.seq_len), jnp.int32, mesh, bspec2),
            "positions": _sds((B, cfg.n_masked), jnp.int32, mesh, bspec2),
        }
        if with_label:
            out["labels"] = _sds((B, cfg.n_masked), jnp.int32, mesh, bspec2)
    elif cfg.kind == "xdeepfm":
        ns = cfg.n_fields - cfg.n_multi_hot
        out = {
            "single_ids": _sds((B, ns), jnp.int32, mesh, bspec2),
            "multi_ids": _sds(
                (B, cfg.n_multi_hot, cfg.max_bag), jnp.int32, mesh,
                P(dp, None, None) if B > 1 else P(None, None, None),
            ),
        }
    else:  # bst
        out = {
            "hist_items": _sds((B, cfg.seq_len), jnp.int32, mesh, bspec2),
            "target_item": _sds((B,), jnp.int32, mesh, bspec),
        }
    if with_label and cfg.kind != "bert4rec":
        out["label"] = _sds((B,), jnp.int32, mesh, bspec)
    return out


def _recsys_dense_params(cfg) -> int:
    shapes = recsys_mod.param_shapes(cfg)
    total = 0
    for path, s in jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, int) for i in x)
    )[0]:
        name = str(getattr(path[-1], "key", path[-1]))
        if name not in ("item_embed", "cat_embed", "embed", "linear"):
            total += int(np.prod(s))
    return total


def _recsys_flops(cfg, B) -> float:
    dense = _recsys_dense_params(cfg)
    if cfg.kind == "dien":
        gru = 2 * (3 * (2 * cfg.embed_dim) * cfg.gru_dim + 3 * cfg.gru_dim ** 2)
        return B * (2 * cfg.seq_len * 2 * gru + 2 * dense)
    if cfg.kind in ("bert4rec", "bst"):
        S = cfg.seq_len + (1 if cfg.kind == "bst" else 0)
        # blocks applied per position + attention S² term
        e = cfg.embed_dim
        blk = cfg.n_blocks * (2 * S * (4 * e * e + 8 * e * e) + 4 * S * S * e)
        return B * (blk + 2 * dense)
    return B * 2 * dense  # xdeepfm: CIN+MLP params each used once per example


def _recsys_cell(spec: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = spec.model
    dp = fsdp_axes(mesh)
    params = jax.eval_shape(lambda: recsys_mod.init_params(jax.random.PRNGKey(0), cfg))
    specs = recsys_param_specs(recsys_mod.param_shapes(cfg), mesh)
    params_sds = _attach(params, specs, mesh)

    if shape.kind == "train":
        B = shape.sizes["batch"]
        opt, opt_name = train_factories.pick_optimizer(0)
        ostate = _attach(
            jax.eval_shape(opt.init, params_sds),
            train_factories.opt_state_specs(opt_name, specs, recsys_mod.param_shapes(cfg)),
            mesh,
        )
        batch = _recsys_batch_sds(cfg, B, mesh, with_label=True)
        fn = train_factories.make_recsys_train_step(cfg, opt)
        return Cell(
            spec.arch_id, shape.name, "train", fn,
            ((params_sds, ostate), batch), donate=(0,),
            model_flops=3.0 * _recsys_flops(cfg, B),
        )
    if shape.kind == "serve":
        B = shape.sizes["batch"]
        batch = _recsys_batch_sds(cfg, B, mesh, with_label=False)
        if cfg.kind == "bert4rec":
            fn = lambda p, b: recsys_mod.bert4rec_logits(p, b["items"], b["positions"], cfg)
        else:
            fn = functools.partial(recsys_mod.FORWARD_FNS[cfg.kind], cfg=cfg)
        return Cell(
            spec.arch_id, shape.name, "serve", fn, (params_sds, batch),
            model_flops=_recsys_flops(cfg, B),
        )
    # retrieval: one context × n_candidates
    C = shape.sizes["n_candidates"]
    batch = _recsys_batch_sds(cfg, 1, mesh, with_label=False)
    cands = _sds((C,), jnp.int32, mesh, P(dp))
    fn = functools.partial(recsys_mod.retrieval_scores, cfg=cfg)
    flops = 2.0 * C * cfg.embed_dim if cfg.kind != "xdeepfm" else _recsys_flops(cfg, C)
    return Cell(
        spec.arch_id, shape.name, "retrieval", fn, (params_sds, batch, cands),
        model_flops=flops,
    )


# --------------------------------------------------------------------------
# cooc cells (the paper's workload)
# --------------------------------------------------------------------------
def _cooc_cell(spec: ArchSpec, shape: ShapeSpec, mesh, overrides: dict | None = None) -> Cell:
    from repro.core.distributed import make_distributed_gram
    from repro.kernels import ops as kops

    cfg = dataclasses.replace(spec.model, **(overrides or {}))
    dp = fsdp_axes(mesh)
    if shape.kind == "cooc_gram":
        D, H = shape.sizes["doc_chunk"], shape.sizes["head"]
        if overrides and "doc_chunk" in overrides:
            D = cfg.doc_chunk
        B = _sds((D, H), cfg.dtype, mesh, P(dp, "model"))
        fn = make_distributed_gram(mesh, schedule=cfg.schedule)
        return Cell(
            spec.arch_id, shape.name, "cooc_gram", fn, (B,),
            model_flops=2.0 * D * H * H,
            notes=f"schedule={cfg.schedule}",
        )
    # cooc_hist: tail LIST-SCAN histogram
    L = shape.sizes["postings_chunk"]
    rows, V = shape.sizes["rows"], shape.sizes["vocab_tile"]
    ids = _sds((L,), jnp.int32, mesh, P(dp))
    seg = _sds((L,), jnp.int32, mesh, P(dp))
    fn = lambda i, s: kops.segment_hist(i, s, num_rows=rows, vocab=V, use_kernel=False)
    return Cell(
        spec.arch_id, shape.name, "cooc_hist", fn, (ids, seg),
        model_flops=2.0 * L,  # one add per posting
    )


# --------------------------------------------------------------------------
def build_cell(arch_id: str, shape_name: str, mesh, **kw) -> Cell:
    spec = get_spec(arch_id)
    shape = spec.shapes[shape_name]
    builder = {
        "lm": _lm_cell,
        "gnn": _gnn_cell,
        "recsys": _recsys_cell,
        "cooc": _cooc_cell,
    }[spec.family]
    if spec.family in ("lm", "cooc"):
        return builder(spec, shape, mesh, **kw)
    return builder(spec, shape, mesh)


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch × shape) cells + the paper's own 2 cells."""
    out = []
    from repro.configs import list_archs

    for arch in list_archs():
        spec = get_spec(arch)
        for name in spec.shapes:
            out.append((arch, name))
    return out
