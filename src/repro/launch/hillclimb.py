import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: re-lower a cell under config variants and record
the roofline deltas (hypothesis → change → before → after).

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen-decode
    PYTHONPATH=src python -m repro.launch.hillclimb --all
"""

import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

# Each experiment: (name, arch, shape, multi_pod, cell_kw, hypothesis)
EXPERIMENTS = {
    # ---- worst roofline fraction: qwen dense 110B training ---------------
    "qwen-train": [
        (
            "baseline",
            "qwen1.5-110b", "train_4k", False, {},
            "GSPMD resolves the FSDP-sharded contraction dim by all-reducing "
            "(B,S,ff)-sized partial outputs (~GBs/layer) instead of gathering "
            "the ~450MB/layer weight shards.",
        ),
        (
            "zero3-weight-gather",
            "qwen1.5-110b", "train_4k", False,
            {"overrides": {"zero3_gather_weights": True}},
            "Constrain weights to (replicated, model) at the use point → one "
            "all-gather of params_bf16/model_par per layer (ZeRO-3). Napkin: "
            "80 layers × ~0.45GB ≈ 36GB fwd (+2× bwd/remat) ≈ 100GB vs "
            "4.4TB baseline → predict ~10-40× lower t_coll.",
        ),
        (
            "zero3 + bf16-attn-scores",
            "qwen1.5-110b", "train_4k", False,
            {"overrides": {"zero3_gather_weights": True, "kv_chunk": 4096}},
            "Larger attention blocks (4096 vs 2048 chunks) quarter the "
            "number of online-softmax rescale passes; HBM bytes per score "
            "block stay VMEM-feasible per chip at d_head=128.",
        ),
    ],
    # ---- most collective-bound cell: qwen long_500k decode --------------
    "qwen-decode": [
        (
            "baseline",
            "qwen1.5-110b", "long_500k", False, {},
            "FSDP layout at decode forces per-token all-gather of the "
            "d_model-sharded weight shards (~params_bf16/model_par bytes/step).",
        ),
        (
            "dshard-activations",
            "qwen1.5-110b", "long_500k", False,
            {"overrides": {"shard_decode_dmodel": True}},
            "2D-TP serving: keep decode activations d_model-sharded over the "
            "data axes so contractions run shard-local and only (B,1,·) "
            "partials are all-reduced — predicted ≥10× collective reduction.",
        ),
    ],
    # ---- the paper's own technique: distributed Gram --------------------
    "cooc-gram": [
        (
            "allgather (paper-faithful LIST-BLOCKS)",
            "cooc-wt10g", "head_gram", False,
            {"overrides": {"schedule": "allgather"}},
            "One all-gather of the full right operand (V bytes/device) "
            "before the Gram matmul — bandwidth burst, no overlap.",
        ),
        (
            "ring (beyond-paper)",
            "cooc-wt10g", "head_gram", False,
            {"overrides": {"schedule": "ring"}},
            "Rotate V/16 column blocks via collective-permute; same total "
            "bytes but permute (not all-gather) → overlappable with the "
            "block matmul and O(V_loc) peak instead of O(V).",
        ),
        (
            "ring + half doc chunk",
            "cooc-wt10g", "head_gram", False,
            {"overrides": {"schedule": "ring", "doc_chunk": 262144}},
            "Halving the doc tile halves per-call VMEM pressure; collective "
            "bytes per processed doc unchanged — expect ~2× lower t_coll "
            "per call with the same t_coll/doc.",
        ),
    ],
    # ---- representative MoE training cell -------------------------------
    "deepseek-train": [
        (
            "baseline",
            "deepseek-v3-671b", "train_4k", False, {},
            "EP combine psum is f32 (T·d·4 bytes/layer over 'model').",
        ),
        (
            "bf16-combine",
            "deepseek-v3-671b", "train_4k", False,
            {"overrides": {"moe_combine_dtype": "bfloat16"}},
            "Cast the combined expert output to bf16 before the psum — "
            "halves MoE collective bytes; expert outputs are bf16-born, so "
            "only the k-way weighted sum loses f32 carry.",
        ),
        (
            "capacity-1.0",
            "deepseek-v3-671b", "train_4k", False,
            {"overrides": {"capacity_factor": 1.0}},
            "cf 1.25→1.0 drops ≤25% of overflow tokens; expert GEMM FLOPs "
            "and dispatch traffic shrink 20%; quality cost is the known "
            "GShard drop trade-off (not measurable in a dry-run).",
        ),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=sorted(EXPERIMENTS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/hillclimb.jsonl")
    args = ap.parse_args()
    names = sorted(EXPERIMENTS) if args.all else [args.cell]
    for name in names:
        for variant, arch, shape, mp, kw, hyp in EXPERIMENTS[name]:
            print(f"\n=== {name} / {variant} ===\nhypothesis: {hyp}")
            run_cell(
                arch, shape, mp, args.out,
                extra={"experiment": name, "variant": variant, "hypothesis": hyp},
                cell_kw=kw,
            )


if __name__ == "__main__":
    main()
