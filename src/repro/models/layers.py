"""Shared neural layers, pure-functional JAX.

Conventions:
  * params are plain dicts of jnp arrays; every layer fn is
    ``fn(params, x, cfg) -> y`` with no global state;
  * activations run in cfg.act_dtype (bf16 by default), softmax / norms /
    losses accumulate in f32;
  * attention is chunked (online-softmax / flash-style) — never materializes
    the full (S × S) score matrix, which is what makes 32k prefill and 4k
    training shapes fit VMEM/HBM budgets at scale.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------- norms
def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * scale


# ----------------------------------------------------------------- rope
def rope_freqs(dim: int, theta: float = 10_000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float64) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n_heads, dim) or (..., S, dim); positions: (..., S)."""
    dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dim, theta), dtype=jnp.float32)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, dim/2)
    if x.ndim - angles.ndim == 2:  # head axis present in x
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------- blocked attn
def _expand_kv(x: jax.Array, H: int) -> jax.Array:
    """(B, S, K, D) → (B, S, H, D): repeat each kv head G = H/K times so the
    head axis is H everywhere (q head h reads kv head h // G). Keeps the head
    dimension cleanly shardable over "model" even when K < mesh extent."""
    B, S, K, D = x.shape
    if K == H:
        return x
    return jnp.repeat(x, H // K, axis=2)


def blocked_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Sk, K, Dh)
    v: jax.Array,  # (B, Sk, K, Dv)
    *,
    causal: bool,
    q_chunk: int = 2048,
    kv_chunk: int = 2048,
    scale: float | None = None,
) -> jax.Array:
    """Flash-style blocked attention with STATIC python loops.

    Static loops (vs lax.scan) because (a) fully-masked causal blocks are
    skipped at trace time — the compiled FLOPs are the true ~S²/2 causal
    cost, and (b) XLA cost analysis counts loop bodies once, which would make
    the roofline lie. Online softmax keeps the live score block at
    (B, q_chunk, H, kv_chunk) f32. Returns (B, Sq, H, Dv).
    """
    B, Sq, H, Dh = q.shape
    _, Sk, K, Dv = v.shape
    scale = Dh ** -0.5 if scale is None else scale
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    qf = q.astype(jnp.float32) * scale

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    n_q = (Sq + q_chunk - 1) // q_chunk
    n_kv = (Sk + kv_chunk - 1) // kv_chunk

    outs = []
    for qi in range(n_q):
        q_lo, q_hi = qi * q_chunk, min((qi + 1) * q_chunk, Sq)
        qb = qf[:, q_lo:q_hi]
        m = jnp.full((B, q_hi - q_lo, H), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, q_hi - q_lo, H), jnp.float32)
        acc = jnp.zeros((B, q_hi - q_lo, H, Dv), jnp.float32)
        for ji in range(n_kv):
            kv_lo, kv_hi = ji * kv_chunk, min((ji + 1) * kv_chunk, Sk)
            if causal and kv_lo > q_hi - 1:
                continue  # block entirely in the future — skipped at trace time
            kb = k[:, kv_lo:kv_hi].astype(jnp.float32)
            vb = v[:, kv_lo:kv_hi].astype(jnp.float32)
            s = jnp.einsum(
                "bqhd,bshd->bqhs", qb, kb, preferred_element_type=jnp.float32
            )
            if causal and kv_hi - 1 > q_lo:  # diagonal block: apply the mask
                mask = (kv_lo + jnp.arange(kv_hi - kv_lo))[None, :] <= (
                    q_lo + jnp.arange(q_hi - q_lo)
                )[:, None]
                s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhs,bshd->bqhd", p, vb, preferred_element_type=jnp.float32
            )
            m = m_new
        outs.append(acc / jnp.maximum(l[..., None], 1e-20))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, Dh)
    k: jax.Array,  # (B, S, K, Dh)  (full cache)
    v: jax.Array,  # (B, S, K, Dv)
    pos: jax.Array,  # scalar: current position (attend to [0, pos])
    scale: float | None = None,
) -> jax.Array:
    """Single-token attention against the whole cache (no chunking — the
    position bound is dynamic, so causal block-skipping cannot help)."""
    B, _, H, Dh = q.shape
    S = k.shape[1]
    scale = Dh ** -0.5 if scale is None else scale
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    s = jnp.einsum(
        "bqhd,bshd->bqhs", q.astype(jnp.float32) * scale, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    mask = jnp.arange(S) <= pos
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bqhs,bshd->bqhd", p, v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)


# ----------------------------------------------------------------- ffn
def ffn(params: dict, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_in"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif kind == "squared_relu":  # Nemotron-4 (Primer)
        h = jnp.einsum("...d,df->...f", x, params["w_in"])
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    elif kind == "gelu":
        h = jnp.einsum("...d,df->...f", x, params["w_in"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    else:
        raise ValueError(kind)
    return jnp.einsum("...f,fd->...d", h, params["w_out"])


def init_ffn(key, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = (2.0 / d_model) ** 0.5
    s_out = (2.0 / d_ff) ** 0.5
    p = {
        "w_in": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


# ------------------------------------------------------- sharding helper
def _active_abstract_mesh():
    """jax.sharding.get_abstract_mesh where available; older releases expose
    it under jax._src.mesh (returning () outside any mesh context)."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        from jax._src.mesh import get_abstract_mesh as get
    return get()


def maybe_shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint that degrades to a no-op outside a mesh
    context (CPU unit tests). Each entry of ``axes`` is an axis name, a tuple
    of names, or None; names absent from the active mesh are dropped."""
    mesh = _active_abstract_mesh()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return x
    avail = set(mesh.axis_names)

    def clean(a):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            kept = tuple(n for n in a if n in avail)
            return kept if kept else None
        return a if a in avail else None

    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*[clean(a) for a in axes]))


DATA_AXES = ("pod", "data")  # batch-sharding axes (whichever exist)


# ----------------------------------------------------------------- MoE
def moe_ffn(
    params: dict,
    x: jax.Array,  # (B, S, d)
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    expert_kind: str = "swiglu",
) -> tuple[jax.Array, jax.Array]:
    """Sort-based capacity-constrained top-k routing, dispatched PER BATCH
    ROW so the scatter stays local to the batch ("data") shard. The dispatch
    buffer is then resharded row-sharded → expert-sharded ("model") — i.e.
    GSPMD inserts exactly the expert-parallel all-to-all — and back after the
    expert matmuls. Expert weights carry a leading E axis sharded over
    "model". Returns (y, aux_loss).
    """
    B, S, d = x.shape
    E, k = n_experts, top_k

    logits = jnp.einsum("bsd,de->bse", x, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (B, S, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # renorm

    # ---- load-balancing aux loss (Switch): E * Σ_e f_e * p_e
    me = probs.mean(axis=(0, 1))  # (E,)
    ce = jnp.zeros(E).at[idx.reshape(-1)].add(1.0) / (B * S * k)
    aux = E * jnp.sum(me * ce)

    # ---- per-row sorted dispatch with fixed per-expert capacity
    cap = int(np.ceil(S * k / E * capacity_factor))
    flat_e = idx.reshape(B, S * k)
    flat_t = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(S * k)
    flat_g = gate.reshape(B, S * k)
    order = jnp.argsort(flat_e, axis=-1)  # (B, S*k)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = flat_t[order]  # (B, S*k) token index within the row
    sg = jnp.take_along_axis(flat_g, order, axis=-1)
    # rank within expert group (se sorted per row)
    pos = jnp.arange(S * k)[None, :] - jax.vmap(
        lambda s: jnp.searchsorted(s, s, side="left")
    )(se)
    keep = pos < cap
    bidx = jnp.arange(B)[:, None]
    buf = jnp.zeros((B, E, cap, d), dtype=x.dtype)
    buf = buf.at[
        bidx,
        jnp.where(keep, se, E - 1),
        jnp.where(keep, pos, cap - 1),
    ].add(jnp.where(keep[..., None], jnp.take_along_axis(
        x, st[..., None], axis=1), 0))
    # reshard: row-sharded → expert-sharded (the EP all-to-all)
    buf = maybe_shard(buf, DATA_AXES, "model", None, None)

    # ---- expert computation: (B, E, C, d) × (E, d, f)
    if expert_kind == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
        u = jnp.einsum("becd,edf->becf", buf, params["w_in"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("becd,edf->becf", buf, params["w_in"])
        h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    y_buf = jnp.einsum("becf,efd->becd", h, params["w_out"])
    # reshard back: expert-sharded → row-sharded (the return all-to-all)
    y_buf = maybe_shard(y_buf, DATA_AXES, None, None, None)

    # ---- combine (weighted gather back to tokens; dropped slots add 0)
    gathered = y_buf[bidx, se, jnp.minimum(pos, cap - 1)]  # (B, S*k, d)
    contrib = jnp.where(keep[..., None], gathered * sg[..., None].astype(x.dtype), 0)
    y = jnp.zeros((B, S, d), dtype=jnp.float32).at[bidx, st].add(
        contrib.astype(jnp.float32)
    )
    y = y.astype(x.dtype)

    if "shared" in params:  # DeepSeek shared expert(s), always-on
        y = y + ffn(params["shared"], x, expert_kind)
    return y, aux


def moe_ffn_ep(
    params: dict,
    x: jax.Array,  # (B, S, d) sharded (dp, None, None) or replicated
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    expert_kind: str = "swiglu",
    combine_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE with EXPLICIT collectives (shard_map).

    Layout: expert weights (E, d, f) sharded P("model", fsdp, None) — expert
    parallelism over "model", FSDP over the remaining axes. Activations are
    replicated over "model", so every model rank can route every local token
    itself and process only the experts it owns; the only cross-"model"
    communication is ONE psum of the (B_loc, S, d) combined output per layer
    (plus the FSDP weight all-gather). No dispatch all-to-all is needed, and
    no GSPMD reshard guessing (which materializes the dispatch buffer
    globally — the failure mode this function exists to avoid).
    """
    mesh = _active_abstract_mesh()
    if mesh is None or "model" not in (getattr(mesh, "axis_names", None) or ()):
        return moe_ffn(
            params, x, n_experts=n_experts, top_k=top_k,
            capacity_factor=capacity_factor, expert_kind=expert_kind,
        )
    if not hasattr(jax, "shard_map"):
        # pre-0.5 shard_map mis-lowers over an AbstractMesh inside jit
        # (SPMD partitioner shape RET_CHECK); use the resource-env mesh
        from jax._src.mesh import thread_resources

        concrete = thread_resources.env.physical_mesh
        if getattr(concrete, "axis_names", None):
            mesh = concrete
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in mesh.axis_names if a != "model")
    B = x.shape[0]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    batch_axes = dp if (B % dp_size == 0 and B >= dp_size) else None

    gated = expert_kind == "swiglu"

    def region(x_loc, router, w_in_loc, w_out_loc, w_gate_loc):
        # x_loc: (B_loc, S, d); w_in_loc: (E_loc, d_loc, f); w_out_loc: (E_loc, f, d_loc)
        Bl, S, d = x_loc.shape
        E_loc = w_in_loc.shape[0]
        rank = jax.lax.axis_index("model")
        # FSDP gather of this layer's expert weights (transient, one layer live)
        w_in = jax.lax.all_gather(w_in_loc, dp, axis=1, tiled=True)
        w_out = jax.lax.all_gather(w_out_loc, dp, axis=2, tiled=True)
        w_gate = (
            jax.lax.all_gather(w_gate_loc, dp, axis=1, tiled=True) if gated else None
        )

        T = Bl * S
        xt = x_loc.reshape(T, d)
        logits = (xt @ router).astype(jnp.float32)  # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, top_k)  # (T, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # aux loss over the GLOBAL batch (psum over dp; model ranks identical)
        me = probs.mean(axis=0)
        ce = jnp.zeros(n_experts).at[idx.reshape(-1)].add(1.0) / (T * top_k)
        aux = n_experts * jnp.sum(me * ce)
        if dp and batch_axes is not None:
            # tokens shard over dp → aux is dp-varying → average the shards
            aux = jax.lax.pmean(aux, dp)

        # sorted local dispatch, restricted to the experts this rank owns
        cap = int(np.ceil(T * top_k / n_experts * capacity_factor))
        flat_e = idx.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T), top_k)
        flat_g = gate.reshape(-1)
        order = jnp.argsort(flat_e)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        pos = jnp.arange(T * top_k) - jnp.searchsorted(se, se, side="left")
        se_loc = se - rank * E_loc
        keep = (pos < cap) & (se_loc >= 0) & (se_loc < E_loc)
        buf = jnp.zeros((E_loc, cap, d), dtype=x_loc.dtype)
        buf = buf.at[
            jnp.where(keep, se_loc, E_loc - 1),
            jnp.where(keep, pos, cap - 1),
        ].add(jnp.where(keep[:, None], xt[st], 0))

        if gated:
            g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
            u = jnp.einsum("ecd,edf->ecf", buf, w_in)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        else:
            h = jnp.einsum("ecd,edf->ecf", buf, w_in)
            h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x_loc.dtype)
        y_buf = jnp.einsum("ecf,efd->ecd", h, w_out)

        # combine: map (expert, slot) back to (token, k) via the inverse
        # permutation and GATHER — no scatter-add, no f32 (T·k, d) buffers;
        # the weighted k-sum accumulates in f32 inside one einsum
        inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * top_k))
        pos_tok = pos[inv].reshape(T, top_k)
        se_loc_tok = idx - rank * E_loc  # (T, k)
        keep_tok = (pos_tok < cap) & (se_loc_tok >= 0) & (se_loc_tok < E_loc)
        vals = y_buf[
            jnp.clip(se_loc_tok, 0, E_loc - 1), jnp.clip(pos_tok, 0, cap - 1)
        ]  # (T, k, d)
        w = jnp.where(keep_tok, gate, 0.0)
        y = jnp.einsum("tkd,tk->td", vals, w, preferred_element_type=jnp.float32)
        if combine_dtype is not None:
            y = y.astype(combine_dtype)  # §Perf: halve the psum wire bytes
        # combine across expert owners: the ONE cross-"model" collective
        y = jax.lax.psum(y, "model")
        if batch_axes is None and dp:
            # replicated-batch path (B < dp extent, e.g. B=1 decode): y is
            # numerically identical on every dp rank but typed dp-varying
            # (it flows through dp-gathered weights) — pmean renormalizes the
            # type; the payload is a single token (~KBs)
            y = jax.lax.pmean(y, dp)
        return y.reshape(Bl, S, d).astype(x_loc.dtype), aux

    w_gate = params.get("w_gate", params["w_in"][:, :, :0])  # dummy when ungated
    shard_map = getattr(jax, "shard_map", None)
    extra = {}
    if shard_map is None:  # pre-0.5 home; its replication checker rejects
        from jax.experimental.shard_map import shard_map  # the pmean path

        extra = {"check_rep": False}
    y, aux = shard_map(
        region,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(None, None),
            P("model", dp, None),
            P("model", None, dp),
            P("model", dp, None),
        ),
        out_specs=(P(batch_axes, None, None), P()),
        **extra,
    )(x, params["router"], params["w_in"], params["w_out"], w_gate)

    if "shared" in params:
        y = y + ffn(params["shared"], x, expert_kind)
    return y, aux


def init_moe(
    key,
    d_model: int,
    expert_d_ff: int,
    n_experts: int,
    n_shared: int,
    kind: str,
    dtype,
) -> dict:
    ks = jax.random.split(key, 5)
    s_in = (2.0 / d_model) ** 0.5
    s_out = (2.0 / expert_d_ff) ** 0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, n_experts)) * s_in).astype(
            jnp.float32
        ),
        "w_in": (
            jax.random.normal(ks[1], (n_experts, d_model, expert_d_ff)) * s_in
        ).astype(dtype),
        "w_out": (
            jax.random.normal(ks[2], (n_experts, expert_d_ff, d_model)) * s_out
        ).astype(dtype),
    }
    if kind == "swiglu":
        p["w_gate"] = (
            jax.random.normal(ks[3], (n_experts, d_model, expert_d_ff)) * s_in
        ).astype(dtype)
    if n_shared:
        p["shared"] = init_ffn(ks[4], d_model, expert_d_ff * n_shared, kind, dtype)
    return p
