"""LM transformer family: GQA/MLA attention, dense/MoE FFN, optional MTP.

Covers the five assigned LM architectures (deepseek-v3-671b, olmoe-1b-7b,
qwen1.5-110b, minicpm3-4b, nemotron-4-340b) from one config dataclass.

Structure: pre-RMSNorm blocks, scanned over layers (weights stacked with a
leading L axis → small HLO, fast SPMD partitioning, remat-friendly), tied
flash-style chunked attention for train/prefill and absorbed-MLA or
cached-GQA attention for decode.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (
    apply_rope,
    blocked_attention,
    decode_attention,
    ffn,
    init_ffn,
    init_moe,
    moe_ffn_ep,
    rms_norm,
)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    attn: str = "gqa"               # "gqa" | "mla"
    qkv_bias: bool = False          # Qwen1.5
    qk_norm: bool = False           # OLMoE
    ffn_kind: str = "swiglu"        # "swiglu" | "squared_relu" | "gelu"
    # MoE (n_experts == 0 → dense FFN)
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # MLA
    q_lora_rank: int = 0            # 0 → direct q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MTP (DeepSeek-V3)
    mtp: bool = False
    mtp_weight: float = 0.3
    rope_theta: float = 10_000.0
    dtype: str = "float32"          # params + activations
    kv_chunk: int = 1024            # flash-attention chunk
    remat: bool = True
    # dry-run: fully unroll the layer scan so compiled.cost_analysis() counts
    # every layer (XLA cost analysis counts while-loop bodies ONCE)
    unroll_layers: bool = False
    # §Perf knobs (EXPERIMENTS.md): d_model-sharded decode activations
    # (2D tensor-parallel serving — stops GSPMD from all-gathering FSDP
    # weight shards per decoded token), and reduced-precision MoE combine
    shard_decode_dmodel: bool = False
    moe_combine_dtype: str = "float32"
    # ZeRO-3 semantics: constrain each layer's weights to (replicated, model)
    # at their use point so GSPMD all-gathers the small FSDP shard instead of
    # all-reducing (B,S,ff)-sized partial matmul outputs
    zero3_gather_weights: bool = False

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    def num_params(self) -> int:
        """Analytic parameter count (for 6ND roofline bookkeeping)."""
        leaves = jax.tree.leaves(
            param_shapes(self), is_leaf=lambda x: isinstance(x, tuple)
        )
        return sum(int(np.prod(s)) for s in leaves)

    def num_active_params(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        total = self.num_params()
        if not self.moe:
            return total
        shapes = param_shapes(self)
        expert = sum(
            int(np.prod(shapes["layers"][k]))
            for k in ("moe_w_in", "moe_w_out", "moe_w_gate")
            if k in shapes["layers"]
        )
        active = expert * (self.top_k / self.n_experts)
        return int(total - expert + active)


# --------------------------------------------------------------------------
# parameter shapes / init
# --------------------------------------------------------------------------
def _layer_shapes(cfg: LMConfig) -> dict:
    d, H, K, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s: dict = {"ln1": (d,), "ln2": (d,)}
    if cfg.attn == "gqa":
        s.update(
            wq=(d, H * Dh), wk=(d, K * Dh), wv=(d, K * Dh), wo=(H * Dh, d)
        )
        if cfg.qkv_bias:
            s.update(bq=(H * Dh,), bk=(K * Dh,), bv=(K * Dh,))
        if cfg.qk_norm:
            s.update(q_norm=(Dh,), k_norm=(Dh,))
    else:  # mla
        r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
        nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        if qr:
            s.update(w_dq=(d, qr), q_ln=(qr,), w_uq=(qr, H * (nope + rope)))
        else:
            s.update(w_uq=(d, H * (nope + rope)))
        s.update(
            w_dkv=(d, r),
            kv_ln=(r,),
            w_kr=(d, rope),
            w_uk=(r, H * nope),
            w_uv=(r, H * vd),
            wo=(H * vd, d),
        )
    if cfg.moe:
        e, eff = cfg.n_experts, cfg.expert_d_ff
        s.update(
            router=(d, e),
            moe_w_in=(e, d, eff),
            moe_w_out=(e, eff, d),
        )
        if cfg.ffn_kind == "swiglu":
            s["moe_w_gate"] = (e, d, eff)
        if cfg.n_shared_experts:
            sff = eff * cfg.n_shared_experts
            s.update(sh_w_in=(d, sff), sh_w_out=(sff, d))
            if cfg.ffn_kind == "swiglu":
                s["sh_w_gate"] = (d, sff)
    else:
        s.update(w_in=(d, cfg.d_ff), w_out=(cfg.d_ff, d))
        if cfg.ffn_kind == "swiglu":
            s["w_gate"] = (d, cfg.d_ff)
    return s


def param_shapes(cfg: LMConfig) -> dict:
    L = cfg.n_layers
    layer = {k: (L, *v) for k, v in _layer_shapes(cfg).items()}
    shapes = {
        "embed": (cfg.vocab_size, cfg.d_model),
        "layers": layer,
        "final_ln": (cfg.d_model,),
        "out_head": (cfg.d_model, cfg.vocab_size),
    }
    if cfg.mtp:
        shapes["mtp"] = {
            "proj": (2 * cfg.d_model, cfg.d_model),
            "ln_h": (cfg.d_model,),
            "ln_e": (cfg.d_model,),
            "block": {k: (1, *v) for k, v in _layer_shapes(cfg).items()},
        }
    return shapes


def init_params(key: jax.Array, cfg: LMConfig) -> dict:
    """Random init matching param_shapes. Norm scales start at 1."""
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, shape), k in zip(flat, keys):
        name = getattr(path[-1], "key", str(path[-1]))
        if "ln" in name or "norm" in name:
            leaves.append(jnp.ones(shape, dtype=cfg.jdtype))
        elif name in ("bq", "bk", "bv"):
            leaves.append(jnp.zeros(shape, dtype=cfg.jdtype))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = (1.0 / max(fan_in, 1)) ** 0.5
            leaves.append(
                (jax.random.normal(k, shape) * scale).astype(cfg.jdtype)
            )
    return jax.tree.unflatten(treedef, leaves)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------
def _gqa_qkv(p: dict, x: jax.Array, cfg: LMConfig, positions: jax.Array):
    B, S, _ = x.shape
    H, K, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, K, Dh)
    v = v.reshape(B, S, K, Dh)
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(p, x, cfg: LMConfig, positions):
    q, k, v = _gqa_qkv(p, x, cfg, positions)
    o = blocked_attention(
        q, k, v, causal=True, q_chunk=cfg.kv_chunk, kv_chunk=cfg.kv_chunk
    )
    B, S = x.shape[:2]
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"]), (k, v)


def gqa_decode(p, x, cfg: LMConfig, cache: dict, pos):
    """x: (B, 1, d); cache: {"k": (B, S, K, Dh), "v": ...}; pos: scalar."""
    B = x.shape[0]
    q, k_new, v_new = _gqa_qkv(p, x, cfg, positions=pos[None])
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
    o = decode_attention(q, k, v, pos)
    return (
        jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), p["wo"]),
        {"k": k, "v": v},
    )


def _mla_q(p, x, cfg: LMConfig, positions):
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = rms_norm(p["q_ln"], jnp.einsum("bsd,dr->bsr", x, p["w_dq"]))
        q = jnp.einsum("bsr,rh->bsh", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dh->bsh", x, p["w_uq"])
    q = q.reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p, x, cfg: LMConfig, positions):
    """Prefill/train MLA: explicit up-projection, flash-chunked attention."""
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    ckv = rms_norm(p["kv_ln"], jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]))
    kr = apply_rope(jnp.einsum("bsd,dr->bsr", x, p["w_kr"]), positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rh->bsh", ckv, p["w_uk"]).reshape(B, S, H, nope)
    v = jnp.einsum("bsr,rh->bsh", ckv, p["w_uv"]).reshape(B, S, H, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, rope))], axis=-1
    )
    o = blocked_attention(
        q, k, v, causal=True, q_chunk=cfg.kv_chunk, kv_chunk=cfg.kv_chunk,
        scale=(nope + rope) ** -0.5,
    )
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"])
    return out, (ckv, kr)


def mla_decode(p, x, cfg: LMConfig, cache: dict, pos):
    """Absorbed-MLA decode: attention entirely in the compressed latent space
    (never materializes per-position K/V — O(S·r) cache reads, which is what
    makes the 500k-token decode shape feasible)."""
    B = x.shape[0]
    H = cfg.n_heads
    nope, rope, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, x, cfg, positions=pos[None])  # (B,1,H,·)
    ckv_new = rms_norm(p["kv_ln"], jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]))
    kr_new = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, p["w_kr"]), pos[None], cfg.rope_theta
    )
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0)
    )
    kr = jax.lax.dynamic_update_slice(
        cache["kr"], kr_new.astype(cache["kr"].dtype), (0, pos, 0)
    )
    # absorb W_uk into q: q_eff[b,h,r] = Σ_n q_nope[b,h,n] · W_uk[r, h, n]
    w_uk = p["w_uk"].reshape(r, H, nope)
    q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    scale = (nope + rope) ** -0.5
    s = (
        jnp.einsum("bqhr,bsr->bhqs", q_eff.astype(jnp.float32), ckv.astype(jnp.float32))
        + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32), kr.astype(jnp.float32))
    ) * scale
    S_max = ckv.shape[1]
    mask = jnp.arange(S_max) <= pos
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bqhr", pattn, ckv.astype(jnp.float32))  # latent ctx
    w_uv = p["w_uv"].reshape(r, H, vd)
    o = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1), p["wo"])
    return out, {"ckv": ckv, "kr": kr}


# --------------------------------------------------------------------------
# transformer blocks
# --------------------------------------------------------------------------
def _ffn_part(p: dict, h: jax.Array, cfg: LMConfig):
    if cfg.moe:
        moe_params = {
            "router": p["router"],
            "w_in": p["moe_w_in"],
            "w_out": p["moe_w_out"],
        }
        if "moe_w_gate" in p:
            moe_params["w_gate"] = p["moe_w_gate"]
        if "sh_w_in" in p:
            moe_params["shared"] = {
                k.replace("sh_", ""): p[k]
                for k in ("sh_w_in", "sh_w_out", "sh_w_gate")
                if k in p
            }
        import jax.numpy as _jnp

        return moe_ffn_ep(
            moe_params,
            h,
            n_experts=cfg.n_experts,
            top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            expert_kind=cfg.ffn_kind,
            combine_dtype=(
                None if cfg.moe_combine_dtype == "float32"
                else _jnp.dtype(cfg.moe_combine_dtype)
            ),
        )
    return ffn(p, h, cfg.ffn_kind), jnp.zeros((), jnp.float32)


# weight-name → model-parallel dim position (for the ZeRO-3 use-point gather)
_Z3_IN = ("wq", "wk", "wv", "w_in", "w_gate", "w_uq", "w_uk", "w_uv",
          "sh_w_in", "sh_w_gate")  # (d_in, X·model)
_Z3_OUT = ("wo", "w_out", "sh_w_out")  # (X·model, d_out)
_Z3_REP = ("w_dq", "w_dkv", "w_kr")  # no model dim → fully gathered


def _zero3(p: dict, cfg: LMConfig) -> dict:
    """At the use point, constrain this layer's FSDP-sharded weights back to
    (replicated-over-data, model-sharded). GSPMD then emits ONE all-gather of
    the small weight shard per layer instead of all-reducing activation-sized
    partial-contraction outputs (the ZeRO-3 schedule)."""
    if not cfg.zero3_gather_weights:
        return p
    from repro.models.layers import maybe_shard

    out = {}
    for k, v in p.items():
        if k in _Z3_IN:
            out[k] = maybe_shard(v, None, "model")
        elif k in _Z3_OUT:
            out[k] = maybe_shard(v, "model", None)
        elif k in _Z3_REP:
            out[k] = maybe_shard(v, None, None)
        else:
            out[k] = v
    return out


def block(p: dict, h: jax.Array, cfg: LMConfig, positions):
    p = _zero3(p, cfg)
    attn_fn = mla_attention if cfg.attn == "mla" else gqa_attention
    a, _ = attn_fn(p, rms_norm(p["ln1"], h), cfg, positions)
    h = h + a
    f, aux = _ffn_part(p, rms_norm(p["ln2"], h), cfg)
    return h + f, aux


def block_decode(p: dict, h: jax.Array, cfg: LMConfig, cache: dict, pos):
    p = _zero3(p, cfg)
    dec_fn = mla_decode if cfg.attn == "mla" else gqa_decode
    a, cache = dec_fn(p, rms_norm(p["ln1"], h), cfg, cache, pos)
    h = h + a
    f, _ = _ffn_part(p, rms_norm(p["ln2"], h), cfg)
    return h + f, cache


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------
def forward(params: dict, tokens: jax.Array, cfg: LMConfig):
    """tokens: (B, S) int32 → (logits f32 (B,S,V), h_pre_norm, aux_loss)."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.jdtype)
    positions = jnp.arange(S)

    def body(carry, layer_p):
        h, aux = carry
        h, a = block(layer_p, h, cfg, positions)
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (h, aux), _ = jax.lax.scan(
        body_fn,
        (h, jnp.zeros((), jnp.float32)),
        params["layers"],
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    hn = rms_norm(params["final_ln"], h)
    logits = jnp.einsum(
        "bsd,dv->bsv", hn, params["out_head"], preferred_element_type=jnp.float32
    )
    return logits, h, aux


def _ce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Cross-entropy without take_along_axis: the label pick is a masked
    reduction, so a vocab-sharded logits tensor never gets all-gathered
    (gather over a sharded dim would)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.einsum("...v,...v->...", logits, onehot)
    return lse - picked


def loss_fn(params: dict, batch: dict, cfg: LMConfig) -> tuple[jax.Array, dict]:
    tokens = batch["tokens"]
    logits, h, aux = forward(params, tokens, cfg)
    ce = _ce(logits[:, :-1], tokens[:, 1:]).mean()
    loss = ce + cfg.aux_loss_weight * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp:
        mtp = params["mtp"]
        # predict token t+2 from h_t and embed(token t+1)  (DeepSeek-V3 MTP)
        h_in = rms_norm(mtp["ln_h"], h[:, :-1])
        e_in = rms_norm(
            mtp["ln_e"], params["embed"][tokens[:, 1:]].astype(cfg.jdtype)
        )
        x = jnp.einsum("bsd,dm->bsm", jnp.concatenate([h_in, e_in], -1), mtp["proj"])
        positions = jnp.arange(x.shape[1])
        layer0 = jax.tree.map(lambda a: a[0], mtp["block"])
        x, _ = block(layer0, x, cfg, positions)
        mtp_logits = jnp.einsum(
            "bsd,dv->bsv",
            rms_norm(params["final_ln"], x),
            params["out_head"],
            preferred_element_type=jnp.float32,
        )
        mtp_ce = _ce(mtp_logits[:, :-1], tokens[:, 2:]).mean()
        loss = loss + cfg.mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    return loss, metrics


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def cache_shapes(cfg: LMConfig, batch: int, max_seq: int) -> dict:
    L = cfg.n_layers
    if cfg.attn == "mla":
        return {
            "ckv": (L, batch, max_seq, cfg.kv_lora_rank),
            "kr": (L, batch, max_seq, cfg.qk_rope_dim),
        }
    return {
        "k": (L, batch, max_seq, cfg.n_kv_heads, cfg.d_head),
        "v": (L, batch, max_seq, cfg.n_kv_heads, cfg.d_head),
    }


def init_cache(cfg: LMConfig, batch: int, max_seq: int) -> dict:
    return {
        k: jnp.zeros(s, dtype=cfg.jdtype) for k, s in cache_shapes(cfg, batch, max_seq).items()
    }


def prefill(params: dict, tokens: jax.Array, cfg: LMConfig):
    """Full-sequence forward that also returns the per-layer KV cache.
    Returns (last-position logits (B, V), cache stacked (L, ...))."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.jdtype)
    positions = jnp.arange(S)
    attn_fn = mla_attention if cfg.attn == "mla" else gqa_attention

    def body(h, layer_p):
        layer_p = _zero3(layer_p, cfg)
        a, kv = attn_fn(layer_p, rms_norm(layer_p["ln1"], h), cfg, positions)
        h = h + a
        f, _ = _ffn_part(layer_p, rms_norm(layer_p["ln2"], h), cfg)
        return h + f, kv

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, kvs = jax.lax.scan(
        body_fn, h, params["layers"],
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    hn = rms_norm(params["final_ln"], h[:, -1])
    logits = jnp.einsum(
        "bd,dv->bv", hn, params["out_head"], preferred_element_type=jnp.float32
    )
    if cfg.attn == "mla":
        cache = {"ckv": kvs[0], "kr": kvs[1]}
    else:
        cache = {"k": kvs[0], "v": kvs[1]}
    return logits, cache


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array, cfg: LMConfig):
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 (current
    position = current cache length). cache leaves are (L, B, S, ...).
    Returns (logits (B, V), updated cache)."""
    from repro.models.layers import DATA_AXES, maybe_shard

    h = params["embed"][tokens].astype(cfg.jdtype)
    if cfg.shard_decode_dmodel:
        h = maybe_shard(h, None, None, DATA_AXES)

    def body(h, xs):
        layer_p, layer_cache = xs
        h, new_cache = block_decode(layer_p, h, cfg, layer_cache, pos)
        if cfg.shard_decode_dmodel:
            h = maybe_shard(h, None, None, DATA_AXES)
        return h, new_cache

    h, new_cache = jax.lax.scan(
        body, h, (params["layers"], cache),
        unroll=cfg.n_layers if cfg.unroll_layers else 1,
    )
    hn = rms_norm(params["final_ln"], h[:, -1])
    logits = jnp.einsum(
        "bd,dv->bv", hn, params["out_head"], preferred_element_type=jnp.float32
    )
    return logits, new_cache
