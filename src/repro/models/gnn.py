"""GraphSAGE (mean aggregator) — full-graph and sampled-minibatch modes.

JAX has no sparse SpMM beyond BCOO, so message passing is implemented the
TPU-native way: edge-index gather + ``jax.ops.segment_sum`` scatter (the same
substrate as the LIST-SCAN co-occurrence path — see DESIGN.md §8). The
minibatch mode consumes fixed-fanout neighbor blocks from the real sampler in
data/sampler.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_in: int = 602
    d_hidden: int = 128
    n_classes: int = 41
    aggregator: str = "mean"
    sample_sizes: tuple = (25, 10)
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


def param_shapes(cfg: GNNConfig) -> dict:
    shapes = {}
    d_prev = cfg.d_in
    for l in range(cfg.n_layers):
        shapes[f"layer{l}"] = {
            "w_self": (d_prev, cfg.d_hidden),
            "w_neigh": (d_prev, cfg.d_hidden),
            "b": (cfg.d_hidden,),
        }
        d_prev = cfg.d_hidden
    shapes["head"] = {"w": (d_prev, cfg.n_classes), "b": (cfg.n_classes,)}
    return shapes


def init_params(key: jax.Array, cfg: GNNConfig) -> dict:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, shape), k in zip(flat, keys):
        if len(shape) == 1:
            leaves.append(jnp.zeros(shape, cfg.jdtype))
        else:
            scale = (1.0 / shape[0]) ** 0.5
            leaves.append((jax.random.normal(k, shape) * scale).astype(cfg.jdtype))
    return jax.tree.unflatten(treedef, leaves)


def _sage_layer(p: dict, h: jax.Array, h_neigh: jax.Array) -> jax.Array:
    out = h @ p["w_self"] + h_neigh @ p["w_neigh"] + p["b"]
    out = jax.nn.relu(out)
    # GraphSAGE L2 normalization
    norm = jnp.linalg.norm(out, axis=-1, keepdims=True)
    return out / jnp.maximum(norm, 1e-6)


def forward_full_graph(
    params: dict, feats: jax.Array, edge_index: jax.Array, cfg: GNNConfig
) -> jax.Array:
    """feats: (N, F); edge_index: (2, E) int32 rows (src, dst). Messages flow
    src → dst; mean aggregation via two segment_sums (sum / degree)."""
    n = feats.shape[0]
    src, dst = edge_index[0], edge_index[1]
    deg = jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst, num_segments=n)
    deg = jnp.maximum(deg, 1.0)[:, None]
    h = feats.astype(cfg.jdtype)
    for l in range(cfg.n_layers):
        msg = jax.ops.segment_sum(h[src], dst, num_segments=n)
        h_neigh = (msg / deg).astype(cfg.jdtype)
        h = _sage_layer(params[f"layer{l}"], h, h_neigh)
    return h @ params["head"]["w"] + params["head"]["b"]


def forward_sampled(
    params: dict,
    seed_feats: jax.Array,   # (B, F)
    hop1_feats: jax.Array,   # (B, f1, F)
    hop2_feats: jax.Array,   # (B, f1, f2, F)
    cfg: GNNConfig,
) -> jax.Array:
    """Two-layer fixed-fanout minibatch forward (fanouts f1, f2). Dense
    gathers were done by the host sampler; aggregation is mean over the
    fanout axes (GraphSAGE with sampling, arXiv:1706.02216 Alg. 2)."""
    assert cfg.n_layers == 2
    # layer 0 applied at hop-1 nodes: aggregate hop-2 neighborhoods
    h1 = _sage_layer(
        params["layer0"],
        hop1_feats.astype(cfg.jdtype),
        hop2_feats.astype(cfg.jdtype).mean(axis=2),
    )  # (B, f1, H)
    h0 = _sage_layer(
        params["layer0"],
        seed_feats.astype(cfg.jdtype),
        hop1_feats.astype(cfg.jdtype).mean(axis=1),
    )  # (B, H)
    # layer 1 at seeds: aggregate transformed hop-1
    h = _sage_layer(params["layer1"], h0, h1.mean(axis=1))
    return h @ params["head"]["w"] + params["head"]["b"]


def loss_full_graph(params, feats, edge_index, labels, label_mask, cfg):
    logits = forward_full_graph(params, feats, edge_index, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ce = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return (ce * label_mask).sum() / jnp.maximum(label_mask.sum(), 1.0)


def forward_batched_graphs(
    params, feats, edge_index, graph_ids, cfg: GNNConfig, n_graphs: int
):
    """Batched small graphs (molecule shape): one big disjoint graph, then
    mean-pool node embeddings per graph via segment_sum → graph logits."""
    n = feats.shape[0]
    src, dst = edge_index[0], edge_index[1]
    deg = jax.ops.segment_sum(jnp.ones_like(dst, jnp.float32), dst, num_segments=n)
    deg = jnp.maximum(deg, 1.0)[:, None]
    h = feats.astype(cfg.jdtype)
    for l in range(cfg.n_layers):
        msg = jax.ops.segment_sum(h[src], dst, num_segments=n)
        h = _sage_layer(params[f"layer{l}"], h, (msg / deg).astype(cfg.jdtype))
    pooled = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
    sizes = jax.ops.segment_sum(
        jnp.ones((n,), jnp.float32), graph_ids, num_segments=n_graphs
    )
    pooled = pooled / jnp.maximum(sizes, 1.0)[:, None]
    return pooled @ params["head"]["w"] + params["head"]["b"]


def loss_batched_graphs(params, feats, edge_index, graph_ids, labels, cfg, n_graphs):
    logits = forward_batched_graphs(params, feats, edge_index, graph_ids, cfg, n_graphs)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0].mean()


def loss_sampled(params, seed_feats, hop1_feats, hop2_feats, labels, cfg):
    logits = forward_sampled(params, seed_feats, hop1_feats, hop2_feats, cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0].mean()
