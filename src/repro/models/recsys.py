"""Recsys rankers: DIEN, BERT4Rec, xDeepFM, BST.

The hot path is the sparse embedding lookup. JAX has no native EmbeddingBag,
so it is built here from ``jnp.take`` + masked segment reduction
(``embedding_bag``) — single-hot fields use plain take, multi-hot fields go
through the bag. Tables are stored as ONE concatenated (total_rows, dim)
matrix with per-field offsets so that row-sharding over the "model" axis
gives balanced expert-style embedding parallelism.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import blocked_attention


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                     # "dien" | "bert4rec" | "xdeepfm" | "bst"
    n_items: int = 0
    n_cats: int = 0
    embed_dim: int = 32
    seq_len: int = 20
    # dien
    gru_dim: int = 0
    # bert4rec / bst
    n_blocks: int = 0
    n_heads: int = 0
    n_masked: int = 10            # masked positions per sequence (bert4rec)
    # xdeepfm
    field_vocabs: tuple = ()      # per-field vocab sizes (single-hot first)
    n_multi_hot: int = 0          # last n fields are multi-hot bags
    max_bag: int = 8
    cin_layers: tuple = ()
    mlp: tuple = ()
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def n_fields(self) -> int:
        return len(self.field_vocabs)


# --------------------------------------------------------------------------
# EmbeddingBag substrate
# --------------------------------------------------------------------------
def embedding_lookup(table: jax.Array, ids: jax.Array) -> jax.Array:
    """Plain row gather; ids < 0 return zeros."""
    safe = jnp.maximum(ids, 0)
    out = jnp.take(table, safe, axis=0)
    return jnp.where((ids >= 0)[..., None], out, 0)


def embedding_bag(
    table: jax.Array, ids: jax.Array, mode: str = "sum"
) -> jax.Array:
    """EmbeddingBag: ids (..., L) with -1 padding → (..., dim) reduction.
    take + masked sum ≡ torch.nn.EmbeddingBag(mode=sum/mean)."""
    vecs = embedding_lookup(table, ids)  # (..., L, dim)
    s = vecs.sum(axis=-2)
    if mode == "sum":
        return s
    n = jnp.maximum((ids >= 0).sum(axis=-1, keepdims=True), 1)
    return s / n


def _mlp(params: Sequence[dict], x: jax.Array, final_linear: bool = True) -> jax.Array:
    n = len(params)
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < n - 1 or not final_linear:
            x = jax.nn.relu(x)
    return x


def _init_linear(key, d_in, d_out, dtype):
    return {
        "w": (jax.random.normal(key, (d_in, d_out)) * (1.0 / d_in) ** 0.5).astype(dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def _init_mlp(key, dims: Sequence[int], dtype):
    keys = jax.random.split(key, len(dims) - 1)
    return [
        _init_linear(k, dims[i], dims[i + 1], dtype) for i, k in enumerate(keys)
    ]


# --------------------------------------------------------------------------
# GRU / AUGRU (DIEN)
# --------------------------------------------------------------------------
def _init_gru(key, d_in, d_hidden, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    si, sh = (1.0 / d_in) ** 0.5, (1.0 / d_hidden) ** 0.5
    return {
        "wx": (jax.random.normal(k1, (d_in, 3 * d_hidden)) * si).astype(dtype),
        "wh": (jax.random.normal(k2, (d_hidden, 3 * d_hidden)) * sh).astype(dtype),
        "b": jnp.zeros((3 * d_hidden,), dtype),
    }


def _gru_gates(p, x, h):
    gx = x @ p["wx"] + p["b"]
    gh = h @ p["wh"]
    xz, xr, xn = jnp.split(gx, 3, axis=-1)
    hz, hr, hn = jnp.split(gh, 3, axis=-1)
    z = jax.nn.sigmoid(xz + hz)
    r = jax.nn.sigmoid(xr + hr)
    n = jnp.tanh(xn + r * hn)
    return z, n


def gru(p: dict, xs: jax.Array) -> jax.Array:
    """xs: (B, T, d_in) → states (B, T, d_hidden)."""
    B = xs.shape[0]
    H = p["wh"].shape[0]

    def step(h, x):
        z, n = _gru_gates(p, x, h)
        h = (1 - z) * n + z * h
        return h, h

    h0 = jnp.zeros((B, H), xs.dtype)
    _, hs = jax.lax.scan(step, h0, jnp.swapaxes(xs, 0, 1))
    return jnp.swapaxes(hs, 0, 1)


def augru(p: dict, xs: jax.Array, att: jax.Array) -> jax.Array:
    """Attentional-update GRU (DIEN): update gate scaled by attention score.
    xs: (B, T, d_in); att: (B, T) in [0,1]. Returns final state (B, H)."""
    B = xs.shape[0]
    H = p["wh"].shape[0]

    def step(h, xa):
        x, a = xa
        z, n = _gru_gates(p, x, h)
        z = z * a[:, None]
        h = (1 - z) * h + z * n
        return h, None

    h0 = jnp.zeros((B, H), xs.dtype)
    h, _ = jax.lax.scan(
        step, h0, (jnp.swapaxes(xs, 0, 1), jnp.swapaxes(att, 0, 1))
    )
    return h


# --------------------------------------------------------------------------
# DIEN
# --------------------------------------------------------------------------
def dien_param_shapes(cfg: RecsysConfig) -> dict:
    e, g = cfg.embed_dim, cfg.gru_dim
    d_in = 2 * e  # item ⊕ category
    mlp_dims = (g + 3 * d_in,) + tuple(cfg.mlp) + (1,)
    return {
        "item_embed": (cfg.n_items, e),
        "cat_embed": (cfg.n_cats, e),
        "gru": {"wx": (d_in, 3 * g), "wh": (g, 3 * g), "b": (3 * g,)},
        "augru": {"wx": (d_in, 3 * g), "wh": (g, 3 * g), "b": (3 * g,)},
        "att_w": (g, d_in),
        "mlp": [
            {"w": (mlp_dims[i], mlp_dims[i + 1]), "b": (mlp_dims[i + 1],)}
            for i in range(len(mlp_dims) - 1)
        ],
        "user_proj": (g, e),  # retrieval tower head
    }


def dien_forward(params, batch, cfg: RecsysConfig):
    """batch: hist_items/hist_cats (B, T) (−1 pad), target_item/target_cat (B,)."""
    hist = jnp.concatenate(
        [
            embedding_lookup(params["item_embed"], batch["hist_items"]),
            embedding_lookup(params["cat_embed"], batch["hist_cats"]),
        ],
        axis=-1,
    )  # (B, T, 2e)
    tgt = jnp.concatenate(
        [
            embedding_lookup(params["item_embed"], batch["target_item"]),
            embedding_lookup(params["cat_embed"], batch["target_cat"]),
        ],
        axis=-1,
    )  # (B, 2e)
    states = gru(params["gru"], hist)  # (B, T, g)
    att = jax.nn.sigmoid(
        jnp.einsum("btg,ge,be->bt", states, params["att_w"], tgt)
    )
    mask = batch["hist_items"] >= 0
    att = att * mask
    final = augru(params["augru"], hist, att)  # (B, g)
    hist_sum = embedding_bag(params["item_embed"], batch["hist_items"])
    hist_sum = jnp.concatenate(
        [hist_sum, embedding_bag(params["cat_embed"], batch["hist_cats"])], -1
    )
    x = jnp.concatenate([final, tgt, hist_sum, tgt * hist_sum], axis=-1)
    return _mlp(params["mlp"], x)[:, 0]  # logits (B,)


def dien_user_vector(params, batch, cfg):
    """Two-tower retrieval head: AUGRU state → item-embedding space."""
    hist = jnp.concatenate(
        [
            embedding_lookup(params["item_embed"], batch["hist_items"]),
            embedding_lookup(params["cat_embed"], batch["hist_cats"]),
        ],
        axis=-1,
    )
    states = gru(params["gru"], hist)
    att = jnp.ones(batch["hist_items"].shape, states.dtype) * (
        batch["hist_items"] >= 0
    )
    final = augru(params["augru"], hist, att)
    return final @ params["user_proj"]


# --------------------------------------------------------------------------
# BERT4Rec
# --------------------------------------------------------------------------
def bert4rec_param_shapes(cfg: RecsysConfig) -> dict:
    e = cfg.embed_dim
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append(
            {
                "ln1": (e,), "ln2": (e,),
                "wq": (e, e), "wk": (e, e), "wv": (e, e), "wo": (e, e),
                "w_in": (e, 4 * e), "w_out": (4 * e, e),
                "b_in": (4 * e,), "b_out": (e,),
            }
        )
    return {
        # +1 row: the [MASK] token
        "item_embed": (cfg.n_items + 1, e),
        "pos_embed": (cfg.seq_len, e),
        "blocks": blocks,
        "final_ln": (e,),
    }


def _bert_block(p, h, n_heads):
    from repro.models.layers import rms_norm

    B, S, e = h.shape
    dh = e // n_heads
    x = rms_norm(p["ln1"], h)
    q = (x @ p["wq"]).reshape(B, S, n_heads, dh)
    k = (x @ p["wk"]).reshape(B, S, n_heads, dh)
    v = (x @ p["wv"]).reshape(B, S, n_heads, dh)
    o = blocked_attention(q, k, v, causal=False, q_chunk=S, kv_chunk=S)
    h = h + o.reshape(B, S, e) @ p["wo"]
    x = rms_norm(p["ln2"], h)
    x = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
    return h + x @ p["w_out"] + p["b_out"]


def bert4rec_encode(params, items, cfg: RecsysConfig):
    """items: (B, S) with −1 pad; [MASK] = n_items. Bidirectional encoder."""
    from repro.models.layers import rms_norm

    h = embedding_lookup(params["item_embed"], items) + params["pos_embed"]
    for p in params["blocks"]:
        h = _bert_block(p, h, cfg.n_heads)
    return rms_norm(params["final_ln"], h)  # (B, S, e)


def bert4rec_logits(params, items, positions, cfg: RecsysConfig):
    """Scores over the full item vocab at the given (B, M) positions —
    weight-tied output head (h @ E^T)."""
    h = bert4rec_encode(params, items, cfg)
    hm = jnp.take_along_axis(h, positions[..., None], axis=1)  # (B, M, e)
    return jnp.einsum(
        "bme,ve->bmv", hm, params["item_embed"][: cfg.n_items],
        preferred_element_type=jnp.float32,
    )


# --------------------------------------------------------------------------
# xDeepFM
# --------------------------------------------------------------------------
def xdeepfm_param_shapes(cfg: RecsysConfig) -> dict:
    total_rows = int(sum(cfg.field_vocabs))
    F, D = cfg.n_fields, cfg.embed_dim
    cin = []
    h_prev = F
    for h in cfg.cin_layers:
        cin.append({"w": (h_prev * F, h)})
        h_prev = h
    mlp_dims = (F * D,) + tuple(cfg.mlp) + (1,)
    return {
        "embed": (total_rows, D),
        "linear": (total_rows,),
        "cin": cin,
        "cin_head": (int(sum(cfg.cin_layers)), 1),
        "mlp": [
            {"w": (mlp_dims[i], mlp_dims[i + 1]), "b": (mlp_dims[i + 1],)}
            for i in range(len(mlp_dims) - 1)
        ],
    }


def _xdeepfm_field_offsets(cfg: RecsysConfig) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(cfg.field_vocabs)[:-1]]).astype(np.int32)


def xdeepfm_embed(params, batch, cfg: RecsysConfig):
    """batch: single_ids (B, F_single), multi_ids (B, F_multi, max_bag).
    Returns (B, F, D) field embeddings + (B,) linear term."""
    offs = jnp.asarray(_xdeepfm_field_offsets(cfg))
    n_single = cfg.n_fields - cfg.n_multi_hot
    sid = batch["single_ids"] + offs[:n_single]
    e_single = embedding_lookup(params["embed"], sid)  # (B, Fs, D)
    lin = embedding_lookup(params["linear"][:, None], sid)[..., 0].sum(-1)
    if cfg.n_multi_hot:
        moffs = offs[n_single:]
        mid = jnp.where(
            batch["multi_ids"] >= 0, batch["multi_ids"] + moffs[:, None], -1
        )
        e_multi = embedding_bag(params["embed"], mid, mode="mean")  # (B, Fm, D)
        lin = lin + embedding_bag(params["linear"][:, None], mid, "sum")[..., 0].sum(-1)
        e = jnp.concatenate([e_single, e_multi], axis=1)
    else:
        e = e_single
    return e, lin


def _cin(params, x0: jax.Array) -> jax.Array:
    """Compressed Interaction Network: explicit vector-wise crosses.
    x0: (B, F, D) → concat of per-layer sum-pools (B, Σh)."""
    pools = []
    xk = x0
    for layer in params:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)  # (B, Hk, F, D)
        B, Hk, F, D = z.shape
        xk = jnp.einsum("bqd,qh->bhd", z.reshape(B, Hk * F, D), layer["w"])
        pools.append(xk.sum(axis=-1))  # (B, Hk+1)
    return jnp.concatenate(pools, axis=-1)


def xdeepfm_forward(params, batch, cfg: RecsysConfig):
    e, lin = xdeepfm_embed(params, batch, cfg)  # (B, F, D)
    cin_out = _cin(params["cin"], e) @ params["cin_head"]  # (B, 1)
    B = e.shape[0]
    dnn_out = _mlp(params["mlp"], e.reshape(B, -1))  # (B, 1)
    return lin + cin_out[:, 0] + dnn_out[:, 0]  # logits (B,)


# --------------------------------------------------------------------------
# BST (Behavior Sequence Transformer)
# --------------------------------------------------------------------------
def bst_param_shapes(cfg: RecsysConfig) -> dict:
    e = cfg.embed_dim
    S = cfg.seq_len + 1  # history + target item
    blocks = []
    for _ in range(cfg.n_blocks):
        blocks.append(
            {
                "ln1": (e,), "ln2": (e,),
                "wq": (e, e), "wk": (e, e), "wv": (e, e), "wo": (e, e),
                "w_in": (e, 4 * e), "w_out": (4 * e, e),
                "b_in": (4 * e,), "b_out": (e,),
            }
        )
    mlp_dims = (S * e,) + tuple(cfg.mlp) + (1,)
    return {
        "item_embed": (cfg.n_items, e),
        "pos_embed": (S, e),
        "blocks": blocks,
        "mlp": [
            {"w": (mlp_dims[i], mlp_dims[i + 1]), "b": (mlp_dims[i + 1],)}
            for i in range(len(mlp_dims) - 1)
        ],
        "user_proj": (e, e),
    }


def bst_forward(params, batch, cfg: RecsysConfig):
    """batch: hist_items (B, S), target_item (B,) → logits (B,)."""
    seq = jnp.concatenate(
        [batch["hist_items"], batch["target_item"][:, None]], axis=1
    )
    h = embedding_lookup(params["item_embed"], seq) + params["pos_embed"]
    for p in params["blocks"]:
        h = _bert_block(p, h, cfg.n_heads)
    B = h.shape[0]
    return _mlp(params["mlp"], h.reshape(B, -1))[:, 0]


def bst_user_vector(params, batch, cfg: RecsysConfig):
    h = embedding_lookup(params["item_embed"], batch["hist_items"]) + params[
        "pos_embed"
    ][: cfg.seq_len]
    for p in params["blocks"]:
        h = _bert_block(p, h, cfg.n_heads)
    return h.mean(axis=1) @ params["user_proj"]


# --------------------------------------------------------------------------
# shared: init, losses, retrieval
# --------------------------------------------------------------------------
PARAM_SHAPE_FNS = {
    "dien": dien_param_shapes,
    "bert4rec": bert4rec_param_shapes,
    "xdeepfm": xdeepfm_param_shapes,
    "bst": bst_param_shapes,
}

FORWARD_FNS = {
    "dien": dien_forward,
    "xdeepfm": xdeepfm_forward,
    "bst": bst_forward,
}


def param_shapes(cfg: RecsysConfig) -> dict:
    return PARAM_SHAPE_FNS[cfg.kind](cfg)


def init_params(key: jax.Array, cfg: RecsysConfig) -> dict:
    shapes = param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, shape), k in zip(flat, keys):
        name = getattr(path[-1], "key", "")
        if name in ("b", "b_in", "b_out", "linear") or "ln" in str(name):
            if "ln" in str(name) and "linear" != name:
                leaves.append(jnp.ones(shape, cfg.jdtype))
            else:
                leaves.append(jnp.zeros(shape, cfg.jdtype))
        else:
            fan_in = shape[0] if len(shape) >= 2 else shape[-1]
            scale = min((1.0 / max(fan_in, 1)) ** 0.5, 0.05)
            leaves.append((jax.random.normal(k, shape) * scale).astype(cfg.jdtype))
    return jax.tree.unflatten(treedef, leaves)


def pointwise_loss(params, batch, cfg: RecsysConfig) -> jax.Array:
    """BCE-with-logits (dien / xdeepfm / bst click prediction)."""
    logits = FORWARD_FNS[cfg.kind](params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def masked_item_loss(params, batch, cfg: RecsysConfig) -> jax.Array:
    """BERT4Rec masked-item cross-entropy over the full item softmax."""
    logits = bert4rec_logits(params, batch["items"], batch["positions"], cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    return (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, batch, cfg: RecsysConfig) -> jax.Array:
    if cfg.kind == "bert4rec":
        return masked_item_loss(params, batch, cfg)
    return pointwise_loss(params, batch, cfg)


def retrieval_scores(params, batch, cand_ids: jax.Array, cfg: RecsysConfig):
    """Score ONE user context against n_candidates items: batched dot of the
    user vector with gathered candidate embeddings (never a loop). xDeepFM is
    not a two-tower model — its retrieval IS the full forward with the
    candidate field varying (still one batched pass)."""
    if cfg.kind == "xdeepfm":
        C = cand_ids.shape[0]
        wide = {
            "single_ids": jnp.broadcast_to(
                batch["single_ids"], (C,) + batch["single_ids"].shape[1:]
            ).at[:, 0].set(cand_ids),
            "multi_ids": jnp.broadcast_to(
                batch["multi_ids"], (C,) + batch["multi_ids"].shape[1:]
            ),
        }
        return xdeepfm_forward(params, wide, cfg)
    if cfg.kind == "bert4rec":
        h = bert4rec_encode(params, batch["items"], cfg)[:, -1]  # (1, e)
        u = h[0]
    elif cfg.kind == "dien":
        u = dien_user_vector(params, batch, cfg)[0]
    else:  # bst
        u = bst_user_vector(params, batch, cfg)[0]
    table = params["item_embed"]
    cands = jnp.take(table, jnp.minimum(cand_ids, table.shape[0] - 1), axis=0)
    return jnp.einsum("e,ce->c", u, cands, preferred_element_type=jnp.float32)
