"""Model zoo: LM transformers (GQA/MLA/MoE/MTP), GraphSAGE, recsys rankers."""
