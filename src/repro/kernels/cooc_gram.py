"""Pallas TPU kernel: tiled co-occurrence Gram matmul (the LIST-BLOCKS core).

Computes C[I,J] = B[:,I]ᵀ B[:,J] for 0/1 incidence tiles streamed HBM→VMEM.
Grid = (M/blk_m, N/blk_n, D/blk_d) with the document (contraction) dimension
innermost and sequential; the (blk_m, blk_n) f32 output tile stays resident
in VMEM across the contraction and is written once — mirroring LIST-BLOCKS'
write-once accumulator discipline (no merge phase).

MXU alignment: blk_m, blk_n multiples of 128 (lane), blk_d multiple of 8
(sublane, f32). 0/1 values are exact in bf16/f32; accumulation is f32, exact
below 2²⁴ documents per call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _gram_kernel(bi_ref, bj_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # (blk_d, blk_m)ᵀ @ (blk_d, blk_n) on the MXU, f32 accumulate
    out_ref[...] += jax.lax.dot_general(
        bi_ref[...],
        bj_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("blk_m", "blk_n", "blk_d", "interpret")
)
def cooc_gram_kernel(
    b_i: jax.Array,
    b_j: jax.Array,
    *,
    blk_m: int = 128,
    blk_n: int = 128,
    blk_d: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """b_i: (D, M), b_j: (D, N) 0/1 tiles; D, M, N multiples of the block
    sizes (ops.cooc_gram pads). Returns f32 (M, N)."""
    d, m = b_i.shape
    _, n = b_j.shape
    grid = (m // blk_m, n // blk_n, d // blk_d)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_d, blk_m), lambda i, j, k: (k, i)),
            pl.BlockSpec((blk_d, blk_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(b_i, b_j)
