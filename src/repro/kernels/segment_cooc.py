"""Pallas TPU kernel: batched histogram accumulator (the LIST-SCAN core).

LIST-SCAN's accumulator table is a histogram: row i of C is a bincount over
the concatenated forward documents of postings(i). TPUs have no fast scatter,
so the histogram is recast as two comparisons and one MXU matmul per tile:

    seg_onehot[r, l] = (seg[l] == r)            (rows × blk_l)
    id_onehot[l, v]  = (ids[l] == v)            (blk_l × blk_v)
    out[r, v]       += seg_onehot @ id_onehot   (MXU, f32 exact)

Grid = (V/blk_v, L/blk_l); the (rows, blk_v) tile stays VMEM-resident across
the L sweep. Padding entries carry ids = -1 / seg = -1 and match nothing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segment_hist_kernel(ids_ref, seg_ref, out_ref, *, num_rows: int, blk_v: int):
    v_blk = pl.program_id(0)
    l_blk = pl.program_id(1)

    @pl.when(l_blk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]  # (1, blk_l) int32
    seg = seg_ref[...]  # (1, blk_l) int32
    blk_l = ids.shape[-1]

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (num_rows, blk_l), 0)
    seg_onehot = (seg == row_iota).astype(jnp.bfloat16)  # (rows, blk_l)
    v_iota = jax.lax.broadcasted_iota(jnp.int32, (blk_l, blk_v), 1)
    v_base = v_blk * blk_v
    id_onehot = ((ids.T - v_base) == v_iota).astype(jnp.bfloat16)  # (blk_l, blk_v)

    out_ref[...] += jax.lax.dot_general(
        seg_onehot,
        id_onehot,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("num_rows", "vocab", "blk_v", "blk_l", "interpret")
)
def segment_hist_kernel(
    ids: jax.Array,
    seg: jax.Array,
    *,
    num_rows: int,
    vocab: int,
    blk_v: int = 128,
    blk_l: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """ids, seg: (L,) int32 with -1 padding; L multiple of blk_l, vocab
    multiple of blk_v (ops.segment_hist pads). Returns f32 (num_rows, vocab)."""
    (l,) = ids.shape
    ids2 = ids.reshape(1, l)
    seg2 = seg.reshape(1, l)
    grid = (vocab // blk_v, l // blk_l)
    kernel = functools.partial(
        _segment_hist_kernel, num_rows=num_rows, blk_v=blk_v
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_l), lambda v, lb: (0, lb)),
            pl.BlockSpec((1, blk_l), lambda v, lb: (0, lb)),
        ],
        out_specs=pl.BlockSpec((num_rows, blk_v), lambda v, lb: (0, v)),
        out_shape=jax.ShapeDtypeStruct((num_rows, vocab), jnp.float32),
        interpret=interpret,
    )(ids2, seg2)
