"""Pallas TPU kernel: bit-packed pair intersection counting (LIST-PAIRS core).

Posting lists are packed 32 documents per uint32 word (data/index.py
``incidence_bitpacked``). The intersection size of two posting lists is
Σ_w popcount(w_i & w_j) — the VPU path: 32× less HBM traffic than a bf16
incidence tile, no MXU involvement, exact integer counts.

Grid = (M/blk_m, N/blk_n, W/blk_w), word dimension innermost/sequential, the
(blk_m, blk_n) int32 accumulator resident in VMEM. The (blk_m, blk_n, blk_w)
AND intermediate lives in VREG/VMEM — block sizes keep it ≤ 2 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitpair_kernel(wi_ref, wj_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    both = jnp.bitwise_and(wi_ref[...][:, None, :], wj_ref[...][None, :, :])
    out_ref[...] += jax.lax.population_count(both).astype(jnp.int32).sum(axis=-1)


@functools.partial(
    jax.jit, static_argnames=("blk_m", "blk_n", "blk_w", "interpret")
)
def bitpair_kernel(
    rows_i: jax.Array,
    rows_j: jax.Array,
    *,
    blk_m: int = 64,
    blk_n: int = 64,
    blk_w: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """rows_i: (M, W), rows_j: (N, W) uint32; dims multiples of block sizes
    (ops.bitpair_popcount pads). Returns int32 (M, N)."""
    m, w = rows_i.shape
    n, _ = rows_j.shape
    grid = (m // blk_m, n // blk_n, w // blk_w)
    return pl.pallas_call(
        _bitpair_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_m, blk_w), lambda i, j, k: (i, k)),
            pl.BlockSpec((blk_n, blk_w), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(rows_i, rows_j)
