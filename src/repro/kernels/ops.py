"""Jit'd public wrappers for the Pallas kernels.

Each op pads its inputs to kernel block multiples, dispatches to the Pallas
kernel (``interpret=True`` on CPU — the kernel body runs in Python for
correctness validation; compiled Mosaic on TPU), and slices the result back.
``use_kernel=False`` routes to the pure-jnp oracle in ref.py — the oracle IS
the reference semantics, so both paths are interchangeable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.bitpair import bitpair_kernel
from repro.kernels.cooc_gram import cooc_gram_kernel
from repro.kernels.segment_cooc import segment_hist_kernel


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value=0) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths, constant_values=value)


def cooc_gram(
    b_i,
    b_j,
    *,
    use_kernel: bool = True,
    blk_m: int = 128,
    blk_n: int = 128,
    blk_d: int = 256,
) -> jax.Array:
    """Gram tile C = b_iᵀ b_j for 0/1 incidence tiles (D, M), (D, N) → f32 (M, N)."""
    b_i = jnp.asarray(b_i, dtype=jnp.float32)
    b_j = jnp.asarray(b_j, dtype=jnp.float32)
    if not use_kernel:
        return ref.cooc_gram_ref(b_i, b_j)
    m, n = b_i.shape[1], b_j.shape[1]
    b_i = _pad_to(_pad_to(b_i, 0, blk_d), 1, blk_m)
    b_j = _pad_to(_pad_to(b_j, 0, blk_d), 1, blk_n)
    out = cooc_gram_kernel(
        b_i, b_j, blk_m=blk_m, blk_n=blk_n, blk_d=blk_d, interpret=_interpret()
    )
    return out[:m, :n]


def bitpair_popcount(
    rows_i,
    rows_j,
    *,
    use_kernel: bool = True,
    blk_m: int = 64,
    blk_n: int = 64,
    blk_w: int = 128,
) -> jax.Array:
    """Intersection counts over uint32 bitmaps (M, W), (N, W) → int32 (M, N)."""
    rows_i = jnp.asarray(np.ascontiguousarray(rows_i), dtype=jnp.uint32)
    rows_j = jnp.asarray(np.ascontiguousarray(rows_j), dtype=jnp.uint32)
    if not use_kernel:
        return ref.bitpair_popcount_ref(rows_i, rows_j)
    m, n = rows_i.shape[0], rows_j.shape[0]
    rows_i = _pad_to(_pad_to(rows_i, 0, blk_m), 1, blk_w)
    rows_j = _pad_to(_pad_to(rows_j, 0, blk_n), 1, blk_w)
    out = bitpair_kernel(
        rows_i, rows_j, blk_m=blk_m, blk_n=blk_n, blk_w=blk_w, interpret=_interpret()
    )
    return out[:m, :n]


def segment_hist(
    ids,
    seg,
    *,
    num_rows: int,
    vocab: int,
    use_kernel: bool = True,
    blk_v: int = 128,
    blk_l: int = 512,
) -> jax.Array:
    """Batched LIST-SCAN histogram: (L,) ids + (L,) segment ids (−1 = pad)
    → int32 (num_rows, vocab)."""
    ids = jnp.asarray(ids, dtype=jnp.int32)
    seg = jnp.asarray(seg, dtype=jnp.int32)
    if not use_kernel:
        return ref.segment_hist_ref(ids, seg, num_rows, vocab)
    ids = _pad_to(ids, 0, blk_l, value=-1)
    seg = _pad_to(seg, 0, blk_l, value=-1)
    vpad = vocab + ((-vocab) % blk_v)
    out = segment_hist_kernel(
        ids,
        seg,
        num_rows=num_rows,
        vocab=vpad,
        blk_v=blk_v,
        blk_l=blk_l,
        interpret=_interpret(),
    )
    return out[:, :vocab].astype(jnp.int32)
