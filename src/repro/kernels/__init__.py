"""Pallas TPU kernels for the counting hot paths (Gram matmul, bit-packed
intersection, segment histograms) and the serving hot path (fused top-k
gather). Every kernel has a jnp reference implementation and an interpreter
path so CPU CI exercises the exact kernel code.

Only the serving kernel is re-exported here; counting methods import their
kernel module directly (kernels.cooc_gram, kernels.bitpair, ...).
"""

from repro.kernels.topk_gather import topk_gather

__all__ = ["topk_gather"]
