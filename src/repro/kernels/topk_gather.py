"""Pallas TPU kernel: batched top-k neighbour selection on gathered CSR rows.

The serving hot path (store/query.py) gathers each queried term's merged
neighbour row from the mmap'd segments, pads the rows into a rectangular
``(B, L)`` tile, and ranks the ``L`` candidates per row by count, PMI, or
Dice. The reference implementation scores the tile and calls
``jax.lax.top_k`` in one jitted function; this kernel moves the whole
score-and-select step into a single Pallas launch so the tile never leaves
VMEM between scoring and selection:

    score tile (VPU)  →  k × (row-max, first-argmax, mask)  →  (B, k)

Selection is k rounds of masked row-max. Each round takes the running
maximum per row and, among the slots achieving it, the **lowest column
index** — exactly ``jax.lax.top_k``'s tie rule — then retires that slot.
``k`` is a serving-sized constant (≤ tens), so the unrolled loop stays tiny
compared to the O(B·L) scoring work, and results are bit-identical to the
reference on every path (the CI edge-case suite asserts this with
``interpret=True``).

Scores (df = document frequency, D = total documents):
    count  c(t, n)                        — exact int32 ranking
    pmi    log(c · D / (df_t · df_n))    — pointwise mutual information
    dice   2c / (df_t + df_n)            — Dice coefficient

Padding slots carry id -1 / count 0 and score 0 (count) or -inf (pmi/dice),
matching the reference scorer, so rows shorter than ``k`` surface id -1.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

LANE = 128  # TPU lane width: pad the candidate axis to a multiple of this

_INT_MIN = jnp.iinfo(jnp.int32).min


def _score_tile(ids, cnts, df_t, df_n, *, score: str, num_docs: int):
    """Score a padded (blk_b, L) candidate tile; same expressions (and the
    same dtypes, op for op) as the reference scorer in store/query.py."""
    valid = ids >= 0
    if score == "count":
        return jnp.where(valid, cnts, 0).astype(jnp.int32), _INT_MIN
    if score == "pmi":
        s = jnp.log(
            cnts.astype(jnp.float32)
            * jnp.float32(num_docs)
            / (df_t.astype(jnp.float32) * df_n.astype(jnp.float32))
        )
        return jnp.where(valid, s, -jnp.inf), -jnp.inf
    if score == "dice":
        s = 2.0 * cnts.astype(jnp.float32) / (df_t + df_n).astype(jnp.float32)
        return jnp.where(valid, s, -jnp.inf), -jnp.inf
    raise ValueError(f"unknown score {score!r}; have ('count', 'pmi', 'dice')")


def _topk_gather_kernel(
    ids_ref,
    cnts_ref,
    dft_ref,
    dfn_ref,
    out_ids_ref,
    out_s_ref,
    *,
    k: int,
    k_pad: int,
    score: str,
    num_docs: int,
):
    ids = ids_ref[...]  # (blk_b, L) int32, -1 padding
    s, fill = _score_tile(
        ids, cnts_ref[...], dft_ref[...], dfn_ref[...],
        score=score, num_docs=num_docs,
    )
    blk_b, L = ids.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (blk_b, L), 1)

    alive = jnp.ones((blk_b, L), dtype=jnp.bool_)
    sel_ids, sel_s = [], []
    for _ in range(k):  # k is static and small: unrolled row-max rounds
        masked = jnp.where(alive, s, fill)
        m = jnp.max(masked, axis=1, keepdims=True)
        # first (lowest-index) slot achieving the max — lax.top_k's tie rule
        idx = jnp.min(
            jnp.where(alive & (masked == m), col, jnp.int32(L)),
            axis=1, keepdims=True,
        )
        pick = col == idx
        sel_ids.append(jnp.max(jnp.where(pick, ids, _INT_MIN), axis=1))
        sel_s.append(m[:, 0])
        alive = alive & ~pick

    top_ids = jnp.stack(sel_ids, axis=1)
    top_s = jnp.stack(sel_s, axis=1)
    if k_pad > k:  # lane-align the output tile; the wrapper slices it off
        top_ids = jnp.concatenate(
            [top_ids, jnp.full((blk_b, k_pad - k), -1, top_ids.dtype)], axis=1
        )
        top_s = jnp.concatenate(
            [top_s, jnp.full((blk_b, k_pad - k), fill, top_s.dtype)], axis=1
        )
    out_ids_ref[...] = top_ids
    out_s_ref[...] = top_s


@functools.partial(
    jax.jit,
    static_argnames=("num_docs", "score", "k", "blk_b", "interpret"),
)
def _topk_gather(
    ids, cnts, df_t, df_n, *, num_docs, score, k, blk_b, interpret
):
    B, L = ids.shape
    L_pad = max(LANE, -(-L // LANE) * LANE)
    B_pad = -(-B // blk_b) * blk_b
    ids = jnp.pad(ids, ((0, B_pad - B), (0, L_pad - L)), constant_values=-1)
    cnts = jnp.pad(cnts, ((0, B_pad - B), (0, L_pad - L)))
    df_n = jnp.pad(df_n, ((0, B_pad - B), (0, L_pad - L)), constant_values=1)
    df_t = jnp.pad(df_t, ((0, B_pad - B), (0, 0)), constant_values=1)

    k_pad = max(LANE, -(-k // LANE) * LANE) if not interpret else k
    kernel = functools.partial(
        _topk_gather_kernel, k=k, k_pad=k_pad, score=score, num_docs=num_docs
    )
    s_dtype = jnp.int32 if score == "count" else jnp.float32
    top_ids, top_s = pl.pallas_call(
        kernel,
        grid=(B_pad // blk_b,),
        in_specs=[
            pl.BlockSpec((blk_b, L_pad), lambda b: (b, 0)),
            pl.BlockSpec((blk_b, L_pad), lambda b: (b, 0)),
            pl.BlockSpec((blk_b, 1), lambda b: (b, 0)),
            pl.BlockSpec((blk_b, L_pad), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((blk_b, k_pad), lambda b: (b, 0)),
            pl.BlockSpec((blk_b, k_pad), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, k_pad), jnp.int32),
            jax.ShapeDtypeStruct((B_pad, k_pad), s_dtype),
        ],
        interpret=interpret,
    )(ids, cnts, df_t, df_n)
    return top_ids[:B, :k], top_s[:B, :k]


def topk_gather(
    ids,
    cnts,
    df_t,
    df_n,
    *,
    num_docs: int,
    score: str = "count",
    k: int = 10,
    blk_b: int = 8,
    interpret: bool | None = None,
):
    """Top-k neighbours of a gathered candidate tile, fully on-device.

    Args:
        ids:   (B, L) int candidate term IDs, padded with -1.
        cnts:  (B, L) int pair counts (0 in padding slots).
        df_t:  (B,) or (B, 1) int document frequency of each queried term.
        df_n:  (B, L) int document frequency of each candidate (>= 1).
        num_docs: total documents in the store (a per-store constant — it is
            baked into the jitted launch, not shipped per call).
        score: "count" | "pmi" | "dice".
        k:     neighbours to return; must be <= L.
        blk_b: query rows per grid step.
        interpret: run the Pallas interpreter instead of compiling (None =
            auto: interpret everywhere except a real TPU backend, which is
            how CPU CI exercises the kernel).

    Returns:
        (top_ids (B, k) int32, top_scores (B, k) int32 or float32) — rows
        with fewer than k candidates padded with id -1 (score 0 for count,
        -inf otherwise). Bit-identical to the reference scorer.

    Example::

        ids  = np.array([[4, 9, -1, -1]])   # one row, two real candidates
        cnts = np.array([[3, 7,  0,  0]])
        top_ids, top_s = topk_gather(ids, cnts, np.array([5]),
                                     np.maximum(ids, 1), num_docs=100, k=2)
        # top_ids -> [[9, 4]], top_s -> [[7, 3]]
    """
    if score not in ("count", "pmi", "dice"):
        raise ValueError(f"unknown score {score!r}; have ('count', 'pmi', 'dice')")
    ids = jnp.asarray(np.asarray(ids), dtype=jnp.int32)
    cnts = jnp.asarray(np.asarray(cnts), dtype=jnp.int32)
    df_t = jnp.asarray(np.asarray(df_t), dtype=jnp.int32).reshape(ids.shape[0], 1)
    df_n = jnp.asarray(np.asarray(df_n), dtype=jnp.int32)
    if not 1 <= k <= ids.shape[1]:
        raise ValueError(f"k={k} must be in [1, L={ids.shape[1]}]")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _topk_gather(
        ids, cnts, df_t, df_n,
        num_docs=int(num_docs), score=score, k=int(k),
        blk_b=int(blk_b), interpret=bool(interpret),
    )
