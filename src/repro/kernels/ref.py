"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def cooc_gram_ref(b_i: jax.Array, b_j: jax.Array) -> jax.Array:
    """C[I,J] = B[:,I]ᵀ B[:,J] over 0/1 incidence tiles.

    b_i: (D, M), b_j: (D, N) — float (0/1 valued). Returns f32 (M, N).
    Exact for D < 2^24 (f32 integer range).
    """
    return jnp.einsum(
        "dm,dn->mn", b_i, b_j, preferred_element_type=jnp.float32
    ).astype(jnp.float32)


def bitpair_popcount_ref(rows_i: jax.Array, rows_j: jax.Array) -> jax.Array:
    """Pair intersection sizes over bit-packed posting bitmaps.

    rows_i: (M, W) uint32, rows_j: (N, W) uint32 — bit d of word w set iff the
    term occurs in document 32*w + d. Returns int32 (M, N) with
    out[m, n] = Σ_w popcount(rows_i[m, w] & rows_j[n, w]).
    """
    both = jnp.bitwise_and(rows_i[:, None, :], rows_j[None, :, :])
    return jax.lax.population_count(both).astype(jnp.int32).sum(axis=-1)


def segment_hist_ref(
    ids: jax.Array, seg: jax.Array, num_rows: int, vocab: int
) -> jax.Array:
    """Batched histogram (the LIST-SCAN accumulator): out[r, v] = #{l : seg[l]
    == r ∧ ids[l] == v}. Entries with seg < 0 or ids < 0 are padding."""
    valid = (seg >= 0) & (ids >= 0)
    flat = jnp.where(valid, seg * vocab + ids, num_rows * vocab)
    counts = jax.ops.segment_sum(
        jnp.where(valid, 1, 0).astype(jnp.int32),
        flat,
        num_segments=num_rows * vocab + 1,
    )
    return counts[:-1].reshape(num_rows, vocab)
