"""Sharded, atomic, async checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/manifest.json + one .npy per pytree leaf
         (per-host shard files when the array is sharded: leaf__shardK.npy).

Guarantees needed at 1000+-node scale:
  * atomicity — writes go to ``step_N.tmp`` and are renamed only after fsync;
    a crashed writer never leaves a ``step_N`` directory half-written,
    restart picks the newest complete step;
  * async — ``CheckpointManager.save_async`` snapshots device arrays to host
    memory synchronously (cheap) and writes in a background thread so the
    train loop never blocks on disk;
  * resharding restore — ``restore_checkpoint(..., shardings=...)`` re-lays
    the loaded arrays onto any target mesh (elastic restart after failures
    does not need the failed mesh topology);
  * self-describing — the manifest stores the pytree structure, shapes,
    dtypes and the writer's mesh so integrity can be verified before use.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the final step directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    names, leaves, _ = _flatten(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype == jax.numpy.bfloat16:  # np.save can't round-trip bf16
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"name": name, "file": fname, "shape": list(arr.shape), "dtype": logical_dtype}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, tree_like, shardings=None):
    """Restore into the structure of ``tree_like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    jax.sharding.Sharding for resharded (elastic) restore."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    _, leaves_like, treedef = _flatten(tree_like)
    if len(manifest["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target structure has {len(leaves_like)}"
        )
    arrays = []
    shard_leaves = (
        jax.tree.leaves(shardings, is_leaf=lambda s: hasattr(s, "addressable_devices"))
        if shardings is not None
        else [None] * len(leaves_like)
    )
    for rec, like, shard in zip(manifest["leaves"], leaves_like, shard_leaves):
        arr = np.load(os.path.join(path, rec["file"]))
        if rec["dtype"] == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16)
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"{rec['name']}: shape {arr.shape} != {like.shape}")
        if shard is not None:
            arrays.append(jax.device_put(arr, shard))
        else:
            arrays.append(jax.numpy.asarray(arr, dtype=like.dtype))
    return jax.tree.unflatten(treedef, arrays), manifest["extra"]


class CheckpointManager:
    """Async writer + retention policy."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save_async(self, step: int, tree, extra: dict | None = None):
        # snapshot to host memory synchronously — the device buffers may be
        # donated/overwritten by the next train step
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
