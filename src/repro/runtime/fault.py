"""Fault tolerance for the co-occurrence pipeline and the training loop.

The key structural property (DESIGN.md §6): the distributed Gram sum
C = Σ_s B_sᵀ B_s is a bag of independent, additive (shard × vocab-tile) work
units. Fault tolerance is therefore bookkeeping, not consensus:

  * ``WorkTracker`` — the (shard, tile) completion bitmap. Completed units
    are idempotent (each unit's contribution is added exactly once because
    the unit, not the worker, owns the accumulator slot).
  * ``HeartbeatMonitor`` — deadline-based failure/straggler detection. A unit
    leased past its deadline is re-enqueued (backup-task / speculative
    execution, MapReduce-style). Whichever completion lands first wins; the
    bitmap makes the second a no-op.
  * Training-side: the same tracker drives data-shard reassignment after an
    elastic re-mesh (runtime/elastic.py), and CheckpointManager provides the
    restart point.

Host-level logic (pure python/numpy) — on a real cluster the heartbeats come
from jax.distributed client liveness; here workers are simulated, which is
exactly what the unit tests exercise.

``SharedWorkTracker`` lifts the same lease discipline across **process
boundaries**: the tracker state lives in one JSON file mutated only under an
advisory ``flock`` (the same concurrency primitive the store manifest uses),
leases carry wall-clock TTL deadlines renewed by worker heartbeats, and a
lease past its deadline is reclaimed by whichever claimer sees it first — a
SIGKILL'd worker's shard is re-done, never lost. The parallel ingest
executor (core/plan.py) runs its spill-shard workers against it.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

try:
    import fcntl
except ImportError:  # non-POSIX: single-process use keeps working unlocked
    fcntl = None


@dataclasses.dataclass
class Lease:
    unit: tuple
    worker: str
    deadline: float


class WorkTracker:
    """Completion bitmap + lease table over independent work units."""

    def __init__(self, units):
        self.pending = list(units)
        self.leases: dict[tuple, Lease] = {}
        self.done: set[tuple] = set()
        self.completions_ignored = 0  # duplicate completions (backup tasks)

    # -- scheduling --
    def claim(self, worker: str, now: float, lease_seconds: float = 60.0):
        if not self.pending:
            # TTL expiry at claim time: a lease acquired and never renewed
            # must not block the unit forever under a second claimer — the
            # stale lease is reclaimed here, not only when the owner's own
            # scheduling loop happens to call expire()
            self.expire(now)
        if not self.pending:
            return None
        unit = self.pending.pop(0)
        self.leases[unit] = Lease(unit, worker, now + lease_seconds)
        return unit

    def complete(self, unit: tuple, worker: str) -> bool:
        """Returns True iff this completion is the FIRST for the unit (the
        caller may then add its contribution to the accumulator)."""
        if unit in self.done:
            self.completions_ignored += 1
            return False
        self.done.add(unit)
        self.leases.pop(unit, None)
        return True

    # -- failure & straggler handling --
    def expire(self, now: float) -> list[tuple]:
        """Re-enqueue units whose lease expired (dead or straggling worker)."""
        expired = [l.unit for l in self.leases.values() if l.deadline < now]
        for u in expired:
            del self.leases[u]
        # retry-first: expired units jump the queue (backup-task semantics)
        self.pending = expired + self.pending
        return expired

    def fail_worker(self, worker: str) -> list[tuple]:
        """Immediately re-enqueue everything leased to a known-dead worker."""
        units = [l.unit for l in self.leases.values() if l.worker == worker]
        for u in units:
            del self.leases[u]
        self.pending = units + self.pending  # retry-first
        return units

    @property
    def finished(self) -> bool:
        return not self.pending and not self.leases

    def state(self) -> dict:
        """Serializable snapshot (checkpointed alongside the accumulator)."""
        return {
            "pending": [list(u) for u in self.pending],
            "leased": [list(l.unit) for l in self.leases.values()],
            "done": [list(u) for u in sorted(self.done)],
        }

    @classmethod
    def from_state(cls, state: dict) -> "WorkTracker":
        t = cls([])
        # leased units were in flight at checkpoint time → re-enqueue
        t.pending = [tuple(u) for u in state["pending"]] + [
            tuple(u) for u in state["leased"]
        ]
        t.done = {tuple(u) for u in state["done"]}
        return t


class SharedWorkTracker:
    """The WorkTracker lease discipline, shared across processes via one
    flock'd JSON state file.

    Every mutation is a read-modify-write of ``path`` under an exclusive
    advisory lock on ``path + ".lock"`` (state itself is replaced
    atomically, so crash mid-write never corrupts it). Leases carry
    wall-clock (``time.time``) TTL deadlines: ``claim`` first re-enqueues
    every lease past its deadline (reclaim), workers extend their own lease
    with ``renew`` heartbeats while a unit is in flight, and ``complete``
    runs an optional ``commit`` callable under the lock *before* recording
    the unit done — so an atomic rename (promoting a worker's finished
    spill directory) and the completion record can never be observed apart.

    Example::

        t = SharedWorkTracker.create("/tmp/claims.json", [(0,), (1,)],
                                     lease_seconds=30.0)
        u = t.claim("w0")
        t.renew(u, "w0")            # heartbeat while working
        t.complete(u, "w0")         # first completion wins
    """

    def __init__(self, path: str, *, lease_seconds: float = 30.0):
        self.path = path
        self.lease_seconds = float(lease_seconds)

    # ------------------------------------------------------------ lifecycle
    @classmethod
    def create(cls, path: str, units, *, lease_seconds: float = 30.0
               ) -> "SharedWorkTracker":
        """Initialize the state file with ``units`` all pending (overwrites
        any previous state at ``path``)."""
        t = cls(path, lease_seconds=lease_seconds)
        t._write_state(
            {
                "pending": [list(u) for u in units],
                "leases": {},          # key -> {worker, deadline}
                "done": [],
                "reclaims": 0,
                "completions_ignored": 0,
            }
        )
        return t

    @classmethod
    def open(cls, path: str, *, lease_seconds: float = 30.0
             ) -> "SharedWorkTracker":
        if not os.path.exists(path):
            raise FileNotFoundError(path)
        return cls(path, lease_seconds=lease_seconds)

    # ------------------------------------------------------------ low level
    @staticmethod
    def _key(unit: tuple) -> str:
        return json.dumps(list(unit))

    def _lock(self):
        lf = open(self.path + ".lock", "a")
        if fcntl is not None:
            fcntl.flock(lf, fcntl.LOCK_EX)
        return lf  # closing the handle releases the flock

    def _read_state(self) -> dict:
        with open(self.path) as f:
            return json.load(f)

    def _write_state(self, state: dict) -> None:
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, self.path)

    def _expire_locked(self, state: dict, now: float) -> None:
        stale = [k for k, l in state["leases"].items() if l["deadline"] < now]
        for k in stale:
            del state["leases"][k]
            # retry-first: reclaimed units jump the queue (backup tasks)
            state["pending"].insert(0, json.loads(k))
            state["reclaims"] += 1

    # ----------------------------------------------------------- scheduling
    def claim(self, worker: str) -> tuple | None:
        """Claim the next pending unit (expired leases are reclaimed first).
        Returns None when nothing is claimable right now — the caller should
        check :attr:`finished` and otherwise wait for a lease to expire."""
        lf = self._lock()
        try:
            state = self._read_state()
            self._expire_locked(state, time.time())
            if not state["pending"]:
                self._write_state(state)  # persist any reclaim bookkeeping
                return None
            unit = tuple(state["pending"].pop(0))
            state["leases"][self._key(unit)] = {
                "worker": worker,
                "deadline": time.time() + self.lease_seconds,
            }
            self._write_state(state)
            return unit
        finally:
            lf.close()

    def renew(self, unit: tuple, worker: str) -> bool:
        """Heartbeat: extend this worker's lease on ``unit``. Returns False
        when the lease was lost (expired and reclaimed, or completed) — the
        worker should abandon the unit (its completion would be ignored)."""
        lf = self._lock()
        try:
            state = self._read_state()
            lease = state["leases"].get(self._key(unit))
            if lease is None or lease["worker"] != worker:
                return False
            lease["deadline"] = time.time() + self.lease_seconds
            self._write_state(state)
            return True
        finally:
            lf.close()

    def complete(self, unit: tuple, worker: str, commit=None) -> bool:
        """First completion wins. When this is the first, ``commit()`` (if
        given) runs under the tracker lock *before* the unit is recorded
        done — its side effect (e.g. an atomic directory rename) and the
        done-record are mutually consistent for every other process."""
        lf = self._lock()
        try:
            state = self._read_state()
            if list(unit) in state["done"]:
                state["completions_ignored"] += 1
                self._write_state(state)
                return False
            if commit is not None:
                commit()
            state["leases"].pop(self._key(unit), None)
            state["done"].append(list(unit))
            self._write_state(state)
            return True
        finally:
            lf.close()

    def requeue(self, unit: tuple) -> None:
        """Force a unit back to pending (recovery: its committed artifact
        went missing). Drops any done-record and lease for it."""
        lf = self._lock()
        try:
            state = self._read_state()
            state["done"] = [u for u in state["done"] if u != list(unit)]
            state["leases"].pop(self._key(unit), None)
            if list(unit) not in state["pending"]:
                state["pending"].insert(0, list(unit))
            self._write_state(state)
        finally:
            lf.close()

    # ------------------------------------------------------------- queries
    def snapshot(self) -> dict:
        """A point-in-time copy of the shared state (no lock: reads see
        some complete, atomically-replaced state)."""
        return self._read_state()

    @property
    def finished(self) -> bool:
        s = self._read_state()
        return not s["pending"] and not s["leases"]

    def done_units(self) -> set[tuple]:
        return {tuple(u) for u in self._read_state()["done"]}

    @property
    def reclaims(self) -> int:
        return int(self._read_state()["reclaims"])


class HeartbeatMonitor:
    """Deadline-based liveness. Workers ping; silence past ``timeout`` marks
    them dead; ``slow_factor``× the median completion time marks a straggler
    (which triggers a backup task, not a kill)."""

    def __init__(self, timeout: float = 30.0, slow_factor: float = 3.0):
        self.timeout = timeout
        self.slow_factor = slow_factor
        self.last_seen: dict[str, float] = {}
        self.durations: list[float] = []

    def ping(self, worker: str, now: float | None = None):
        self.last_seen[worker] = time.monotonic() if now is None else now

    def record_duration(self, seconds: float):
        self.durations.append(seconds)

    def dead_workers(self, now: float) -> list[str]:
        return [w for w, t in self.last_seen.items() if now - t > self.timeout]

    def straggler_deadline(self) -> float:
        """Lease duration adapted to observed completion times."""
        if not self.durations:
            return self.timeout
        med = sorted(self.durations)[len(self.durations) // 2]
        return max(self.slow_factor * med, 1e-3)
