"""Fault tolerance for the co-occurrence pipeline and the training loop.

The key structural property (DESIGN.md §6): the distributed Gram sum
C = Σ_s B_sᵀ B_s is a bag of independent, additive (shard × vocab-tile) work
units. Fault tolerance is therefore bookkeeping, not consensus:

  * ``WorkTracker`` — the (shard, tile) completion bitmap. Completed units
    are idempotent (each unit's contribution is added exactly once because
    the unit, not the worker, owns the accumulator slot).
  * ``HeartbeatMonitor`` — deadline-based failure/straggler detection. A unit
    leased past its deadline is re-enqueued (backup-task / speculative
    execution, MapReduce-style). Whichever completion lands first wins; the
    bitmap makes the second a no-op.
  * Training-side: the same tracker drives data-shard reassignment after an
    elastic re-mesh (runtime/elastic.py), and CheckpointManager provides the
    restart point.

Host-level logic (pure python/numpy) — on a real cluster the heartbeats come
from jax.distributed client liveness; here workers are simulated, which is
exactly what the unit tests exercise.
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class Lease:
    unit: tuple
    worker: str
    deadline: float


class WorkTracker:
    """Completion bitmap + lease table over independent work units."""

    def __init__(self, units):
        self.pending = list(units)
        self.leases: dict[tuple, Lease] = {}
        self.done: set[tuple] = set()
        self.completions_ignored = 0  # duplicate completions (backup tasks)

    # -- scheduling --
    def claim(self, worker: str, now: float, lease_seconds: float = 60.0):
        if not self.pending:
            return None
        unit = self.pending.pop(0)
        self.leases[unit] = Lease(unit, worker, now + lease_seconds)
        return unit

    def complete(self, unit: tuple, worker: str) -> bool:
        """Returns True iff this completion is the FIRST for the unit (the
        caller may then add its contribution to the accumulator)."""
        if unit in self.done:
            self.completions_ignored += 1
            return False
        self.done.add(unit)
        self.leases.pop(unit, None)
        return True

    # -- failure & straggler handling --
    def expire(self, now: float) -> list[tuple]:
        """Re-enqueue units whose lease expired (dead or straggling worker)."""
        expired = [l.unit for l in self.leases.values() if l.deadline < now]
        for u in expired:
            del self.leases[u]
        # retry-first: expired units jump the queue (backup-task semantics)
        self.pending = expired + self.pending
        return expired

    def fail_worker(self, worker: str) -> list[tuple]:
        """Immediately re-enqueue everything leased to a known-dead worker."""
        units = [l.unit for l in self.leases.values() if l.worker == worker]
        for u in units:
            del self.leases[u]
        self.pending = units + self.pending  # retry-first
        return units

    @property
    def finished(self) -> bool:
        return not self.pending and not self.leases

    def state(self) -> dict:
        """Serializable snapshot (checkpointed alongside the accumulator)."""
        return {
            "pending": [list(u) for u in self.pending],
            "leased": [list(l.unit) for l in self.leases.values()],
            "done": [list(u) for u in sorted(self.done)],
        }

    @classmethod
    def from_state(cls, state: dict) -> "WorkTracker":
        t = cls([])
        # leased units were in flight at checkpoint time → re-enqueue
        t.pending = [tuple(u) for u in state["pending"]] + [
            tuple(u) for u in state["leased"]
        ]
        t.done = {tuple(u) for u in state["done"]}
        return t


class HeartbeatMonitor:
    """Deadline-based liveness. Workers ping; silence past ``timeout`` marks
    them dead; ``slow_factor``× the median completion time marks a straggler
    (which triggers a backup task, not a kill)."""

    def __init__(self, timeout: float = 30.0, slow_factor: float = 3.0):
        self.timeout = timeout
        self.slow_factor = slow_factor
        self.last_seen: dict[str, float] = {}
        self.durations: list[float] = []

    def ping(self, worker: str, now: float | None = None):
        self.last_seen[worker] = time.monotonic() if now is None else now

    def record_duration(self, seconds: float):
        self.durations.append(seconds)

    def dead_workers(self, now: float) -> list[str]:
        return [w for w, t in self.last_seen.items() if now - t > self.timeout]

    def straggler_deadline(self) -> float:
        """Lease duration adapted to observed completion times."""
        if not self.durations:
            return self.timeout
        med = sorted(self.durations)[len(self.durations) // 2]
        return max(self.slow_factor * med, 1e-3)
