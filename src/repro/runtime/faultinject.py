"""Env-gated fault injection: named failpoints for resilience testing.

Fault tolerance that was never exercised is hope, not engineering. This
registry lets tests and benchmarks *script* failures into the serving
layer (store/serving.py) — and any future subsystem — without patching
code: a failpoint is a named hook compiled into the hot path that does
nothing unless the ``REPRO_FAULTS`` environment variable arms it. The env
var is the transport on purpose: serving workers are **spawned** processes
that inherit ``os.environ``, so one assignment in the test process arms
the same schedule in every worker it launches.

Spec format — semicolon-separated ``name=arg`` entries::

    REPRO_FAULTS="kill-worker=1:3;stall-queue=0:0.25:2"

Args are colon-separated fields; a leading ``wid`` field — only recognized
when two or more fields are present — scopes the point to one worker
(``*`` or omitted = every worker; a lone field is always the value, so
``drop-response=4`` means N=4 on any worker, not worker 4). The serving
failpoints:

| failpoint       | arg                | effect                               |
|-----------------|--------------------|--------------------------------------|
| ``kill-worker`` | ``[wid:]N``        | SIGKILL self when the worker has     |
|                 |                    | completed ``N`` micro-batches and    |
|                 |                    | claims the next one (mid-flight)     |
| ``stall-queue`` | ``[wid:]S[:N]``    | sleep ``S`` seconds before each of   |
|                 |                    | the next ``N`` batches (default 1) — |
|                 |                    | the queue backs up behind the stall  |
| ``drop-response``| ``[wid:]N[:skip]``| silently discard the worker's next   |
|                 |                    | ``N`` answer messages after letting  |
|                 |                    | ``skip`` through (claims still flow, |
|                 |                    | so supervision stays honest)         |

Disarmed (the default — ``REPRO_FAULTS`` unset or empty) every check is
one dict lookup on an empty registry; nothing is configured, parsed, or
counted. Points are **per-process**: each worker parses the env var once
at startup, and hit counters (the "after N" state) live in that process.

Example::

    >>> fr = FaultRegistry("kill-worker=1:3;stall-queue=0.25")
    >>> fr.active("kill-worker"), fr.active("nope")
    (True, False)
    >>> fr.kill_worker(worker=1, batches_done=2)   # not yet
    False
    >>> fr.stall_queue(worker=0)                   # unscoped: any worker
    0.25
    >>> fr.stall_queue(worker=0)                   # budget of 1 spent
    0.0
    >>> FaultRegistry("").active("kill-worker")    # disarmed registry
    False
"""

from __future__ import annotations

import os
import time

ENV_VAR = "REPRO_FAULTS"

# serving failpoint names (the registry itself is name-agnostic; these are
# the points store/serving.py compiles in)
KILL_WORKER = "kill-worker"
STALL_QUEUE = "stall-queue"
DROP_RESPONSE = "drop-response"

_ANY = None  # unscoped wid field


def _parse_arg(arg: str) -> tuple[int | None, list[str]]:
    """Split ``[wid:]fields...`` — a leading integer field is a worker
    scope only when more fields follow it (a lone field is always the
    value); ``*`` (or a leading non-integer) means every worker."""
    fields = arg.split(":") if arg else []
    if not fields:
        return _ANY, []
    if fields[0] == "*":
        return _ANY, fields[1:]
    if len(fields) >= 2:
        try:
            return int(fields[0]), fields[1:]
        except ValueError:
            return _ANY, fields
    return _ANY, fields


class FaultRegistry:
    """Parsed failpoint schedule of one process.

    ``active(name)`` is the cheap guard call sites use before doing any
    work; the named helpers (:meth:`kill_worker`, :meth:`stall_queue`,
    :meth:`drop_response`) implement the serving failpoints' trigger
    semantics, including their per-process hit budgets.
    """

    def __init__(self, spec: str = ""):
        self.spec = spec or ""
        self._points: dict[str, tuple[int | None, list[str]]] = {}
        self._hits: dict[str, int] = {}
        for entry in self.spec.split(";"):
            entry = entry.strip()
            if not entry:
                continue
            name, _, arg = entry.partition("=")
            self._points[name.strip()] = _parse_arg(arg.strip())

    def __bool__(self) -> bool:
        return bool(self._points)

    def active(self, name: str) -> bool:
        return name in self._points

    def _scoped(self, name: str, worker: int) -> list[str] | None:
        """The point's fields if it is armed for ``worker``, else None."""
        point = self._points.get(name)
        if point is None:
            return None
        wid, fields = point
        if wid is not None and wid != worker:
            return None
        return fields

    # ------------------------------------------------- serving failpoints
    def kill_worker(self, *, worker: int, batches_done: int) -> bool:
        """True when this worker should die: it has completed ``N``
        batches (arg) and is claiming another. The caller SIGKILLs itself
        — after flushing its claim, so supervision sees the in-flight
        requests it strands."""
        fields = self._scoped(KILL_WORKER, worker)
        if fields is None:
            return False
        after = int(fields[0]) if fields else 0
        return batches_done >= after

    def stall_queue(self, *, worker: int) -> float:
        """Seconds to stall before serving the next batch — ``S`` for each
        of the first ``N`` triggers (default 1), then 0.0. The queue backs
        up behind the sleep, which is how tests script overload."""
        fields = self._scoped(STALL_QUEUE, worker)
        if not fields:
            return 0.0
        seconds = float(fields[0])
        budget = int(fields[1]) if len(fields) > 1 else 1
        key = f"{STALL_QUEUE}:{worker}"
        if self._hits.get(key, 0) >= budget:
            return 0.0
        self._hits[key] = self._hits.get(key, 0) + 1
        return seconds

    def drop_response(self, *, worker: int) -> bool:
        """True for the worker's next ``N`` answer messages after letting
        the first ``skip`` (default 0) pass: the caller discards them
        instead of enqueueing, simulating a lost response — e.g. a stream
        whose first chunk arrives and whose tail never does (the client's
        deadline or the supervisor, not its patience, must save it)."""
        fields = self._scoped(DROP_RESPONSE, worker)
        if fields is None:
            return False
        budget = int(fields[0]) if fields else 1
        skip = int(fields[1]) if len(fields) > 1 else 0
        key = f"{DROP_RESPONSE}:{worker}"
        n = self._hits.get(key, 0)
        self._hits[key] = n + 1
        return skip <= n < skip + budget


_DISARMED = FaultRegistry("")


def from_env() -> FaultRegistry:
    """The process's fault schedule, parsed fresh from ``REPRO_FAULTS``
    (workers call this once at startup; tests re-call it after mutating
    the env). Returns a shared disarmed registry when unset."""
    spec = os.environ.get(ENV_VAR, "")
    return FaultRegistry(spec) if spec else _DISARMED


def kill_self(*, flush_s: float = 0.1) -> None:  # pragma: no cover - dies
    """SIGKILL the current process after a short pause that lets mp-queue
    feeder threads flush buffered messages (the claim a supervisor needs
    must reach the pipe before the process vanishes)."""
    import signal

    time.sleep(flush_s)
    os.kill(os.getpid(), signal.SIGKILL)
