"""Distributed runtime: sharding rules, fault tolerance, elastic re-meshing."""
