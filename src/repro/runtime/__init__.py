"""Distributed runtime: sharding rules, fault tolerance, elastic re-meshing,
and env-gated fault injection (``runtime/faultinject.py``) for scripting
failures into resilience tests and benchmarks."""
