"""Elastic re-meshing: rebuild the device mesh after node loss/gain.

Policy: keep the tensor-parallel ("model") extent fixed if possible (its
sharding is baked into weight layouts and collectives are latency-critical),
shrink the data/pod extents to the largest grid that fits the surviving
device count, park the remainder as hot spares. Restart = restore the last
checkpoint with the new mesh's shardings (checkpoint/checkpoint.py supports
resharded restore) and rebalance the data shards (runtime/fault.WorkTracker).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple
    axis_names: tuple
    spares: int

    @property
    def num_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_mesh(
    n_devices: int,
    model_parallel: int = 16,
    axis_names: tuple = ("data", "model"),
) -> MeshPlan:
    """Largest (data, model) grid with the requested model extent; if fewer
    than ``model_parallel`` devices survive, degrade model parallelism to the
    largest power of two that fits."""
    mp = model_parallel
    while mp > 1 and n_devices < mp:
        mp //= 2
    data = max(n_devices // mp, 1)
    return MeshPlan((data, mp), axis_names, spares=n_devices - data * mp)


def build_mesh(plan: MeshPlan, devices=None) -> jax.sharding.Mesh:
    devices = list(jax.devices()) if devices is None else list(devices)
    use = np.array(devices[: plan.num_devices]).reshape(plan.shape)
    return jax.sharding.Mesh(use, plan.axis_names)


def rebalance_shards(num_shards: int, old_workers: list, new_workers: list) -> dict:
    """Deterministic shard → worker assignment that minimizes movement:
    shards whose old owner survived stay put; orphaned shards round-robin
    onto the least-loaded survivors."""
    old_assign = {s: old_workers[s % len(old_workers)] for s in range(num_shards)}
    load: dict = {w: 0 for w in new_workers}
    assign = {}
    orphans = []
    for s, w in old_assign.items():
        if w in load:
            assign[s] = w
            load[w] += 1
        else:
            orphans.append(s)
    for s in orphans:
        w = min(load, key=lambda k: (load[k], str(k)))
        assign[s] = w
        load[w] += 1
    return assign
