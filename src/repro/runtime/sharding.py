"""Sharding rules: parameter-path → PartitionSpec.

Layout policy (MaxText-flavoured):
  * "model" axis: tensor parallel (attention heads / FFN width / expert axis
    / vocab / embedding rows);
  * remaining axes ("data", and "pod" when multi-pod): FSDP — weights are
    additionally sliced along their d_model-adjacent dimension and
    all-gathered per layer inside the scanned block;
  * norms/biases replicate (tiny).

GSPMD pads non-divisible dimensions (e.g. MiniCPM's 73448 vocab over 16-way
model) — divisibility is only required in our own shard_map code paths.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import PartitionSpec as P


def set_mesh_compat(mesh):
    """jax.set_mesh where it exists. On pre-0.5 releases, combine the legacy
    resource-env context (``with mesh`` — what with_sharding_constraint
    consults) with set_abstract_mesh (what maybe_shard consults) WITHOUT the
    experimental sharding_in_types flag jax._src.mesh.set_mesh flips there,
    which breaks plain jnp indexing during tracing."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    from jax._src.mesh import set_abstract_mesh

    @contextlib.contextmanager
    def _ctx():
        with mesh, set_abstract_mesh(mesh.abstract_mesh):
            yield

    return _ctx()


def fsdp_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")


def _lm_rule(name: str, ndim: int, fsdp, model="model"):
    # name is the leaf key, arrays carry a leading L (layers) axis when ndim
    # is one higher than the logical matrix
    lead = (None,) * (ndim - 2)
    if name in ("embed",):
        return P(model, fsdp)
    if name in ("out_head",):
        return P(fsdp, model)
    if name in ("proj",):  # MTP projection (2d, d)
        return P(fsdp, None)
    if name in ("wq", "wk", "wv", "w_in", "w_gate", "w_uq", "sh_w_in", "sh_w_gate"):
        return P(*lead, fsdp, model)
    if name in ("wo", "w_out", "sh_w_out"):
        return P(*lead, model, fsdp)
    if name in ("w_dq", "w_dkv", "w_kr"):
        return P(*lead, fsdp, None)
    if name in ("w_uk", "w_uv"):
        return P(*lead, None, model)
    if name == "router":
        return P(*((None,) * ndim))  # small; replicated (shard_map in_spec)
    if name in ("moe_w_in", "moe_w_gate"):  # (L, E, d, f)
        return P(*lead[:-1], model, fsdp, None)
    if name == "moe_w_out":  # (L, E, f, d)
        return P(*lead[:-1], model, None, fsdp)
    if name in ("bq", "bk", "bv"):
        return P(*lead, model)
    # norms, small vectors
    return P(*((None,) * ndim))


def lm_param_specs(shapes_tree, mesh) -> dict:
    """shapes_tree: pytree of shape-tuples (models.transformer.param_shapes)."""
    fsdp = fsdp_axes(mesh)

    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            else:
                out[k] = _lm_rule(k, len(v), fsdp)
        return out

    return walk(shapes_tree)


def gnn_param_specs(shapes_tree, mesh) -> dict:
    """GraphSAGE weights are small → replicate everything."""

    def walk(tree):
        if isinstance(tree, dict):
            return {k: walk(v) for k, v in tree.items()}
        return P(*((None,) * len(tree)))

    return walk(shapes_tree)


def recsys_param_specs(shapes_tree, mesh) -> dict:
    """Embedding tables row-shard over "model" (embedding parallelism — the
    recsys analogue of expert parallelism); dense towers replicate (they are
    ≤ a few MB and used by every example)."""

    def walk(tree, key=""):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, key) for v in tree]
        big_table = key in ("item_embed", "cat_embed", "embed", "linear")
        if big_table:
            return P("model", *((None,) * (len(tree) - 1)))
        return P(*((None,) * len(tree)))

    return walk(shapes_tree)


def attach(mesh, specs_tree, shapes_tree, dtype_tree=None, default_dtype="float32"):
    """shape tree + spec tree → pytree of sharded ShapeDtypeStructs."""
    from jax.sharding import NamedSharding

    def leaf(shape, spec):
        import jax.numpy as jnp

        return jax.ShapeDtypeStruct(
            shape, jnp.dtype(default_dtype), sharding=NamedSharding(mesh, spec)
        )

    return jax.tree.map(
        leaf, shapes_tree, specs_tree, is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(i, int) for i in x)
    )
