"""Shared setup for spawning repro worker processes.

Both the serving workers (store/serving.py) and the background compaction
worker (store/segments.py) **spawn** (never fork: JAX runtimes do not
survive a fork) and re-import the repro package from scratch in the child.
That re-import has two environmental footguns, fixed here once:

* the parent may have made ``repro`` importable via ``sys.path`` (a
  conftest, an editable checkout) rather than ``PYTHONPATH`` — the child
  would not inherit that, so the package root is pushed into
  ``PYTHONPATH`` for the duration of the spawns;
* spawn re-runs the parent's ``__main__`` in every child when the parent
  is a plain script (no module spec): an unguarded script would re-execute
  top-level code per child, and an interactive/stdin parent has a phantom
  ``"<stdin>"`` path the child cannot open. Workers import everything from
  repro and need nothing from ``__main__``, so its file path is hidden
  while the children launch.
"""

from __future__ import annotations

import contextlib
import multiprocessing as mp
import os
import sys


@contextlib.contextmanager
def spawn_friendly_env():
    """Yield a spawn multiprocessing context with the environment patched
    so children can re-import repro; restores everything on exit (children
    launched inside the block keep running after it)."""
    ctx = mp.get_context("spawn")
    import repro

    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    old_pp = os.environ.get("PYTHONPATH")
    parts = old_pp.split(os.pathsep) if old_pp else []
    if src_root not in parts:
        os.environ["PYTHONPATH"] = os.pathsep.join([src_root] + parts)
    main_mod = sys.modules.get("__main__")
    hide_main = (
        main_mod is not None
        and getattr(main_mod, "__spec__", None) is None
        and getattr(main_mod, "__file__", None) is not None
    )
    saved_main_file = main_mod.__file__ if hide_main else None
    if hide_main:
        del main_mod.__file__
    try:
        yield ctx
    finally:
        if old_pp is None:
            os.environ.pop("PYTHONPATH", None)
        else:
            os.environ["PYTHONPATH"] = old_pp
        if hide_main:
            main_mod.__file__ = saved_main_file
