"""Typed query requests, query planning, and the one execution path.

Read-side counterpart of the counting planner (core/plan.py): a query is a
frozen, validated request object, a batch of requests is turned into an
executable :class:`QueryPlan` by the :class:`QueryPlanner`, and one shared
executor (:func:`execute_groups`) answers the plan — the same code whether
the caller is the in-process :class:`~repro.store.query.QueryEngine` or a
serving worker process (store/serving.py). The request objects **are** the
wire protocol: a client pickles the exact dataclasses the engine executes,
so invalid queries (unknown score, bad dtype, k < 1) fail at construction
on the client, never mid-batch inside a worker.

    requests ──▶ QueryPlanner.plan() ──▶ QueryPlan ──▶ execute_groups()
       │               │                     │
       │               │                     └─ coalescing groups: one kernel
       │               │                        launch per (k, score) group
       │               └─ hot-term routing: terms hashed to workers so
       │                  per-worker LRU caches partition the vocabulary
       └─ TopKRequest | PairCountsRequest | NeighboursRequest
          (validated at construction; frozen; picklable)

**Hot-term routing.** With ``routing=True`` the planner splits each top-k
request by term ownership: term ``t`` belongs to worker
``(t * 2654435761 mod 2**32) * workers >> 32`` (Knuth's multiplicative
hash with multiply-shift range reduction — deterministic across processes
and Python runs, no seed). Every query for a
given term therefore lands on the same worker, so N per-worker LRU row
caches hold N disjoint slices of the vocabulary instead of N copies of the
Zipf head. The client reassembles per-worker partial results by the
``positions`` recorded in each :class:`RoutedPart` — reassembly is
byte-identical to the unsplit answer (same scores, ids, tie order, padding;
see docs/serving.md).

**Streaming top-k.** A :class:`TopKRequest` with ``chunk=c`` answers as an
iterator of score-ordered ``(ids, scores)`` column blocks of width ≤ c
instead of one monolithic ``(B, k)`` pair — large-k responses cross the
process boundary chunk by chunk. Concatenating the chunks along axis 1
reproduces the monolithic result exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

SCORES = ("count", "pmi", "dice")
KERNELS = ("numpy", "pallas")

# Knuth's multiplicative hash constant (2^32 / phi); see route_term().
_ROUTE_MULT = 2654435761


# ---------------------------------------------------------------------------
# request types (the wire protocol)
# ---------------------------------------------------------------------------


def _as_terms(terms) -> np.ndarray:
    """Normalize to a 1-D int64 term-id array; reject non-integer dtypes."""
    t = np.atleast_1d(np.asarray(terms))
    if t.ndim != 1:
        raise ValueError(f"terms must be 1-D, got shape {t.shape}")
    if t.size and not np.issubdtype(t.dtype, np.integer):
        raise ValueError(
            f"terms must be integer term ids, got dtype {t.dtype}"
        )
    return np.ascontiguousarray(t, dtype=np.int64)


@dataclasses.dataclass(frozen=True, eq=False)
class TopKRequest:
    """Top-k neighbours of a batch of terms, scored by count/PMI/Dice.

    Validation happens at construction — an unknown ``score``, ``k < 1``, a
    float ``terms`` dtype, or ``chunk < 1`` raise here, on the client, not
    inside a serving worker mid-batch. ``chunk`` turns the response into a
    stream of score-ordered column blocks (see module docstring).

    Example::

        req = TopKRequest([3, 17], k=10, score="pmi")
        ids, scores = engine.execute([req])[0]
    """

    terms: np.ndarray
    k: int = 10
    score: str = "count"
    chunk: int | None = None          # None = monolithic; else stream width

    def __post_init__(self):
        object.__setattr__(self, "terms", _as_terms(self.terms))
        if not isinstance(self.k, (int, np.integer)) or self.k < 1:
            raise ValueError(f"k must be an int >= 1, got {self.k!r}")
        if self.score not in SCORES:
            raise ValueError(f"unknown score {self.score!r}; have {SCORES}")
        if self.chunk is not None and (
            not isinstance(self.chunk, (int, np.integer)) or self.chunk < 1
        ):
            raise ValueError(f"chunk must be an int >= 1, got {self.chunk!r}")

    @property
    def batch(self) -> int:
        return len(self.terms)


@dataclasses.dataclass(frozen=True, eq=False)
class PairCountsRequest:
    """Exact co-occurrence counts for a ``(B, 2)`` batch of term pairs.

    Example::

        req = PairCountsRequest(np.array([[3, 17], [5, 5]]))
        counts = engine.execute([req])[0]
    """

    pairs: np.ndarray

    def __post_init__(self):
        p = np.asarray(self.pairs)
        if p.ndim == 1 and p.shape == (2,):
            p = p[None, :]
        if p.ndim != 2 or p.shape[1] != 2:
            raise ValueError(f"pairs must have shape (B, 2), got {p.shape}")
        if p.size and not np.issubdtype(p.dtype, np.integer):
            raise ValueError(
                f"pairs must be integer term ids, got dtype {p.dtype}"
            )
        object.__setattr__(self, "pairs", np.ascontiguousarray(p, dtype=np.int64))

    @property
    def batch(self) -> int:
        return len(self.pairs)


@dataclasses.dataclass(frozen=True, eq=False)
class NeighboursRequest:
    """The full merged ``(neighbour_ids, counts)`` row of one term.

    Example::

        ids, counts = engine.execute([NeighboursRequest(3)])[0]
    """

    term: int

    def __post_init__(self):
        if not isinstance(self.term, (int, np.integer)):
            raise ValueError(
                f"term must be an integer id, got {type(self.term).__name__}"
            )
        object.__setattr__(self, "term", int(self.term))


QueryRequest = TopKRequest | PairCountsRequest | NeighboursRequest


# ---------------------------------------------------------------------------
# wire envelopes
# ---------------------------------------------------------------------------


def make_envelope(
    client_id: int,
    request_id: int,
    part: int,
    parts: int,
    request,
    *,
    t_submit: float | None = None,
    deadline: float | None = None,
) -> tuple:
    """One wire envelope, the unit that crosses a serving request queue:

        (client_id, request_id, part, parts, request, t_submit, deadline)

    ``t_submit`` is the client's submit wall-clock (unix time — the one
    clock two processes share; queue-wait histograms subtract it) and
    ``deadline`` the absolute unix time after which the client has given
    up: a worker dequeueing an expired envelope answers it with a typed
    ``deadline_expired`` error instead of burning a kernel launch on a
    response nobody is waiting for. Both trailing fields are optional —
    :func:`envelope_times` accepts legacy 5-tuples.

    Example::

        env = make_envelope(0, 7, 0, 1, TopKRequest([3]), deadline=1e12)
        envelope_times(env)[1] == 1e12   # True
    """
    return (client_id, request_id, part, parts, request, t_submit, deadline)


def envelope_times(envelope) -> tuple[float | None, float | None]:
    """``(t_submit, deadline)`` of a wire envelope; short (legacy,
    hand-built) tuples yield ``(None, None)`` — both features degrade to
    "not measured" / "no deadline" rather than failing."""
    t_submit = envelope[5] if len(envelope) > 5 else None
    deadline = envelope[6] if len(envelope) > 6 else None
    return t_submit, deadline


def check_request_types(requests) -> None:
    """Raise TypeError unless every element is one of the request types."""
    for r in requests:
        if not isinstance(
            r, (TopKRequest, PairCountsRequest, NeighboursRequest)
        ):
            raise TypeError(
                f"not a query request: {type(r).__name__} (have "
                "TopKRequest, PairCountsRequest, NeighboursRequest)"
            )


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def default_kernel() -> str:
    """Backend-appropriate score-and-select kernel: the fused Pallas path on
    TPU, the jitted reference elsewhere (off-TPU the Pallas kernel runs in
    interpreter mode — bit-identical but slow)."""
    try:
        import jax

        return "pallas" if jax.default_backend() == "tpu" else "numpy"
    except Exception:  # pragma: no cover - jax always present in this repo
        return "numpy"


def route_term(t: int, workers: int) -> int:
    """The worker that owns term ``t``'s cache row:
    ``(t * 2654435761 mod 2**32) * workers >> 32``.

    Knuth multiplicative hash with multiply-shift range reduction — the
    reduction reads the product's *high* bits, which the multiplier mixes
    well for any worker count (a plain ``% workers`` would read the low
    bits, and 2654435761 ≡ 1 mod 16, collapsing to ``t % workers`` for
    power-of-two worker counts). Stable across processes/runs (no seed, no
    Python hash randomization), so the client-side planner and any
    diagnostic tooling agree on placement without coordination.

    Example::

        route_term(42, 4) == route_term(42, 4)   # always
    """
    return (int(t) * _ROUTE_MULT % (1 << 32)) * workers >> 32


def route_terms(terms: np.ndarray, workers: int) -> np.ndarray:
    """Vectorized :func:`route_term` (identical placement)."""
    t = np.asarray(terms, dtype=np.uint64)
    h = (t * np.uint64(_ROUTE_MULT)) % np.uint64(1 << 32)
    return ((h * np.uint64(workers)) >> np.uint64(32)).astype(np.int64)


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RoutedPart:
    """One executable slice of a request, bound to (at most) one worker.

    ``worker=None`` means "any worker" (unrouted: the shared queue).
    ``positions`` are the rows of the *original* request this part covers,
    used by the caller to scatter partial results back; ``None`` means the
    part covers the whole request in order.
    """

    request: QueryRequest
    worker: int | None = None
    part: int = 0
    parts: int = 1
    positions: np.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """What the planner decided for one batch of requests.

    ``parts[i]`` are the routed parts of ``requests[i]``; execution answers
    every part and the caller reassembles by ``positions``. ``kernel`` is
    the planner's score-and-select backend choice — the serving layer boots
    its workers from it, so the plan records what actually executes.

    Example::

        plan = QueryPlanner(workers=4, routing=True).plan([req])
        [p.worker for p in plan.parts[0]]     # cache-owner per slice
    """

    requests: tuple
    parts: tuple
    workers: int = 1
    routing: bool = False
    kernel: str = "numpy"

    def by_worker(self) -> dict:
        """``{worker: [(request_index, RoutedPart), ...]}`` submission order."""
        out: dict = {}
        for i, rparts in enumerate(self.parts):
            for rp in rparts:
                out.setdefault(rp.worker, []).append((i, rp))
        return out

    def describe(self) -> dict:
        """JSON-serializable provenance (mirrors core Plan.describe())."""
        return {
            "requests": len(self.requests),
            "parts": sum(len(p) for p in self.parts),
            "workers": self.workers,
            "routing": self.routing,
            "kernel": self.kernel,
        }


class QueryPlanner:
    """Turns a batch of request objects into an executable :class:`QueryPlan`.

    With ``routing=False`` (or one worker) every request is a single part
    for any worker. With ``routing=True`` top-k requests are split by term
    ownership (:func:`route_term`) so each slice lands on the worker whose
    LRU cache owns those rows; neighbours requests route by their term;
    pair-count requests go whole to one worker (point lookups bypass the
    row cache, so splitting them buys nothing).

    Streamed top-k requests (``chunk`` set) are never split: one worker owns
    the whole stream (routed by the first term) so chunks arrive in order.

    Example::

        planner = QueryPlanner(workers=4, routing=True)
        plan = planner.plan([TopKRequest(range(128), k=10)])
        len(plan.parts[0])        # up to 4 slices, one per cache owner
    """

    def __init__(
        self,
        *,
        workers: int = 1,
        routing: bool = False,
        kernel: str | None = None,
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if kernel is None:
            kernel = default_kernel()
        elif kernel not in KERNELS:
            raise ValueError(f"unknown kernel {kernel!r}; have {KERNELS}")
        self.workers = workers
        # routing needs >= 2 caches to partition; with one worker the plan
        # is honest about being unrouted (and stats report it that way)
        self.routing = routing and workers > 1
        self.kernel = kernel

    def plan(self, requests) -> QueryPlan:
        reqs = tuple(requests)
        check_request_types(reqs)
        return QueryPlan(
            requests=reqs,
            parts=tuple(tuple(self._split(r)) for r in reqs),
            workers=self.workers,
            routing=self.routing,
            kernel=self.kernel,
        )

    # ------------------------------------------------------------ internals
    def _split(self, req) -> list[RoutedPart]:
        if not self.routing:
            return [RoutedPart(request=req)]
        if isinstance(req, NeighboursRequest):
            return [RoutedPart(request=req, worker=route_term(req.term, self.workers))]
        if isinstance(req, PairCountsRequest):
            # point lookups bypass the row cache, so placement only matters
            # for load spread: hash the whole batch, not its first term
            # (which would pile every probe of one hot term on one worker)
            w = route_term(int(req.pairs.sum()), self.workers) if req.batch else 0
            return [RoutedPart(request=req, worker=w)]
        # TopKRequest
        if req.chunk is not None or req.batch == 0:
            w = route_term(int(req.terms[0]), self.workers) if req.batch else 0
            return [RoutedPart(request=req, worker=w)]
        owners = route_terms(req.terms, self.workers)
        used = np.unique(owners)
        if len(used) == 1:
            return [RoutedPart(request=req, worker=int(used[0]))]
        parts = []
        for part, w in enumerate(used):
            pos = np.nonzero(owners == w)[0]
            sub = TopKRequest(
                terms=req.terms[pos], k=req.k, score=req.score, chunk=None
            )
            parts.append(
                RoutedPart(
                    request=sub,
                    worker=int(w),
                    part=part,
                    parts=len(used),
                    positions=pos,
                )
            )
        return parts


# ---------------------------------------------------------------------------
# the one execution path
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ExecGroup:
    """A coalescing group: requests answerable by one kernel launch."""

    kind: str          # "topk" | "topk-stream" | "pairs" | "neighbours"
    key: tuple | None  # (k, score) for "topk"
    items: list        # [(tag, request), ...] — tag is caller-opaque


def coalesce(tagged_requests) -> list[ExecGroup]:
    """Group ``(tag, request)`` pairs for minimal kernel launches: one
    ``topk`` launch per distinct ``(k, score)``, all pair lookups together,
    each stream and each neighbours row on its own. Tags are opaque to the
    executor and come back through ``emit`` — the in-process engine uses
    request indices, serving workers use ``(client, request, part)``."""
    topk: dict[tuple, ExecGroup] = {}
    pairs: ExecGroup | None = None
    out: list[ExecGroup] = []
    for tag, req in tagged_requests:
        if isinstance(req, TopKRequest) and req.chunk is None:
            key = (int(req.k), req.score)
            g = topk.get(key)
            if g is None:
                g = topk[key] = ExecGroup("topk", key, [])
                out.append(g)
            g.items.append((tag, req))
        elif isinstance(req, TopKRequest):
            out.append(ExecGroup("topk-stream", None, [(tag, req)]))
        elif isinstance(req, PairCountsRequest):
            if pairs is None:
                pairs = ExecGroup("pairs", None, [])
                out.append(pairs)
            pairs.items.append((tag, req))
        elif isinstance(req, NeighboursRequest):
            out.append(ExecGroup("neighbours", None, [(tag, req)]))
        else:
            out.append(ExecGroup("invalid", None, [(tag, req)]))
    return out


def _bump(stats, key, n=1):
    if stats is not None:
        stats[key] = stats.get(key, 0) + n


def execute_groups(engine, groups, emit, stats=None) -> None:
    """Answer coalesced groups against ``engine``, reporting through
    ``emit(tag, ok, payload, *, seq=0, last=True, extra=None)``.

    This is the single execution path behind ``QueryEngine.execute`` (tags
    are request indices, emit collects into a list) and the serving workers
    (tags carry client/request/part ids, emit puts response messages on the
    mp queue). Per-item validation errors are emitted as
    ``("value_error", message)`` payloads and never poison sibling requests
    in the same group."""
    for g in groups:
        if g.kind == "topk":
            _exec_topk(engine, g, emit, stats)
        elif g.kind == "topk-stream":
            _exec_stream(engine, g, emit, stats)
        elif g.kind == "pairs":
            _exec_pairs(engine, g, emit, stats)
        elif g.kind == "neighbours":
            _exec_neighbours(engine, g, emit, stats)
        else:  # "invalid": a non-request object reached a worker
            for tag, req in g.items:
                emit(
                    tag, False,
                    ("value_error", f"not a query request: {type(req).__name__}"),
                )


def _exec_topk(engine, group, emit, stats) -> None:
    k, score = group.key
    live = []
    for tag, req in group.items:
        try:
            engine._check_terms(req.terms)
            live.append((tag, req))
        except ValueError as e:
            emit(tag, False, ("value_error", str(e)))
    if not live:
        return
    all_terms = np.concatenate([r.terms for _, r in live])
    try:
        ids, scores = engine._topk_batch(all_terms, k=k, score=score)
    except ValueError as e:  # defensive: requests validate score/k upfront
        for tag, _ in live:
            emit(tag, False, ("value_error", str(e)))
        return
    _bump(stats, "topk_launches")
    _bump(stats, "topk_queries", len(all_terms))
    extra = {"coalesced_requests": len(live)}
    off = 0
    for tag, req in live:
        n = req.batch
        emit(tag, True, (ids[off : off + n], scores[off : off + n]), extra=extra)
        off += n


def _exec_stream(engine, group, emit, stats) -> None:
    for tag, req in group.items:
        try:
            engine._check_terms(req.terms)
            ids, scores = engine._topk_batch(req.terms, k=req.k, score=req.score)
        except ValueError as e:
            emit(tag, False, ("value_error", str(e)))
            continue
        _bump(stats, "topk_launches")
        _bump(stats, "topk_queries", req.batch)
        chunk = int(req.chunk)
        n_chunks = max(-(-req.k // chunk), 1)
        extra = {"chunks": n_chunks}
        for i in range(n_chunks):
            sl = slice(i * chunk, min((i + 1) * chunk, req.k))
            _bump(stats, "stream_chunks")
            emit(
                tag, True, (ids[:, sl], scores[:, sl]),
                seq=i, last=(i == n_chunks - 1), extra=extra,
            )


def _exec_pairs(engine, group, emit, stats) -> None:
    live = []
    for tag, req in group.items:
        try:
            engine._check_terms(req.pairs.reshape(-1))
            live.append((tag, req))
        except ValueError as e:
            emit(tag, False, ("value_error", str(e)))
    if not live:
        return
    all_pairs = np.concatenate([r.pairs for _, r in live])
    counts = engine.store.pair_counts(all_pairs)
    _bump(stats, "pair_launches")
    _bump(stats, "pair_queries", len(all_pairs))
    extra = {"coalesced_requests": len(live)}
    off = 0
    for tag, req in live:
        n = req.batch
        emit(tag, True, counts[off : off + n], extra=extra)
        off += n


def _exec_neighbours(engine, group, emit, stats) -> None:
    for tag, req in group.items:
        try:
            engine._check_terms(np.asarray([req.term], dtype=np.int64))
        except ValueError as e:
            emit(tag, False, ("value_error", str(e)))
            continue
        _bump(stats, "neighbours_queries")
        emit(tag, True, engine._row(req.term))
