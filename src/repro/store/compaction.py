"""Tier-pressure compaction daemon for continuously growing stores.

A batch build compacts once, on demand. A streaming store grows a new
micro-segment every seal and would accumulate unbounded read
amplification, so :class:`CompactionDaemon` watches tier pressure — it
triggers whenever :meth:`Store.plan_compaction`'s size-tiered policy finds
a run of at least ``fanout`` similar-sized segments — and merges that tier,
repeatedly, with exponential backoff while no tier qualifies.

Two execution modes share the trigger logic:

* ``inline=True`` merges in this process (``Store.compact``) — used by
  tests and ``until_converged()``, where per-round process-spawn cost
  would dominate.
* ``inline=False`` (default for ``start()``) delegates to
  ``Store.compact_background``'s spawned worker, so the daemon thread
  never blocks its host (e.g. a serving parent or the stream driver) on a
  large merge; appends continue concurrently and readers pick up the swap
  on their next ``refresh()``.

Compaction never changes query results — only how many segments answer
them — so the daemon is safe to run against a store that is being queried
and appended to at the same time.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import repro.obs as obs


@dataclass
class CompactionPolicy:
    """When to merge: the size-tiered trigger plus backoff tuning.

    ``fanout`` is the invariant the daemon converges the store toward: no
    size tier holds ``fanout`` or more similar-sized segments (it maps to
    ``plan_compaction(min_segments=fanout)``). ``tier_ratio`` defines
    "similar-sized". While no tier qualifies the daemon sleeps
    ``backoff_s`` doubling up to ``max_backoff_s``; any successful merge
    resets the backoff, since one merge often creates the next tier.
    """

    fanout: int = 4
    tier_ratio: float = 4.0
    max_segments_per_merge: int | None = None
    backoff_s: float = 0.05
    max_backoff_s: float = 2.0

    def __post_init__(self):
        if self.fanout < 2:
            raise ValueError("fanout must be >= 2")
        if self.tier_ratio < 1.0:
            raise ValueError("tier_ratio must be >= 1.0")
        if self.backoff_s <= 0 or self.max_backoff_s < self.backoff_s:
            raise ValueError("need 0 < backoff_s <= max_backoff_s")


class CompactionDaemon:
    """Keep a store's tier invariant while it grows.

    ``run_once()`` checks pressure and performs at most one merge;
    ``until_converged()`` loops inline merges until no tier qualifies;
    ``start()``/``stop()`` run the check in a daemon thread with backoff.
    """

    def __init__(self, store, policy: CompactionPolicy | None = None, *,
                 inline: bool = False, registry=None):
        self.store = store
        self.policy = policy or CompactionPolicy()
        self.inline = inline
        self.reg = registry if registry is not None else obs.get_registry()
        self.merges = 0
        self.segments_merged = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- triggers
    def plan(self) -> list[str]:
        """Current tier under pressure ([] = invariant holds)."""
        self.store.refresh()
        return self.store.plan_compaction(
            min_segments=self.policy.fanout,
            tier_ratio=self.policy.tier_ratio,
            max_segments=self.policy.max_segments_per_merge,
        )

    def run_once(self) -> int:
        """One pressure check; returns how many segments were merged away
        (0 when the tier invariant already holds)."""
        names = self.plan()
        if not names:
            return 0
        with self.reg.span(
            "compaction/merge", segments=len(names), inline=self.inline
        ):
            if self.inline:
                self.store.compact(names)
            else:
                handle = self.store.compact_background(names)
                if handle is not None:
                    handle.join()
                    self.store.refresh()
        self.merges += 1
        self.segments_merged += len(names)
        self.reg.counter("compaction/merges").inc(1)
        self.reg.counter("compaction/segments_merged").inc(len(names))
        return len(names)

    def until_converged(self, *, max_rounds: int = 1_000) -> int:
        """Merge inline until no tier exceeds ``fanout``; returns rounds
        performed. The convergence tests drive this directly."""
        was_inline, self.inline = self.inline, True
        try:
            rounds = 0
            while rounds < max_rounds and self.run_once():
                rounds += 1
            return rounds
        finally:
            self.inline = was_inline

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "CompactionDaemon":
        if self._thread is not None:
            raise RuntimeError("compaction daemon already started")
        self._thread = threading.Thread(
            target=self._loop, name="compaction-daemon", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, timeout: float | None = 60.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    def _loop(self) -> None:
        backoff = self.policy.backoff_s
        while not self._stop.is_set():
            merged = self.run_once()
            if merged:
                backoff = self.policy.backoff_s  # pressure: look again soon
                continue
            self._stop.wait(backoff)
            backoff = min(backoff * 2, self.policy.max_backoff_s)

    def summary(self) -> dict:
        return {
            "merges": self.merges,
            "segments_merged": self.segments_merged,
            "fanout": self.policy.fanout,
            "tier_ratio": self.policy.tier_ratio,
        }
