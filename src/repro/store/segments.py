"""LSM-style co-occurrence store: a manifest of immutable CSR segments.

A store directory holds ``store.json`` plus one subdirectory per segment:

    store.json       {vocab_size, segments: [...], next_seg_id}
    seg-00000/       immutable CSR segment (csr_store.py layout)
    seg-00001/
    ...

Counts are additive across document batches (C = Σ_s B_sᵀ B_s), so the
store supports **exact incremental appends**: counting a new document batch
produces a new segment; queries sum counts across segments; ``compact()``
k-way-merges all segments back into one with no loss of exactness. The same
merge path ingests per-shard outputs of the distributed runner, following
the inverted-index-based real-time construction of Cheng (2023).
"""

from __future__ import annotations

import json
import os
import shutil

import numpy as np

from repro.store.builder import SpillSink, merge_row_streams, sum_by_key
from repro.store.csr_store import CSRSegment, write_segment

STORE_META = "store.json"


class Store:
    """A directory of CSR segments behind a JSON manifest."""

    def __init__(self, path: str, manifest: dict):
        self.path = path
        self.manifest = manifest
        self._segments: dict[str, CSRSegment] = {}
        # bumped on every manifest mutation; query engines use it to know
        # when their row caches are stale
        self.version = 0
        # (inode, mtime_ns, size) of store.json as last read/written; lets
        # refresh() detect another process's commit with one stat()
        self._meta_sig = self._stat_sig()

    # ------------------------------------------------------- lifecycle
    @classmethod
    def create(cls, path: str, vocab_size: int) -> "Store":
        if os.path.exists(os.path.join(path, STORE_META)):
            raise FileExistsError(f"store already exists at {path}")
        os.makedirs(path, exist_ok=True)
        store = cls(
            path, {"vocab_size": vocab_size, "segments": [], "next_seg_id": 0}
        )
        store._save()
        return store

    @classmethod
    def open(cls, path: str) -> "Store":
        with open(os.path.join(path, STORE_META)) as f:
            return cls(path, json.load(f))

    @staticmethod
    def exists(path: str) -> bool:
        return os.path.exists(os.path.join(path, STORE_META))

    def _stat_sig(self) -> tuple | None:
        try:
            st = os.stat(os.path.join(self.path, STORE_META))
        except OSError:
            return None
        return (st.st_ino, st.st_mtime_ns, st.st_size)

    def _save(self) -> None:
        tmp = os.path.join(self.path, STORE_META + ".tmp")
        with open(tmp, "w") as f:
            json.dump(self.manifest, f, indent=2)
        os.replace(tmp, os.path.join(self.path, STORE_META))
        self._meta_sig = self._stat_sig()
        self.version += 1

    def refresh(self) -> bool:
        """Pick up another process's manifest commit (append / ingest /
        compact). Cheap when nothing changed — one ``stat()`` of store.json;
        on change the manifest is re-read, lazily-opened segments are
        dropped, and ``version`` bumps so engines invalidate their row
        caches. Serving workers call this between micro-batches, which is
        how a mutation in the parent process becomes visible to queries
        in flight through the serving layer.

        Returns True if the manifest changed.
        """
        sig = self._stat_sig()
        if sig is None or sig == self._meta_sig:
            return False
        with open(os.path.join(self.path, STORE_META)) as f:
            self.manifest = json.load(f)
        self._meta_sig = sig
        self._segments.clear()
        self.version += 1
        return True

    # ------------------------------------------------------- properties
    @property
    def vocab_size(self) -> int:
        return self.manifest["vocab_size"]

    @property
    def segment_names(self) -> list[str]:
        return list(self.manifest["segments"])

    @property
    def segments(self) -> list[CSRSegment]:
        return [self._segment(n) for n in self.manifest["segments"]]

    def _segment(self, name: str) -> CSRSegment:
        if name not in self._segments:
            self._segments[name] = CSRSegment(os.path.join(self.path, name))
        return self._segments[name]

    @property
    def num_docs(self) -> int:
        return sum(s.num_docs for s in self.segments)

    @property
    def total_count(self) -> int:
        return sum(s.total_count for s in self.segments)

    def df(self) -> np.ndarray:
        """Document frequencies summed across segments (additive like the
        counts themselves)."""
        out = np.zeros(self.vocab_size, dtype=np.int64)
        for s in self.segments:
            out += s.df
        return out

    # --------------------------------------------------------- writing
    def _new_segment_dir(self) -> tuple[str, str]:
        name = f"seg-{self.manifest['next_seg_id']:05d}"
        self.manifest["next_seg_id"] += 1
        return name, os.path.join(self.path, name)

    def add_segment_from_sink(
        self,
        sink: SpillSink,
        *,
        df: np.ndarray | None = None,
        num_docs: int = 0,
        source: str = "spill",
    ) -> CSRSegment:
        """Finalize a SpillSink's runs into a new segment of this store."""
        if sink.vocab_size != self.vocab_size:
            raise ValueError(
                f"sink vocab {sink.vocab_size} != store vocab {self.vocab_size}"
            )
        name, seg_dir = self._new_segment_dir()
        seg = sink.finalize_segment(seg_dir, df=df, num_docs=num_docs, source=source)
        self.manifest["segments"].append(name)
        self._save()
        return seg

    def add_segment_from_rows(
        self,
        rows,
        *,
        df: np.ndarray | None = None,
        num_docs: int = 0,
        source: str = "rows",
    ) -> CSRSegment:
        """Write a merged (primary, secondaries, counts) row stream — strictly
        ascending primaries, unique pairs — as a new segment. The single
        segment-adding primitive behind counting, ingest, and compaction."""
        name, seg_dir = self._new_segment_dir()
        write_segment(
            seg_dir, rows, self.vocab_size, df=df, num_docs=num_docs, source=source
        )
        self.manifest["segments"].append(name)
        self._save()
        return self._segment(name)

    def append_collection(
        self,
        c,
        *,
        method: str = "list-scan",
        memory_budget_pairs: int = 4 << 20,
        **kwargs,
    ) -> CSRSegment:
        """Count a new document batch and append it as a segment (the exact
        incremental path: no existing segment is touched). ``method`` may be
        ``"auto"`` — the planner's cost models pick it."""
        from repro.core.cooc import count  # lazy: core wires back into us

        if method == "auto":
            if kwargs:
                raise ValueError(
                    "method kwargs require an explicit method (auto-selected "
                    "methods run with planner-resolved params)"
                )
            from repro.core.plan import CountJob, Planner

            plan = Planner().plan(
                CountJob(
                    collection=c,
                    output="stats",
                    memory_budget_pairs=memory_budget_pairs,
                )
            )
            method, kwargs = plan.method, dict(plan.method_kwargs)
        with SpillSink(
            self.vocab_size, memory_budget_pairs=memory_budget_pairs
        ) as sink:
            count(method, c, sink, **kwargs)
            df = np.bincount(c.terms, minlength=self.vocab_size).astype(np.int64)
            return self.add_segment_from_sink(
                sink, df=df, num_docs=c.num_docs, source=f"count:{method}"
            )

    def ingest_store(self, other: "Store") -> CSRSegment:
        """Merge another store's segments (e.g. a per-shard store from the
        distributed runner) into one new segment here. Exact: counts add."""
        if other.vocab_size != self.vocab_size:
            raise ValueError("vocab mismatch")
        return self.add_segment_from_rows(
            merge_row_streams([s.iter_rows() for s in other.segments]),
            df=other.df(),
            num_docs=other.num_docs,
            source=f"ingest:{os.path.basename(other.path)}",
        )

    def compact(self) -> CSRSegment:
        """Merge all segments into one (LSM major compaction). Queries before
        and after return identical counts. The manifest is committed exactly
        once, *after* the merged segment is fully written — a crash mid-way
        leaves only an orphan directory, never double-counted segments (so
        this cannot go through ``add_segment_from_rows``, which appends)."""
        old_names = self.segment_names
        old_segs = [self._segment(n) for n in old_names]
        df = self.df()
        num_docs = self.num_docs
        name, seg_dir = self._new_segment_dir()
        write_segment(
            seg_dir,
            merge_row_streams([s.iter_rows() for s in old_segs]),
            self.vocab_size,
            df=df,
            num_docs=num_docs,
            source=f"compact:{len(old_names)}",
        )
        self.manifest["segments"] = [name]
        self._save()
        for n in old_names:
            self._segments.pop(n, None)
            shutil.rmtree(os.path.join(self.path, n), ignore_errors=True)
        return self._segment(name)

    # --------------------------------------------------------- queries
    # (thin exact primitives; the batched/scored engine lives in query.py)
    def pair_count(self, i: int, j: int) -> int:
        return sum(s.pair_count(i, j) for s in self.segments)

    def pair_counts(self, pairs: np.ndarray) -> np.ndarray:
        pairs = np.asarray(pairs, dtype=np.int64)
        out = np.zeros(len(pairs), dtype=np.int64)
        for s in self.segments:
            out += s.pair_counts(pairs)
        return out

    def neighbours(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Merged symmetric neighbourhood of ``t`` across segments."""
        segs = self.segments
        if len(segs) == 1:
            ids, cnts = segs[0].neighbours(t)
            return np.asarray(ids, dtype=np.int64), np.asarray(cnts)
        parts = [s.neighbours(t) for s in segs]
        ids = np.concatenate([p[0] for p in parts]).astype(np.int64)
        cnts = np.concatenate([p[1] for p in parts]).astype(np.int64)
        if len(ids) == 0:
            return ids, cnts
        return sum_by_key(ids, cnts)

    def dense(self) -> np.ndarray:
        """Dense strict-upper matrix summed over segments (tests only)."""
        mat = np.zeros((self.vocab_size, self.vocab_size), dtype=np.int64)
        for s in self.segments:
            mat += s.dense()
        return mat
