"""LSM-style co-occurrence store: a manifest of immutable CSR segments.

A store directory holds ``store.json`` plus one subdirectory per segment:

    store.json       {generation, vocab_size, segments: [...],
                      next_seg_id, segment_version}
    seg-00000/       immutable segment (csr_store.py layout, v1 raw or
    seg-00001/        v2 block-compressed — formats coexist freely)
    ...

Counts are additive across document batches (C = Σ_s B_sᵀ B_s), so the
store supports **exact incremental appends**: counting a new document batch
produces a new segment; queries sum counts across segments; ``compact()``
k-way-merges segments back together with no loss of exactness. The same
merge path ingests per-shard outputs of the distributed runner, following
the inverted-index-based real-time construction of Cheng (2023).

Concurrency model: segments are immutable, so the manifest is the only
mutable state. Every commit is a read-modify-write of ``store.json`` under
an advisory ``flock`` (``.store.lock``), which lets a **background
compaction process** merge small segments while the owning process keeps
appending — neither clobbers the other's manifest entry. Within one
process, a single handle may also be shared across threads (a stream
ingestor sealing while a compaction daemon polls ``refresh()``): the
flock cannot serialize those (same file handle, same process), so a
per-handle ``threading.RLock`` additionally guards every reassignment of
``self.manifest`` — ``_commit`` holds it across its read-modify-write so
a concurrent ``refresh()`` can never replace the manifest between the
mutation and the save and silently drop the commit. Readers never take
the cross-process lock: ``refresh()`` detects foreign commits with one
``stat()`` plus a ``generation`` counter cross-check (the counter,
serialized first in store.json, catches the in-place same-size same-mtime
rewrite a bare stat signature can miss), and mmaps opened before a
compaction keep working after it because POSIX unlink only detaches the
name.

Size-tiered compaction: ``plan_compaction()`` picks the smallest run of
similar-sized segments (read-amplification reducers first, never a
rewrite of one big segment to absorb a tiny one), ``compact(names=...)``
merges exactly those, and ``compact_background()`` runs that in a spawned
worker process — the serving workers pick up the swap via their existing
between-batch ``refresh()``.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading

import numpy as np

try:
    import fcntl
except ImportError:  # non-POSIX: single-process use keeps working unlocked
    fcntl = None

from repro.store.builder import SpillSink, merge_row_streams, sum_by_key
from repro.store.csr_store import (
    DEFAULT_SEGMENT_VERSION,
    open_segment,
    segment_bytes,
    write_segment,
)
from repro.store.spawn import spawn_friendly_env

STORE_META = "store.json"
LOCK_NAME = ".store.lock"

_GENERATION_RE = re.compile(rb'"generation":\s*(\d+)')
_PENDING_RE = re.compile(r"\.pending-(\d+)-")


def _pid_alive(pid: int) -> bool:
    """Whether ``pid`` is a live process (EPERM counts as alive)."""
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except OSError:  # EPERM and friends: someone is there
        return True
    return True


class Store:
    """A directory of CSR segments behind a JSON manifest."""

    def __init__(self, path: str, manifest: dict, *, registry=None):
        self.path = path
        self.manifest = manifest
        self.registry = registry
        self._segments: dict[str, object] = {}
        # serializes manifest reassignment across threads sharing this
        # handle (commit vs. refresh); the flock in _commit only covers
        # other processes / other handles
        self._mutex = threading.RLock()
        # bumped on every manifest mutation; query engines use it to know
        # when their row caches are stale
        self.version = 0
        # (inode, mtime_ns, size) of store.json as last read/written; lets
        # refresh() detect another process's commit with one stat()
        self._meta_sig = self._stat_sig()

    # ------------------------------------------------------- lifecycle
    @classmethod
    def create(
        cls, path: str, vocab_size: int, *, segment_version: int | None = None,
        registry=None,
    ) -> "Store":
        """Create an empty store. ``segment_version`` fixes the on-disk
        format of every segment this store writes (1 = raw arrays,
        2 = block-compressed; default 1) — recorded in the manifest, so
        every later append and compaction agrees."""
        if os.path.exists(os.path.join(path, STORE_META)):
            raise FileExistsError(f"store already exists at {path}")
        os.makedirs(path, exist_ok=True)
        store = cls(
            path,
            {
                "generation": 0,
                "vocab_size": vocab_size,
                "segments": [],
                "next_seg_id": 0,
                "segment_version": int(
                    DEFAULT_SEGMENT_VERSION
                    if segment_version is None else segment_version
                ),
            },
            registry=registry,
        )
        store._save()
        return store

    @classmethod
    def open(cls, path: str, *, registry=None) -> "Store":
        with open(os.path.join(path, STORE_META)) as f:
            store = cls(path, json.load(f), registry=registry)
        store._sweep_pending()
        return store

    @staticmethod
    def exists(path: str) -> bool:
        return os.path.exists(os.path.join(path, STORE_META))

    def _sweep_pending(self) -> None:
        """Remove ``.pending-*`` segment directories abandoned by dead
        writers. Pending dirs are only referenced by the ``single_commit``
        call that created them — never by a manifest — so once the pid
        embedded in the name is gone (SIGKILL mid-seal), the directory is
        unreachable garbage. Live pids are left alone: their commit may
        still be in flight."""
        try:
            entries = os.listdir(self.path)
        except OSError:
            return
        for name in entries:
            m = _PENDING_RE.match(name)
            if m and not _pid_alive(int(m.group(1))):
                shutil.rmtree(
                    os.path.join(self.path, name), ignore_errors=True
                )

    def _stat_sig(self) -> tuple | None:
        try:
            st = os.stat(os.path.join(self.path, STORE_META))
        except OSError:
            return None
        return (st.st_ino, st.st_mtime_ns, st.st_size)

    def _probe_generation(self) -> int | None:
        """The manifest's generation counter with one small read — it is
        serialized as the first key, so the head of the file suffices."""
        try:
            with open(os.path.join(self.path, STORE_META), "rb") as f:
                m = _GENERATION_RE.search(f.read(96))
        except OSError:
            return None
        return int(m.group(1)) if m else None

    def _save(self) -> None:
        with self._mutex:
            # generation first: refresh()'s probe reads only the head
            gen = int(self.manifest.get("generation", 0)) + 1
            m = {"generation": gen}
            m.update(
                (k, v) for k, v in self.manifest.items() if k != "generation"
            )
            self.manifest = m
            tmp = os.path.join(self.path, STORE_META + ".tmp")
            with open(tmp, "w") as f:
                json.dump(self.manifest, f, indent=2)
            os.replace(tmp, os.path.join(self.path, STORE_META))
            self._meta_sig = self._stat_sig()
            self.version += 1

    def _commit(self, mutate) -> None:
        """Read-modify-write the manifest under the store's advisory lock
        (cross-process) *and* the handle mutex (cross-thread): re-read the
        freshest manifest (a background compaction or a sibling appender
        may have committed since we last looked), apply ``mutate`` to it,
        write. The mutex stays held from the re-read through ``_save`` so
        a concurrent ``refresh()`` on this same handle can never reassign
        ``self.manifest`` mid-commit and drop the mutation."""
        with self._mutex:
            lf = open(os.path.join(self.path, LOCK_NAME), "a")
            try:
                if fcntl is not None:
                    fcntl.flock(lf, fcntl.LOCK_EX)
                try:
                    with open(os.path.join(self.path, STORE_META)) as f:
                        on_disk = json.load(f)
                except (OSError, json.JSONDecodeError):
                    on_disk = None
                if on_disk is not None and on_disk.get(
                    "generation", 0
                ) != self.manifest.get("generation", 0):
                    # a foreign commit landed: adopt it (and drop lazily-
                    # opened segments it may have removed) before applying
                    # ours on top
                    self.manifest = on_disk
                    self._segments.clear()
                mutate(self.manifest)
                self._save()
            finally:
                lf.close()  # closing releases the flock

    def refresh(self) -> bool:
        """Pick up another process's manifest commit (append / ingest /
        compact). Cheap when nothing changed — one ``stat()`` of store.json,
        plus a head-of-file generation cross-check that catches the case a
        stat signature cannot: an in-place rewrite that lands on the same
        inode, size, and (coarse-clock) mtime. On change the manifest is
        re-read, lazily-opened segments are dropped, and ``version`` bumps
        so engines invalidate their row caches. Serving workers call this
        between micro-batches, which is how a mutation in the parent
        process becomes visible to queries in flight through the serving
        layer.

        Returns True if the manifest changed.
        """
        # under the handle mutex: a _commit in another thread of this
        # process must never see its manifest swapped out mid-mutation
        with self._mutex:
            sig = self._stat_sig()
            if sig is None:
                return False
            if sig == self._meta_sig:
                gen = self._probe_generation()
                if gen is None or gen == int(
                    self.manifest.get("generation", 0)
                ):
                    return False
            with open(os.path.join(self.path, STORE_META)) as f:
                self.manifest = json.load(f)
            self._meta_sig = sig
            self._segments.clear()
            self.version += 1
            return True

    # ------------------------------------------------------- properties
    @property
    def vocab_size(self) -> int:
        return self.manifest["vocab_size"]

    @property
    def segment_version(self) -> int:
        """On-disk format of segments this store writes (1 raw, 2
        compressed). Manifests from before the field default to 1, so old
        stores keep appending the format they already hold."""
        return int(self.manifest.get("segment_version", DEFAULT_SEGMENT_VERSION))

    @property
    def segment_names(self) -> list[str]:
        return list(self.manifest["segments"])

    @property
    def segments(self) -> list:
        # a compaction in another process can delete a segment directory
        # between our manifest read and the open (segments open eagerly, so
        # once _segment returns, unlink cannot hurt it) — adopt the newer
        # manifest and retry rather than surface the race to the query
        for _ in range(8):
            try:
                return [self._segment(n) for n in self.manifest["segments"]]
            except FileNotFoundError:
                if not self.refresh():
                    raise
        raise RuntimeError(
            f"segment set of {self.path} kept changing underneath the reader"
        )

    def _segment(self, name: str):
        if name not in self._segments:
            self._segments[name] = open_segment(
                os.path.join(self.path, name), registry=self.registry
            )
        return self._segments[name]

    @property
    def num_docs(self) -> int:
        return sum(s.num_docs for s in self.segments)

    @property
    def total_count(self) -> int:
        return sum(s.total_count for s in self.segments)

    def df(self) -> np.ndarray:
        """Document frequencies summed across segments (additive like the
        counts themselves)."""
        out = np.zeros(self.vocab_size, dtype=np.int64)
        for s in self.segments:
            out += s.df
        return out

    def freshness(self) -> dict:
        """How fresh this handle's view of the store is: the manifest
        ``generation``, the segment count split by on-disk format version,
        and the unix time of the newest segment append (``None`` when the
        store is empty or predates the ``created_unix`` meta field).
        Serving workers publish this with their stats snapshots so
        ``CoocServer.stats()["freshness"]`` tracks streamed appends live.

        Reads only the tiny per-segment ``meta.json``s, never the arrays; a
        segment a concurrent compaction unlinked mid-walk triggers a
        refresh-and-retry like the ``segments`` property."""
        for _ in range(8):
            by_version: dict[str, int] = {}
            last: float | None = None
            try:
                for name in self.manifest["segments"]:
                    with open(
                        os.path.join(self.path, name, "meta.json")
                    ) as f:
                        meta = json.load(f)
                    v = f"v{int(meta.get('format_version', 1))}"
                    by_version[v] = by_version.get(v, 0) + 1
                    created = meta.get("created_unix")
                    if created is not None:
                        last = created if last is None else max(last, created)
            except FileNotFoundError:
                if not self.refresh():
                    raise
                continue
            return {
                "generation": int(self.manifest.get("generation", 0)),
                "segments": len(self.manifest["segments"]),
                "segments_by_version": by_version,
                "last_append_unix": last,
            }
        raise RuntimeError(
            f"segment set of {self.path} kept changing underneath freshness()"
        )

    # --------------------------------------------------------- writing
    def _reserve_segment(self) -> tuple[str, str]:
        """Allocate the next segment name with a committed ``next_seg_id``
        bump, so a concurrent writer (background compaction vs. appending
        parent) can never be handed the same directory. A crash after the
        reservation leaves a gap in the id sequence, never a collision."""
        holder: dict = {}

        def mut(m):
            holder["name"] = f"seg-{m['next_seg_id']:05d}"
            m["next_seg_id"] += 1

        self._commit(mut)
        name = holder["name"]
        return name, os.path.join(self.path, name)

    def add_segment_from_sink(
        self,
        sink: SpillSink,
        *,
        df: np.ndarray | None = None,
        num_docs: int = 0,
        source: str = "spill",
    ):
        """Finalize a SpillSink's runs into a new segment of this store."""
        if sink.vocab_size != self.vocab_size:
            raise ValueError(
                f"sink vocab {sink.vocab_size} != store vocab {self.vocab_size}"
            )
        name, seg_dir = self._reserve_segment()
        seg = sink.finalize_segment(
            seg_dir, df=df, num_docs=num_docs, source=source,
            version=self.segment_version,
        )
        self._commit(lambda m: m["segments"].append(name))
        self._segments[name] = seg
        return seg

    def add_segment_from_rows(
        self,
        rows,
        *,
        df: np.ndarray | None = None,
        num_docs: int = 0,
        source: str = "rows",
        single_commit: bool = False,
        extra_mutate=None,
    ):
        """Write a merged (primary, secondaries, counts) row stream — strictly
        ascending primaries, unique pairs — as a new segment. The single
        segment-adding primitive behind counting, ingest, and compaction.

        ``single_commit=True`` writes the segment into a hidden pending
        directory first and then performs **one** flock'd manifest commit
        that allocates the name, renames the directory into place, and
        appends it — instead of the default reserve-then-append pair of
        commits. The parallel-ingest finalizer uses this so a crash leaves
        either no trace (an unreferenced pending dir) or the fully
        committed segment, never a reserved-but-absent name.

        ``extra_mutate`` (optional) runs against the manifest inside the
        same locked commit that appends the segment, *before* the append —
        so an unrelated manifest key (e.g. a stream cursor) advances
        atomically with the segment becoming visible. It may raise to abort
        the commit: with ``single_commit`` the pending directory is then
        removed before the exception propagates, so an abort leaves no
        trace. Only a crash (SIGKILL mid-seal) leaves a pending dir behind,
        and ``Store.open``'s dead-pid sweep collects those."""
        if single_commit:
            tmp_dir = os.path.join(
                self.path, f".pending-{os.getpid()}-{id(rows):x}"
            )
            shutil.rmtree(tmp_dir, ignore_errors=True)
            write_segment(
                tmp_dir, rows, self.vocab_size, df=df, num_docs=num_docs,
                source=source, version=self.segment_version,
            )
            holder: dict = {}

            def mut(m):
                if extra_mutate is not None:
                    extra_mutate(m)
                name = f"seg-{m['next_seg_id']:05d}"
                m["next_seg_id"] += 1
                os.replace(tmp_dir, os.path.join(self.path, name))
                m["segments"].append(name)
                holder["name"] = name

            try:
                self._commit(mut)
            except BaseException:
                # aborted (e.g. a stream-cursor fence): the segment was
                # never published, so drop the pending dir now instead of
                # leaking it until some future dead-pid sweep
                shutil.rmtree(tmp_dir, ignore_errors=True)
                raise
            return self._segment(holder["name"])
        name, seg_dir = self._reserve_segment()

        def mut_append(m):
            if extra_mutate is not None:
                extra_mutate(m)
            m["segments"].append(name)

        write_segment(
            seg_dir, rows, self.vocab_size, df=df, num_docs=num_docs,
            source=source, version=self.segment_version,
        )
        self._commit(mut_append)
        return self._segment(name)

    def append_collection(
        self,
        c,
        *,
        method: str = "list-scan",
        memory_budget_pairs: int = 4 << 20,
        **kwargs,
    ):
        """Count a new document batch and append it as a segment (the exact
        incremental path: no existing segment is touched). ``method`` may be
        ``"auto"`` — the planner's cost models pick it."""
        from repro.core.cooc import count  # lazy: core wires back into us

        if method == "auto":
            if kwargs:
                raise ValueError(
                    "method kwargs require an explicit method (auto-selected "
                    "methods run with planner-resolved params)"
                )
            from repro.core.plan import CountJob, Planner

            plan = Planner().plan(
                CountJob(
                    collection=c,
                    output="stats",
                    memory_budget_pairs=memory_budget_pairs,
                )
            )
            method, kwargs = plan.method, dict(plan.method_kwargs)
        with SpillSink(
            self.vocab_size, memory_budget_pairs=memory_budget_pairs
        ) as sink:
            count(method, c, sink, **kwargs)
            df = np.bincount(c.terms, minlength=self.vocab_size).astype(np.int64)
            return self.add_segment_from_sink(
                sink, df=df, num_docs=c.num_docs, source=f"count:{method}"
            )

    def ingest_store(self, other: "Store"):
        """Merge another store's segments (e.g. a per-shard store from the
        distributed runner) into one new segment here. Exact: counts add."""
        if other.vocab_size != self.vocab_size:
            raise ValueError("vocab mismatch")
        return self.add_segment_from_rows(
            merge_row_streams([s.iter_rows() for s in other.segments]),
            df=other.df(),
            num_docs=other.num_docs,
            source=f"ingest:{os.path.basename(other.path)}",
        )

    # ------------------------------------------------------ compaction
    def compact(self, names: list[str] | None = None):
        """Merge segments into one (LSM compaction). ``names=None`` merges
        everything (major compaction); a list merges exactly those segments
        and leaves the rest in place. Queries before and after return
        identical counts. The manifest commit happens exactly once, *after*
        the merged segment is fully written, and is a locked
        read-modify-write — segments another process appended meanwhile
        survive; a crash mid-way leaves only an orphan directory, never
        double-counted segments."""
        old_names = list(names) if names is not None else self.segment_names
        if not old_names:
            raise ValueError("nothing to compact")
        current = set(self.manifest["segments"])
        missing = [n for n in old_names if n not in current]
        if missing:
            raise ValueError(f"unknown segments {missing}")
        old_segs = [self._segment(n) for n in old_names]
        df = np.zeros(self.vocab_size, dtype=np.int64)
        for s in old_segs:
            df += s.df
        num_docs = sum(s.num_docs for s in old_segs)
        name, seg_dir = self._reserve_segment()
        write_segment(
            seg_dir,
            merge_row_streams([s.iter_rows() for s in old_segs]),
            self.vocab_size,
            df=df,
            num_docs=num_docs,
            source=f"compact:{len(old_names)}",
            version=self.segment_version,
        )
        dropped = set(old_names)

        def mut(m):
            m["segments"] = [
                n for n in m["segments"] if n not in dropped
            ] + [name]

        self._commit(mut)
        for n in old_names:
            self._segments.pop(n, None)
            # unlink only detaches the names: readers that opened the old
            # segments before this commit keep valid mmaps until they close
            shutil.rmtree(os.path.join(self.path, n), ignore_errors=True)
        return self._segment(name)

    def plan_compaction(
        self, *, min_segments: int = 2, tier_ratio: float = 4.0,
        max_segments: int | None = None,
    ) -> list[str]:
        """Size-tiered selection: walk segments smallest-first and return
        the first run of at least ``min_segments`` whose sizes stay within
        ``tier_ratio`` of the run's smallest member — the classic LSM
        policy of merging peers, never rewriting a big segment to absorb a
        tiny one. Returns [] when no tier qualifies."""
        names = self.segment_names
        if len(names) < min_segments:
            return []
        sized = sorted(
            (segment_bytes(os.path.join(self.path, n)), n) for n in names
        )
        i = 0
        while i < len(sized):
            j = i
            while j < len(sized) and sized[j][0] <= sized[i][0] * tier_ratio:
                j += 1
            if j - i >= min_segments:
                tier = [n for _, n in sized[i:j]]
                return tier[:max_segments] if max_segments else tier
            i = j
        return []

    def compact_background(
        self, names: list[str] | None = None, *,
        min_segments: int = 2, tier_ratio: float = 4.0,
    ) -> "CompactionHandle | None":
        """Run a compaction in a spawned worker process and return
        immediately. ``names=None`` compacts the tier ``plan_compaction``
        picks (returns None when nothing qualifies). The worker opens its
        own Store handle, merges, and commits under the manifest lock, so
        this process may keep appending concurrently; call ``refresh()``
        (serving workers already do, between micro-batches) to see the
        swap. ``handle.join()`` waits and returns the result dict."""
        if names is None:
            names = self.plan_compaction(
                min_segments=min_segments, tier_ratio=tier_ratio
            )
        names = list(names)
        if not names:
            return None
        with spawn_friendly_env() as ctx:
            result_q = ctx.Queue()
            proc = ctx.Process(
                target=_compact_worker,
                args=(self.path, names, result_q),
                daemon=True,
            )
            proc.start()
        return CompactionHandle(proc, result_q, names)

    # --------------------------------------------------------- queries
    # (thin exact primitives; the batched/scored engine lives in query.py)
    def pair_count(self, i: int, j: int) -> int:
        return sum(s.pair_count(i, j) for s in self.segments)

    def pair_counts(self, pairs: np.ndarray) -> np.ndarray:
        pairs = np.asarray(pairs, dtype=np.int64)
        out = np.zeros(len(pairs), dtype=np.int64)
        for s in self.segments:
            out += s.pair_counts(pairs)
        return out

    def neighbours(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Merged symmetric neighbourhood of ``t`` across segments."""
        segs = self.segments
        if len(segs) == 1:
            ids, cnts = segs[0].neighbours(t)
            return np.asarray(ids, dtype=np.int64), np.asarray(cnts)
        parts = [s.neighbours(t) for s in segs]
        ids = np.concatenate([p[0] for p in parts]).astype(np.int64)
        cnts = np.concatenate([p[1] for p in parts]).astype(np.int64)
        if len(ids) == 0:
            return ids, cnts
        return sum_by_key(ids, cnts)

    def dense(self) -> np.ndarray:
        """Dense strict-upper matrix summed over segments (tests only)."""
        mat = np.zeros((self.vocab_size, self.vocab_size), dtype=np.int64)
        for s in self.segments:
            mat += s.dense()
        return mat


class CompactionHandle:
    """Handle on one background compaction process."""

    def __init__(self, proc, result_q, names: list[str]):
        self.proc = proc
        self.names = names
        self._q = result_q
        self._result: tuple | None = None

    def alive(self) -> bool:
        return self.proc.is_alive()

    def join(self, timeout: float | None = None) -> dict:
        """Wait for the compaction and return its result dict
        (``{"segment", "nnz", "merged"}``). Raises on worker failure."""
        self.proc.join(timeout)
        if self.proc.is_alive():
            raise TimeoutError("background compaction still running")
        if self._result is None:
            try:
                self._result = self._q.get(timeout=5)
            except queue.Empty:
                self._result = (
                    "error", "compaction worker died without a result"
                )
        status, payload = self._result
        if status != "ok":
            raise RuntimeError(f"background compaction failed: {payload}")
        return payload


def _compact_worker(store_path: str, names: list[str], result_q) -> None:
    """Entry point of the spawned compaction process: open an own Store
    handle and run the locked partial compaction."""
    try:
        store = Store.open(store_path)
        seg = store.compact(names=names)
        result_q.put(
            (
                "ok",
                {
                    "segment": os.path.basename(seg.path),
                    "nnz": seg.nnz,
                    "merged": list(names),
                },
            )
        )
    except Exception as e:  # report, don't vanish: join() re-raises
        result_q.put(("error", f"{type(e).__name__}: {e}"))
