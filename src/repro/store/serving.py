"""Multi-client co-occurrence serving: shared-mmap workers, micro-batched
kernel launches, typed wire protocol, hot-term routing, streaming top-k.

The query engine (store/query.py) already batches *within* one call; this
layer batches *across clients*, the way a real serving deployment amortizes
kernel launches over concurrent traffic:

    clients ──▶ request queue(s) ──▶ worker processes ──▶ response queue ─▶ router
    (threads)   (shared or routed)   (N × Store + QueryEngine)   (mp)      (thread)

* **Typed wire protocol** — the request dataclasses of store/requests.py
  *are* what crosses the process boundary: a client submits
  ``(client_id, request_id, part, parts, request)`` envelopes whose payload
  is the same frozen ``TopKRequest | PairCountsRequest | NeighboursRequest``
  the in-process engine executes. Invalid queries (unknown score, bad dtype,
  k < 1) therefore fail at request construction on the client — a worker
  never sees them.
* **Shared mmap** — every worker process opens the same immutable segment
  files with ``np.memmap``; the OS page cache backs all mappings with one
  physical copy, so N workers serve a 100 GB store with ~one store's worth
  of resident pages. Workers ``Store.refresh()`` between micro-batches, so
  a manifest commit (append/ingest/compact) in the parent becomes visible
  to in-flight serving traffic without a restart; ``refresh_interval_ms``
  adds a periodic idle refresh, so a server with *no* traffic still
  follows a stream daemon's commits (see repro.stream).
* **Micro-batching with a latency budget** — a worker takes the first
  request off its queue, then keeps draining for at most ``batch_window_ms``
  (or until ``max_batch`` requests), coalesces compatible requests — same
  ``(k, score)`` for top-k, all pair lookups together — and executes each
  group as **one** batched launch via the same ``execute_groups`` path the
  in-process engine uses.
* **Hot-term routing** (``routing=True``) — each worker gets its own request
  queue and the client-side :class:`~repro.store.requests.QueryPlanner`
  splits every top-k request by term ownership (``route_term``), so the N
  per-worker LRU row caches hold N disjoint slices of the vocabulary
  instead of N copies of the Zipf head. Per-worker hit rates are surfaced
  in the server's stats.
* **Cross-process telemetry** — every worker keeps a private
  :class:`repro.obs.Registry` (queue-wait / execute / request-latency
  histograms, batch-size distribution, query counters) and publishes
  picklable snapshots over the stats queue: periodically between
  micro-batches when ``stats_interval_s`` is set, and always once at exit.
  The parent merges them (histograms merge bucket-wise, so p50/p95/p99 are
  true pooled percentiles) into a live ``server.stats()`` — no shared
  memory, no extra sockets. A worker that dies mid-flight costs its last
  interval of data, not the whole run: the parent serves its final
  snapshot from the freshest one received and surfaces ``workers_lost``.
* **Streaming top-k** — a ``TopKRequest(chunk=c)`` comes back as an iterator
  of score-ordered ``(ids, scores)`` column blocks: large-k responses cross
  the queue chunk by chunk instead of as one monolithic pickle.

Example (driver-side; see launch/cooc_serve.py for the full workload)::

    server = CoocServer(store_path, workers=4, routing=True,
                        batch_window_ms=2.0, kernel="pallas").start()
    client = server.client()                 # one per client thread
    ids, scores = client.topk([3, 17], k=10, score="pmi")
    for ids_c, scores_c in client.topk_stream([3], k=5000, chunk=512):
        ...                                  # score-ordered chunks
    server.stats()["server_timing"]          # live: queue-wait/execute p50/p95/p99
    stats = server.stop()                    # {"requests": ..., "cache_hit_rate": ...}

Workers are **spawned** (never forked): JAX runtimes do not survive a fork,
and a spawned worker importing the store from disk is exactly the
multi-process serving topology this layer exists to exercise.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time

import numpy as np

from repro import obs
from repro.store.spawn import spawn_friendly_env
from repro.store.requests import (
    NeighboursRequest,
    PairCountsRequest,
    QueryPlanner,
    TopKRequest,
    coalesce,
    execute_groups,
)

_STOP = None  # queue sentinel; one per worker, re-enqueued if drained early

_STAT_KEYS = (
    "requests", "batches", "max_batch_requests",
    "topk_queries", "topk_launches", "pair_queries", "pair_launches",
    "neighbours_queries", "stream_chunks", "store_refreshes",
)


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Tuning knobs of one serving deployment (picklable: it crosses the
    process boundary to every worker).

    Example::

        cfg = ServingConfig(workers=4, routing=True, kernel="pallas")
    """

    workers: int = 2
    batch_window_ms: float = 2.0      # micro-batch latency budget
    max_batch: int = 64               # requests coalesced per launch, at most
    kernel: str = "numpy"             # "numpy" | "pallas" (see store/query.py)
    cache_rows: int = 4096            # per-worker LRU capacity
    routing: bool = False             # hot-term routing: per-worker queues
    stats_interval_s: float = 0.0     # 0 = snapshot only at worker exit
    refresh_interval_ms: float = 0.0  # 0 = refresh only between micro-batches

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.batch_window_ms < 0:
            raise ValueError("batch_window_ms must be >= 0")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.stats_interval_s < 0:
            raise ValueError("stats_interval_s must be >= 0")
        if self.refresh_interval_ms < 0:
            raise ValueError("refresh_interval_ms must be >= 0")


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------


def _serve_batch(engine, batch, response_q, worker_id: int, stats: dict) -> None:
    """Coalesce one micro-batch of request envelopes and answer it with as
    few kernel launches as possible, through the same ``execute_groups``
    path as ``QueryEngine.execute``. Invalid requests get error responses
    and do not poison the rest of the batch."""
    stats["batches"] += 1
    stats["requests"] += len(batch)
    stats["max_batch_requests"] = max(stats["max_batch_requests"], len(batch))
    meta = {"worker": worker_id, "batch_requests": len(batch)}
    finished: set = set()  # tags whose final message went out

    def emit(tag, ok, payload, *, seq=0, last=True, extra=None):
        cid, rid, part, parts = tag
        m = {**meta, **(extra or {})}
        if last:
            finished.add(tag)
        response_q.put((cid, rid, part, parts, seq, last, ok, payload, m))

    # envelopes are (cid, rid, part, parts, request[, t_submit]); the
    # trailing submit timestamp (unix time, for queue-wait histograms) is
    # optional so hand-built 5-tuple envelopes keep working
    tagged = [
        ((cid, rid, part, parts), req)
        for cid, rid, part, parts, req, *_ in batch
    ]
    try:
        execute_groups(engine, coalesce(tagged), emit, stats=stats)
    except Exception as e:
        # an unexpected error (e.g. a segment racing a parent compact())
        # must not kill the worker with clients blocked on responses: fail
        # every request that has not answered yet and keep serving
        msg = f"worker {worker_id} error: {type(e).__name__}: {e}"
        for tag, _ in tagged:
            if tag not in finished:
                emit(tag, False, ("serving_error", msg))


def _worker_payload(stats: dict, engine, registry) -> dict:
    """One picklable stats-queue snapshot: the worker's counters dict plus
    its metrics registry snapshot (mergeable histograms included)."""
    out = dict(stats)
    out.update(engine.stats)  # cache_hits / cache_misses
    hits, misses = out["cache_hits"], out["cache_misses"]
    out["cache_hit_rate"] = round(hits / max(hits + misses, 1), 4)
    return {
        "stats": out,
        "metrics": registry.snapshot(),
        # manifest generation / segment census as this worker sees it; the
        # parent keeps the highest-generation view (a mid-commit sibling may
        # briefly lag by one refresh)
        "freshness": engine.store.freshness(),
    }


def _worker_main(
    worker_id: int,
    store_path: str,
    cfg: ServingConfig,
    request_q,
    response_q,
    stats_q,
) -> None:
    """One serving worker: open the store (mmap — pages shared with every
    sibling via the OS page cache), then loop: block for a request, drain the
    queue under the latency budget, serve the coalesced batch. Between
    batches the store manifest is refreshed, so parent-process mutations
    (append/compact) invalidate this worker's row cache exactly like they
    invalidate a direct engine's.

    Telemetry rides a private enabled :class:`repro.obs.Registry` (the
    process-global one stays disabled): per-request queue-wait and latency,
    per-batch execute time and size, query counters via the engine. A
    ``("snap", id, payload)`` snapshot goes on the stats queue at most every
    ``stats_interval_s`` seconds (0 = never), and a ``("final", ...)`` one
    always goes out at exit — so the parent loses at most one interval of
    data if this process dies."""
    from repro.store.query import QueryEngine
    from repro.store.segments import Store

    reg = obs.Registry(enabled=True, max_events=10_000)
    # the registry reaches the segments too: codec/bloom counters
    # (blocks decoded, cache hits, bloom negatives) ride the same snapshots
    engine = QueryEngine(
        Store.open(store_path, registry=reg), cache_rows=cfg.cache_rows,
        kernel=cfg.kernel, registry=reg,
    )
    stats = {k: 0 for k in _STAT_KEYS}
    h_wait = reg.histogram("serving/queue_wait_s")
    h_exec = reg.histogram("serving/execute_s")
    h_lat = reg.histogram("serving/request_latency_s")
    h_bsz = reg.histogram("serving/batch_requests")
    window_s = cfg.batch_window_ms / 1e3
    interval = cfg.stats_interval_s
    refresh_s = cfg.refresh_interval_ms / 1e3
    # idle wake-up: the shorter of the two periodic duties (stats snapshot,
    # manifest refresh); None blocks forever when neither is configured —
    # an idle worker then only refreshes when traffic arrives, as before
    idle_duties = [t for t in (interval, refresh_s) if t > 0]
    idle_timeout = min(idle_duties) if idle_duties else None
    last_pub = last_refresh = time.monotonic()
    stop = False
    while not stop:
        try:
            req = request_q.get(timeout=idle_timeout)
        except queue.Empty:  # idle: periodic duties, then wait again
            now = time.monotonic()
            if refresh_s and now - last_refresh >= refresh_s:
                # an idle server still follows the manifest: segments a
                # stream daemon committed become queryable without traffic
                if engine.store.refresh():
                    stats["store_refreshes"] += 1
                last_refresh = now
            if interval and now - last_pub >= interval:
                stats_q.put(
                    ("snap", worker_id, _worker_payload(stats, engine, reg))
                )
                last_pub = now
            continue
        if req is _STOP:
            break
        batch = [req]
        deadline = time.perf_counter() + window_s
        while len(batch) < cfg.max_batch:
            timeout = deadline - time.perf_counter()
            if timeout <= 0:
                break
            try:
                nxt = request_q.get(timeout=timeout)
            except queue.Empty:
                break
            if nxt is _STOP:
                request_q.put(_STOP)  # hand the sentinel to a sibling
                stop = True
                break
            batch.append(nxt)
        if engine.store.refresh():  # cross-process append/compact visibility
            stats["store_refreshes"] += 1
        last_refresh = time.monotonic()
        # queue wait = batch start minus client submit; unix time is the one
        # clock both processes share (perf_counter epochs differ per process)
        t_start = time.time()
        for item in batch:
            if len(item) > 5 and item[5] is not None:
                h_wait.record(max(t_start - item[5], 0.0))
        t0 = time.perf_counter()
        _serve_batch(engine, batch, response_q, worker_id, stats)
        h_exec.record(time.perf_counter() - t0)
        h_bsz.record(len(batch))
        reg.gauge("serving/batch_window_occupancy").set(
            len(batch) / cfg.max_batch
        )
        t_end = time.time()
        for item in batch:
            if len(item) > 5 and item[5] is not None:
                h_lat.record(max(t_end - item[5], 0.0))
        if interval and time.monotonic() - last_pub >= interval:
            stats_q.put(("snap", worker_id, _worker_payload(stats, engine, reg)))
            last_pub = time.monotonic()
    stats_q.put(("final", worker_id, _worker_payload(stats, engine, reg)))


# ---------------------------------------------------------------------------
# server side
# ---------------------------------------------------------------------------


class ServingError(RuntimeError):
    """A request failed inside a worker; carries the worker's message."""


class _StreamIterator:
    """Chunk iterator of one streamed top-k request. Cleanup (abandoning the
    request id so in-flight chunks are discarded, not buffered forever) is
    guaranteed whether the stream is fully consumed, closed early, errors,
    or is dropped before the first ``next()`` — a plain generator's
    ``finally`` never runs if the body is never entered."""

    def __init__(self, client: "CoocClient", rid: int, timeout: float):
        self._client = client
        self._rid = rid
        self._timeout = timeout
        self._in_flight = 1
        self._closed = False

    def __iter__(self):
        return self

    def __next__(self):
        if self._closed:
            raise StopIteration
        try:
            _, _, seq, last, ok, payload, meta = self._client._next_msg(
                self._rid, self._timeout
            )
        except Exception:
            self.close()
            raise
        self._client.last_meta = meta
        if last:
            self._in_flight = 0
        if not ok:
            self.close()
            self._client._raise(payload)
        if last:
            self.close()
        return payload

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._client._abandon(self._rid, self._in_flight)

    def __del__(self):  # dropped without consumption
        self.close()


class CoocClient:
    """A client handle bound to one :class:`CoocServer`.

    Each concurrent client (thread) gets its own handle via
    ``server.client()``; a handle's methods are blocking RPCs and may be
    called from exactly one thread. ``last_meta`` exposes how the previous
    request was served (worker id, micro-batch size, coalesced requests).

    ``execute()`` mirrors ``QueryEngine.execute``: a batch of typed request
    objects in, one result per request out — the planner may split a request
    across routed workers and this handle reassembles the slices
    byte-identically.

    Example::

        client = server.client()
        ids, scores = client.topk([3, 17], k=10)
        client.last_meta["batch_requests"]   # how many requests shared the batch
    """

    def __init__(self, server: "CoocServer", client_id: int, box: "queue.Queue"):
        self._server = server
        self._client_id = client_id
        self._box = box
        self._req_ids = itertools.count()
        self._msgs: dict[int, list] = {}       # rid -> buffered messages
        self._positions: dict[int, dict] = {}  # rid -> {part: positions}
        self._discard: dict[int, int] = {}     # abandoned rid -> parts in flight
        self.last_meta: dict = {}

    # ------------------------------------------------------------- typed API
    def execute(self, requests, *, timeout: float = 60.0) -> list:
        """Submit a batch of typed requests; returns one result per request
        (streamed top-k yields an iterator of chunks). All parts of all
        requests are submitted before any response is awaited, so distinct
        requests can share a worker micro-batch."""
        plan = self._server.planner.plan(requests)
        entries = []
        for req, parts in zip(plan.requests, plan.parts):
            rid = next(self._req_ids)
            self._positions[rid] = {rp.part: rp.positions for rp in parts}
            for rp in parts:
                self._server._submit(
                    rp.worker,
                    (self._client_id, rid, rp.part, rp.parts, rp.request,
                     time.time()),
                )
            entries.append((rid, req))
        out = []
        for idx, (rid, req) in enumerate(entries):
            try:
                if isinstance(req, TopKRequest) and req.chunk is not None:
                    out.append(self._stream(rid, req, timeout))
                else:
                    out.append(self._assemble(rid, req, timeout))
            except Exception:
                # the failing request abandoned itself; abandon the already
                # submitted later siblings too, or their responses would
                # buffer in _msgs forever
                for later_rid, _ in entries[idx + 1:]:
                    planned = max(len(self._positions.pop(later_rid, {})), 1)
                    self._abandon(later_rid, planned)
                raise
        return out

    def topk(self, terms, k: int = 10, *, score: str = "count", timeout: float = 60.0):
        """Top-k neighbours, served through the shared worker pool. Returns
        ``(ids (B, k), scores (B, k))`` exactly like ``QueryEngine.topk``."""
        return self.execute([TopKRequest(terms, k=k, score=score)],
                            timeout=timeout)[0]

    def topk_stream(
        self, terms, k: int, *, score: str = "count", chunk: int = 1024,
        timeout: float = 60.0,
    ):
        """Streaming top-k: iterator of score-ordered ``(ids, scores)``
        column blocks of width ≤ ``chunk``; concatenation along axis 1
        equals the monolithic ``topk`` result exactly."""
        return self.execute(
            [TopKRequest(terms, k=k, score=score, chunk=chunk)], timeout=timeout
        )[0]

    def pair_counts(self, pairs, *, timeout: float = 60.0) -> np.ndarray:
        """Exact counts for a (B, 2) pair batch, served remotely."""
        return self.execute([PairCountsRequest(pairs)], timeout=timeout)[0]

    def neighbours(self, t: int, *, timeout: float = 60.0):
        """The full merged ``(ids, counts)`` row of term ``t``, served
        remotely (out-of-vocab ids raise the engine's ValueError)."""
        return self.execute([NeighboursRequest(t)], timeout=timeout)[0]

    # ------------------------------------------------------------- assembly
    def _next_msg(self, rid: int, timeout: float):
        """Next buffered/arriving message for ``rid`` (others are buffered;
        messages for abandoned request ids are dropped, not buffered)."""
        deadline = time.monotonic() + timeout
        while not self._msgs.get(rid):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(f"no response for request {rid} in {timeout}s")
            try:
                got_rid, *msg = self._box.get(timeout=remaining)
            except queue.Empty:
                raise TimeoutError(
                    f"no response for request {rid} in {timeout}s"
                ) from None
            if got_rid in self._discard:
                if msg[3]:  # last flag: one in-flight part fully drained
                    self._discard[got_rid] -= 1
                    if self._discard[got_rid] <= 0:
                        del self._discard[got_rid]
                continue
            self._msgs.setdefault(got_rid, []).append(msg)
        return self._msgs[rid].pop(0)

    def _abandon(self, rid: int, in_flight: int) -> None:
        """Stop expecting ``rid`` (error, timeout, or a dropped stream):
        free its buffers and mark however many part-final messages are
        still in flight for discard, so a dead request id can never grow
        ``_msgs`` forever."""
        for msg in self._msgs.pop(rid, []):
            if msg[3]:  # last flag
                in_flight -= 1
        if in_flight > 0:
            self._discard[rid] = in_flight

    def _raise(self, payload):
        kind, message = payload
        if kind == "value_error":
            raise ValueError(message)  # mirror QueryEngine's local errors
        raise ServingError(message)

    def _assemble(self, rid: int, req, timeout: float):
        """Collect all parts of a non-streamed request and scatter routed
        top-k slices back into their original row positions."""
        positions = self._positions.pop(rid, {})
        planned = max(len(positions), 1)
        done: dict[int, tuple] = {}
        finished = 0
        try:
            while finished < planned:
                part, nparts, seq, last, ok, payload, meta = self._next_msg(
                    rid, timeout
                )
                self.last_meta = meta
                if last:
                    finished += 1
                if not ok:
                    self._raise(payload)
                done[part] = payload
        except Exception:
            self._abandon(rid, planned - finished)
            raise
        self._msgs.pop(rid, None)
        if planned == 1:
            return done[0]
        # routed top-k: scatter each worker's rows back by original position
        ids_p, scores_p = done[0]
        B = req.batch
        ids = np.empty((B, ids_p.shape[1]), dtype=ids_p.dtype)
        scores = np.empty((B, scores_p.shape[1]), dtype=scores_p.dtype)
        for part, (pids, pscores) in done.items():
            pos = positions[part]
            ids[pos] = pids
            scores[pos] = pscores
        return ids, scores

    def _stream(self, rid: int, req, timeout: float) -> _StreamIterator:
        """Lazy iterator over a streamed top-k's chunks, in score order.
        Dropping/closing the iterator at any point (even before the first
        ``next()``) abandons the rid, so unconsumed in-flight chunks are
        discarded instead of buffered forever."""
        self._positions.pop(rid, None)
        return _StreamIterator(self, rid, timeout)


class CoocServer:
    """Serve one on-disk store to many clients through shared-mmap worker
    processes with cross-client micro-batching and (optionally) hot-term
    routing.

    Lifecycle: ``start()`` spawns the workers and the response router;
    ``client()`` mints per-thread client handles; ``stats()`` is the live
    (and, after stop, final) aggregated view — counters summed and latency
    histograms merged across workers, with server-side queue-wait / execute
    / request-latency percentiles under ``"server_timing"``; ``stop()``
    drains the workers and returns the final stats. A worker that crashes
    costs its last reporting interval of data, not the run: its freshest
    snapshot stands in and ``stats()["workers_lost"]`` counts it. Usable as
    a context manager.

    Example::

        with CoocServer(path, workers=4, routing=True) as server:
            ids, scores = server.client().topk([3], k=10)
            server.stats()["requests"]       # live merged view
        # __exit__ stopped the workers; server.stats() is now final
    """

    def __init__(
        self,
        store_path: str,
        *,
        workers: int = 2,
        batch_window_ms: float = 2.0,
        max_batch: int = 64,
        kernel: str = "numpy",
        cache_rows: int = 4096,
        routing: bool = False,
        stats_interval_s: float = 0.0,
        refresh_interval_ms: float = 0.0,
    ):
        from repro.store.segments import Store

        if not Store.exists(store_path):
            raise FileNotFoundError(f"no store at {store_path}")
        # the client-side planner: with routing, terms are hashed to the
        # worker that owns their cache row; without, one shared queue. The
        # planner's choices are authoritative — the worker config is built
        # from them, so plan and deployment cannot disagree (routing is
        # reported as inactive when workers == 1).
        self.planner = QueryPlanner(
            workers=workers, routing=routing, kernel=kernel
        )
        self.store_path = store_path
        self.config = ServingConfig(
            workers=workers,
            batch_window_ms=batch_window_ms,
            max_batch=max_batch,
            kernel=self.planner.kernel,
            cache_rows=cache_rows,
            routing=self.planner.routing,
            stats_interval_s=stats_interval_s,
            refresh_interval_ms=refresh_interval_ms,
        )
        self._stats_final: dict = {}
        self._worker_last: dict[int, dict] = {}   # freshest payload per worker
        self._worker_final: set[int] = set()
        self._procs: list = []
        self._boxes: dict[int, queue.Queue] = {}
        self._client_ids = itertools.count()
        self._router = None
        self._started = False

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "CoocServer":
        if self._started:
            raise RuntimeError("server already started")
        # spawned children re-import repro.store.serving: spawn_friendly_env
        # makes the package root importable and hides a script-style
        # __main__ for the duration of the spawns (see store/spawn.py)
        with spawn_friendly_env() as ctx:
            # routed: one request queue per worker (the planner picks the
            # queue); unrouted: one shared queue every worker drains
            # (work stealing)
            n_queues = self.config.workers if self.config.routing else 1
            self._request_qs = [ctx.Queue() for _ in range(n_queues)]
            self._response_q = ctx.Queue()
            self._stats_q = ctx.Queue()
            for i in range(self.config.workers):
                p = ctx.Process(
                    target=_worker_main,
                    args=(
                        i,
                        self.store_path,
                        self.config,
                        self._request_qs[i % n_queues],
                        self._response_q,
                        self._stats_q,
                    ),
                    daemon=True,
                )
                p.start()
                self._procs.append(p)
        self._router = threading.Thread(target=self._route, daemon=True)
        self._router.start()
        self._started = True
        return self

    def _route(self) -> None:
        """Fan responses out of the single mp queue into per-client boxes."""
        while True:
            item = self._response_q.get()
            if item is _STOP:
                return
            cid, rid, part, parts, seq, last, ok, payload, meta = item
            box = self._boxes.get(cid)
            if box is not None:
                box.put((rid, part, parts, seq, last, ok, payload, meta))

    def _submit(self, worker: int | None, envelope) -> None:
        if not self._started:
            raise RuntimeError("server not started (call start())")
        qs = self._request_qs
        qs[worker % len(qs) if worker is not None else 0].put(envelope)

    def client(self) -> CoocClient:
        """Mint a client handle (one per concurrent client thread)."""
        cid = next(self._client_ids)
        box: queue.Queue = queue.Queue()
        self._boxes[cid] = box
        return CoocClient(self, cid, box)

    # ------------------------------------------------------------ telemetry
    def _drain_stats_q(self) -> None:
        """Pull every pending worker snapshot off the stats queue. Each
        worker's freshest payload wins; ``("final", ...)`` marks a clean
        exit."""
        while True:
            try:
                kind, wid, payload = self._stats_q.get_nowait()
            except queue.Empty:
                return
            self._worker_last[wid] = payload
            if kind == "final":
                self._worker_final.add(wid)

    def stats(self) -> dict:
        """Aggregated serving stats: counters summed and latency histograms
        merged across workers. Live (from the freshest per-worker snapshots)
        while the server runs; final after :meth:`stop`.

        Keys of note: ``server_timing`` (queue-wait / execute /
        request-latency p50/p95/p99 in ms, from the merged histograms),
        ``freshness`` (manifest generation, segment count per format
        version, seconds since the newest segment was created — the most
        advanced worker view wins, so it tracks a stream daemon's commits
        live), ``workers_lost`` (workers that never sent a final snapshot),
        ``storage`` (codec traffic on v2 compressed stores: blocks decoded,
        block-cache hit rate, bloom negative rate — zeros on raw v1),
        ``metrics`` (the raw merged snapshot — feed it to
        ``repro.obs.prometheus_text``), ``per_worker`` (each worker's own
        counters, e.g. per-worker ``cache_hit_rate`` under routing)."""
        if not self._started:
            return self._stats_final
        self._drain_stats_q()
        return self._aggregate(live=True)

    def _aggregate(self, *, live: bool, workers_lost: int = 0) -> dict:
        per_worker = {w: p["stats"] for w, p in self._worker_last.items()}
        agg = {
            k: sum(w[k] for w in per_worker.values())
            for k in next(iter(per_worker.values()))
            if k != "cache_hit_rate"
        } if per_worker else {}
        if agg:
            agg["max_batch_requests"] = max(
                w["max_batch_requests"] for w in per_worker.values()
            )
            agg["avg_requests_per_batch"] = round(
                agg["requests"] / max(agg["batches"], 1), 2
            )
            agg["cache_hit_rate"] = round(
                agg["cache_hits"]
                / max(agg["cache_hits"] + agg["cache_misses"], 1),
                4,
            )
        metrics = obs.merge_snapshots(
            [self._worker_last[w]["metrics"] for w in sorted(self._worker_last)]
        )
        timing = {}
        for key, hname in (
            ("queue_wait_ms", "serving/queue_wait_s"),
            ("execute_ms", "serving/execute_s"),
            ("request_latency_ms", "serving/request_latency_s"),
        ):
            state = metrics["histograms"].get(hname)
            if state:
                h = obs.Histogram.from_state(state)
                timing[key] = {
                    "p50": round(h.percentile(50) * 1e3, 3),
                    "p95": round(h.percentile(95) * 1e3, 3),
                    "p99": round(h.percentile(99) * 1e3, 3),
                    "mean": round(h.mean * 1e3, 3),
                    "count": h.count,
                }
        # freshness: the most advanced manifest view any worker has reported
        # (highest generation wins — a sibling mid-refresh may lag by one),
        # with staleness derived from the newest segment's creation stamp
        fresh_views = [
            p["freshness"] for p in self._worker_last.values()
            if p.get("freshness")
        ]
        freshness = {}
        if fresh_views:
            freshness = dict(
                max(fresh_views, key=lambda f: f.get("generation", 0))
            )
            last_append = freshness.get("last_append_unix")
            freshness["seconds_since_last_append"] = (
                round(max(time.time() - last_append, 0.0), 3)
                if last_append else None
            )
        # storage-engine counters (v2 compressed segments; zeros on raw v1
        # stores): codec traffic plus derived block-cache / bloom hit rates
        ctr = metrics.get("counters", {})
        decoded = ctr.get("storage.blocks_decoded", 0)
        c_hits = ctr.get("storage.block_cache_hits", 0)
        c_miss = ctr.get("storage.block_cache_misses", 0)
        b_checks = ctr.get("storage.bloom_checks", 0)
        b_neg = ctr.get("storage.bloom_negative", 0)
        storage = {
            "blocks_decoded": decoded,
            "block_cache_hit_rate": round(c_hits / max(c_hits + c_miss, 1), 4),
            "bloom_checks": b_checks,
            "bloom_negative": b_neg,
            "bloom_negative_rate": round(b_neg / max(b_checks, 1), 4),
        }
        return {
            "workers": self.config.workers,
            "kernel": self.config.kernel,
            "batch_window_ms": self.config.batch_window_ms,
            "routing": self.config.routing,
            "live": live,
            **agg,
            "workers_lost": workers_lost,
            "server_timing": timing,
            "freshness": freshness,
            "storage": storage,
            "metrics": metrics,
            "per_worker": [per_worker[w] for w in sorted(per_worker)],
        }

    def stop(self, timeout: float = 120.0) -> dict:
        """Drain the workers and return the final aggregated serving stats.

        A worker that died without its final snapshot no longer takes the
        whole ``stop()`` down: its freshest periodic snapshot (if any)
        stands in, and the loss is surfaced as ``stats()["workers_lost"]``
        — silent stats loss was the old failure mode."""
        if not self._started:
            return self._stats_final
        if self.config.routing:
            for q in self._request_qs:
                q.put(_STOP)
        else:
            for _ in self._procs:
                self._request_qs[0].put(_STOP)
        expected = set(range(len(self._procs)))
        deadline = time.monotonic() + timeout
        while self._worker_final < expected and time.monotonic() < deadline:
            try:
                kind, wid, payload = self._stats_q.get(timeout=0.1)
            except queue.Empty:
                missing = expected - self._worker_final
                if all(self._procs[w].exitcode is not None for w in missing):
                    break  # the dead will never report: stop waiting
                continue
            self._worker_last[wid] = payload
            if kind == "final":
                self._worker_final.add(wid)
        if self._worker_final < expected:
            # exitcodes can appear before the queue pipe is fully flushed:
            # one grace drain before declaring anyone lost
            time.sleep(0.05)
            self._drain_stats_q()
        workers_lost = len(expected - self._worker_final)
        for p in self._procs:
            p.join(timeout=max(deadline - time.monotonic(), 0.1))
            if p.is_alive():
                p.terminate()
        self._response_q.put(_STOP)
        self._router.join(timeout=5)
        self._started = False
        self._stats_final = self._aggregate(
            live=False, workers_lost=workers_lost
        )
        return self._stats_final

    def __enter__(self) -> "CoocServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()
