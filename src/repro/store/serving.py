"""Multi-client co-occurrence serving: shared-mmap workers, micro-batched
kernel launches, typed wire protocol, hot-term routing, streaming top-k,
and a supervised, overload-shedding fault-tolerance layer.

The query engine (store/query.py) already batches *within* one call; this
layer batches *across clients*, the way a real serving deployment amortizes
kernel launches over concurrent traffic:

    clients ──▶ request queue(s) ──▶ worker processes ──▶ response queue ─▶ router
    (threads)   (shared or routed)   (N × Store + QueryEngine)   (mp)      (thread)

* **Typed wire protocol** — the request dataclasses of store/requests.py
  *are* what crosses the process boundary: a client submits
  ``(client_id, request_id, part, parts, request, t_submit, deadline)``
  envelopes (:func:`repro.store.requests.make_envelope`) whose payload
  is the same frozen ``TopKRequest | PairCountsRequest | NeighboursRequest``
  the in-process engine executes. Invalid queries (unknown score, bad dtype,
  k < 1) therefore fail at request construction on the client — a worker
  never sees them.
* **Shared mmap** — every worker process opens the same immutable segment
  files with ``np.memmap``; the OS page cache backs all mappings with one
  physical copy, so N workers serve a 100 GB store with ~one store's worth
  of resident pages. Workers ``Store.refresh()`` between micro-batches, so
  a manifest commit (append/ingest/compact) in the parent becomes visible
  to in-flight serving traffic without a restart; ``refresh_interval_ms``
  adds a periodic idle refresh, so a server with *no* traffic still
  follows a stream daemon's commits (see repro.stream).
* **Micro-batching with a latency budget** — a worker takes the first
  request off its queue, then keeps draining for at most ``batch_window_ms``
  (or until ``max_batch`` requests), coalesces compatible requests — same
  ``(k, score)`` for top-k, all pair lookups together — and executes each
  group as **one** batched launch via the same ``execute_groups`` path the
  in-process engine uses.
* **Hot-term routing** (``routing=True``) — each worker gets its own request
  queue and the client-side :class:`~repro.store.requests.QueryPlanner`
  splits every top-k request by term ownership (``route_term``), so the N
  per-worker LRU row caches hold N disjoint slices of the vocabulary
  instead of N copies of the Zipf head. Per-worker hit rates are surfaced
  in the server's stats.
* **Worker supervision** — before executing a micro-batch, a worker
  *claims* its request tags on the response queue; a supervisor thread
  watches worker exitcodes and, on death, immediately fails exactly the
  claimed (in-flight) tags back to their clients as a typed
  :class:`WorkerDied` — unclaimed envelopes stay queued and survive the
  respawn. The worker slot is respawned up to ``max_respawns`` times with
  its request queue intact; while the replacement warms (and permanently,
  once the budget is spent) ``_submit`` re-routes the slot's vocabulary
  slice to the next live worker — routing is a cache-locality
  optimization, never a correctness dependency, so any worker can serve
  any slice.
* **Admission control** — ``max_inflight`` bounds every request queue;
  a full queue rejects at submit with a typed :class:`ServerOverloaded`
  (load shedding — never a silent drop), and each envelope carries the
  client's absolute deadline so a worker skips requests that have already
  expired client-side instead of burning a launch on them.
  ``CoocClient.execute(retries=...)`` retries sheds and worker deaths
  with jittered exponential backoff (:func:`backoff_delay`) — never
  timeouts, and never mid-stream.
* **Fault injection** — the env-gated failpoints of
  :mod:`repro.runtime.faultinject` (``kill-worker``, ``stall-queue``,
  ``drop-response``) are compiled into the worker loop, so tests and
  ``benchmarks/resilience_bench.py`` script kill/stall/drop schedules
  through ``REPRO_FAULTS`` without patching code. Disarmed they cost one
  falsy check per batch.
* **Cross-process telemetry** — every worker keeps a private
  :class:`repro.obs.Registry` (queue-wait / execute / request-latency
  histograms, batch-size distribution, query counters) and publishes
  picklable snapshots over the stats queue: periodically between
  micro-batches when ``stats_interval_s`` is set, and always once at exit.
  The parent merges them (histograms merge bucket-wise, so p50/p95/p99 are
  true pooled percentiles) into a live ``server.stats()`` — no shared
  memory, no extra sockets. A worker that dies mid-flight costs its last
  interval of data, not the whole run: its freshest snapshot is archived
  and keeps counting in the aggregate while the replacement starts fresh.
  Resilience counters (``serving/shed``, ``serving/respawns``,
  ``serving/worker_died_failures`` parent-side; ``serving/deadline_expired``
  worker-side) ride the same snapshots into ``stats()["resilience"]``.
* **Streaming top-k** — a ``TopKRequest(chunk=c)`` comes back as an iterator
  of score-ordered ``(ids, scores)`` column blocks: large-k responses cross
  the queue chunk by chunk instead of as one monolithic pickle. If the
  serving worker dies mid-stream, the iterator raises :class:`WorkerDied`
  on the next ``next()`` instead of stalling until the timeout.

Example (driver-side; see launch/cooc_serve.py for the full workload)::

    server = CoocServer(store_path, workers=4, routing=True,
                        batch_window_ms=2.0, kernel="pallas",
                        max_inflight=256, max_respawns=2).start()
    client = server.client()                 # one per client thread
    ids, scores = client.topk([3, 17], k=10, score="pmi")
    for ids_c, scores_c in client.topk_stream([3], k=5000, chunk=512):
        ...                                  # score-ordered chunks
    server.stats()["resilience"]             # shed / respawns / deadline_expired
    stats = server.stop()                    # {"requests": ..., "cache_hit_rate": ...}

Workers are **spawned** (never forked): JAX runtimes do not survive a fork,
and a spawned worker importing the store from disk is exactly the
multi-process serving topology this layer exists to exercise.
"""

from __future__ import annotations

import dataclasses
import itertools
import queue
import random
import threading
import time

import numpy as np

from repro import obs
from repro.runtime import faultinject
from repro.store.spawn import spawn_friendly_env
from repro.store.requests import (
    NeighboursRequest,
    PairCountsRequest,
    QueryPlanner,
    TopKRequest,
    coalesce,
    envelope_times,
    execute_groups,
    make_envelope,
)


class _StopSentinel:
    """Queue stop marker. mp queues *pickle* items, so a sentinel cannot be
    compared by identity across the process boundary — ``isinstance`` is the
    only check that survives a round-trip. A plain ``None`` sentinel (the
    old idiom) additionally collides with any stray ``None`` that lands on
    a queue during a respawn race and silently stops a healthy worker."""

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "<serving stop sentinel>"


_STOP = _StopSentinel()  # one per worker, re-enqueued if drained early


def _is_stop(item) -> bool:
    return isinstance(item, _StopSentinel)


_STAT_KEYS = (
    "requests", "batches", "max_batch_requests",
    "topk_queries", "topk_launches", "pair_queries", "pair_launches",
    "neighbours_queries", "stream_chunks", "store_refreshes",
)

_SUPERVISE_INTERVAL_S = 0.02  # exitcode poll period of the supervisor thread


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Tuning knobs of one serving deployment (picklable: it crosses the
    process boundary to every worker).

    Example::

        cfg = ServingConfig(workers=4, routing=True, kernel="pallas",
                            max_inflight=256, max_respawns=2)
    """

    workers: int = 2
    batch_window_ms: float = 2.0      # micro-batch latency budget
    max_batch: int = 64               # requests coalesced per launch, at most
    kernel: str = "numpy"             # "numpy" | "pallas" (see store/query.py)
    cache_rows: int = 4096            # per-worker LRU capacity
    routing: bool = False             # hot-term routing: per-worker queues
    stats_interval_s: float = 0.0     # 0 = snapshot only at worker exit
    refresh_interval_ms: float = 0.0  # 0 = refresh only between micro-batches
    max_inflight: int = 0             # per-queue envelope bound; 0 = unbounded
    max_respawns: int = 2             # supervisor respawn budget per worker slot

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.batch_window_ms < 0:
            raise ValueError("batch_window_ms must be >= 0")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.stats_interval_s < 0:
            raise ValueError("stats_interval_s must be >= 0")
        if self.refresh_interval_ms < 0:
            raise ValueError("refresh_interval_ms must be >= 0")
        if self.max_inflight < 0:
            raise ValueError("max_inflight must be >= 0 (0 = unbounded)")
        if self.max_respawns < 0:
            raise ValueError("max_respawns must be >= 0 (0 = never respawn)")


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------


def _serve_batch(engine, batch, response_q, worker_id: int, stats: dict) -> None:
    """Coalesce one micro-batch of request envelopes and answer it with as
    few kernel launches as possible, through the same ``execute_groups``
    path as ``QueryEngine.execute``. Invalid requests get error responses
    and do not poison the rest of the batch."""
    stats["batches"] += 1
    stats["requests"] += len(batch)
    stats["max_batch_requests"] = max(stats["max_batch_requests"], len(batch))
    meta = {"worker": worker_id, "batch_requests": len(batch)}
    finished: set = set()  # tags whose final message went out

    def emit(tag, ok, payload, *, seq=0, last=True, extra=None):
        cid, rid, part, parts = tag
        m = {**meta, **(extra or {})}
        if last:
            finished.add(tag)
        response_q.put((cid, rid, part, parts, seq, last, ok, payload, m))

    # envelopes are (cid, rid, part, parts, request[, t_submit[, deadline]]);
    # the trailing fields (see store/requests.py make_envelope) are optional
    # so hand-built 5-tuple envelopes keep working
    tagged = [
        ((cid, rid, part, parts), req)
        for cid, rid, part, parts, req, *_ in batch
    ]
    try:
        execute_groups(engine, coalesce(tagged), emit, stats=stats)
    except Exception as e:
        # an unexpected error (e.g. a segment racing a parent compact())
        # must not kill the worker with clients blocked on responses: fail
        # every request that has not answered yet and keep serving
        msg = f"worker {worker_id} error: {type(e).__name__}: {e}"
        for tag, _ in tagged:
            if tag not in finished:
                emit(tag, False, ("serving_error", msg))


class _FaultyChannel:
    """Response-queue proxy armed by the ``drop-response`` failpoint:
    discards the worker's next N answer messages instead of enqueueing
    them. Claims and deadline-expiry answers bypass the proxy — supervision
    must stay honest even while responses are being lost."""

    def __init__(self, response_q, fr, worker_id: int):
        self._q = response_q
        self._fr = fr
        self._worker_id = worker_id

    def put(self, item) -> None:
        if self._fr.drop_response(worker=self._worker_id):
            return
        self._q.put(item)


def _worker_payload(stats: dict, engine, registry, incarnation: int = 0) -> dict:
    """One picklable stats-queue snapshot: the worker's counters dict plus
    its metrics registry snapshot (mergeable histograms included). The
    incarnation stamp lets the parent ignore pipe-buffered snapshots from a
    dead incarnation after its replacement has started reporting."""
    out = dict(stats)
    out.update(engine.stats)  # cache_hits / cache_misses
    hits, misses = out["cache_hits"], out["cache_misses"]
    out["cache_hit_rate"] = round(hits / max(hits + misses, 1), 4)
    return {
        "stats": out,
        "metrics": registry.snapshot(),
        # manifest generation / segment census as this worker sees it; the
        # parent keeps the highest-generation view (a mid-commit sibling may
        # briefly lag by one refresh)
        "freshness": engine.store.freshness(),
        "incarnation": incarnation,
    }


def _worker_main(
    worker_id: int,
    store_path: str,
    cfg: ServingConfig,
    request_q,
    response_q,
    stats_q,
    incarnation: int = 0,
) -> None:
    """One serving worker: open the store (mmap — pages shared with every
    sibling via the OS page cache), then loop: block for a request, drain the
    queue under the latency budget, serve the coalesced batch. Between
    batches the store manifest is refreshed, so parent-process mutations
    (append/compact) invalidate this worker's row cache exactly like they
    invalidate a direct engine's.

    Fault-tolerance duties per batch: already-expired envelopes (deadline
    in the past) are answered with a ``deadline_expired`` error instead of
    executed; the surviving tags are *claimed* on the response queue
    (``("claim", wid, incarnation, tags)``) before execution, so the
    parent's supervisor knows exactly which requests die with this process;
    the :mod:`repro.runtime.faultinject` failpoints (stall, kill, drop)
    fire between claim and execution. A ``("ready", ...)`` stats message
    after the store opens tells the supervisor a respawned slot is warm.

    Telemetry rides a private enabled :class:`repro.obs.Registry` (the
    process-global one stays disabled): per-request queue-wait and latency,
    per-batch execute time and size, query counters via the engine. A
    ``("snap", id, payload)`` snapshot goes on the stats queue at most every
    ``stats_interval_s`` seconds (0 = never), and a ``("final", ...)`` one
    always goes out at exit — so the parent loses at most one interval of
    data if this process dies."""
    from repro.store.query import QueryEngine
    from repro.store.segments import Store

    fr = faultinject.from_env()
    reg = obs.Registry(enabled=True, max_events=10_000)
    # the registry reaches the segments too: codec/bloom counters
    # (blocks decoded, cache hits, bloom negatives) ride the same snapshots
    engine = QueryEngine(
        Store.open(store_path, registry=reg), cache_rows=cfg.cache_rows,
        kernel=cfg.kernel, registry=reg,
    )
    # the slot is warm: the supervisor clears this worker's degraded flag
    # and routed traffic returns to its own queue
    stats_q.put(("ready", worker_id, {"incarnation": incarnation}))
    stats = {k: 0 for k in _STAT_KEYS}
    c_expired = reg.counter("serving/deadline_expired")
    h_wait = reg.histogram("serving/queue_wait_s")
    h_exec = reg.histogram("serving/execute_s")
    h_lat = reg.histogram("serving/request_latency_s")
    h_bsz = reg.histogram("serving/batch_requests")
    serve_chan = (
        _FaultyChannel(response_q, fr, worker_id)
        if fr.active(faultinject.DROP_RESPONSE) else response_q
    )
    window_s = cfg.batch_window_ms / 1e3
    interval = cfg.stats_interval_s
    refresh_s = cfg.refresh_interval_ms / 1e3
    # idle wake-up: the shorter of the two periodic duties (stats snapshot,
    # manifest refresh); None blocks forever when neither is configured —
    # an idle worker then only refreshes when traffic arrives, as before
    idle_duties = [t for t in (interval, refresh_s) if t > 0]
    idle_timeout = min(idle_duties) if idle_duties else None
    last_pub = last_refresh = time.monotonic()
    stop = False
    while not stop:
        try:
            req = request_q.get(timeout=idle_timeout)
        except queue.Empty:  # idle: periodic duties, then wait again
            now = time.monotonic()
            if refresh_s and now - last_refresh >= refresh_s:
                # an idle server still follows the manifest: segments a
                # stream daemon committed become queryable without traffic
                if engine.store.refresh():
                    stats["store_refreshes"] += 1
                last_refresh = now
            if interval and now - last_pub >= interval:
                stats_q.put(
                    ("snap", worker_id,
                     _worker_payload(stats, engine, reg, incarnation))
                )
                last_pub = now
            continue
        if _is_stop(req):
            break
        if not isinstance(req, tuple) or len(req) < 5:
            continue  # a stray item (e.g. a bare None) is not a stop signal
        batch = [req]
        deadline = time.perf_counter() + window_s
        while len(batch) < cfg.max_batch:
            timeout = deadline - time.perf_counter()
            if timeout <= 0:
                break
            try:
                nxt = request_q.get(timeout=timeout)
            except queue.Empty:
                break
            if _is_stop(nxt):
                request_q.put(_STOP)  # hand the sentinel to a sibling
                stop = True
                break
            if not isinstance(nxt, tuple) or len(nxt) < 5:
                continue
            batch.append(nxt)
        if engine.store.refresh():  # cross-process append/compact visibility
            stats["store_refreshes"] += 1
        last_refresh = time.monotonic()
        # a request whose client-side deadline already passed gets a typed
        # error instead of a kernel launch: the client stopped waiting, so
        # the launch would be pure wasted capacity under overload
        now = time.time()
        live = []
        for item in batch:
            _t_sub, dl = envelope_times(item)
            if dl is not None and now > dl:
                c_expired.inc()
                response_q.put((
                    item[0], item[1], item[2], item[3], 0, True, False,
                    ("deadline_expired",
                     f"deadline passed {now - dl:.3f}s before worker "
                     f"{worker_id} dequeued the request"),
                    {"worker": worker_id},
                ))
                continue
            live.append(item)
        if not live:
            continue
        batch = live
        # claim before executing: if this process dies mid-batch the
        # supervisor fails exactly these tags — queued-but-unclaimed
        # envelopes survive for the respawned worker
        response_q.put((
            "claim", worker_id, incarnation,
            [(it[0], it[1], it[2], it[3]) for it in batch],
        ))
        if fr:
            stall = fr.stall_queue(worker=worker_id)
            if stall:
                time.sleep(stall)
            if fr.kill_worker(worker=worker_id, batches_done=stats["batches"]):
                faultinject.kill_self()
        # queue wait = batch start minus client submit; unix time is the one
        # clock both processes share (perf_counter epochs differ per process)
        t_start = time.time()
        for item in batch:
            t_sub, _dl = envelope_times(item)
            if t_sub is not None:
                h_wait.record(max(t_start - t_sub, 0.0))
        t0 = time.perf_counter()
        _serve_batch(engine, batch, serve_chan, worker_id, stats)
        h_exec.record(time.perf_counter() - t0)
        h_bsz.record(len(batch))
        reg.gauge("serving/batch_window_occupancy").set(
            len(batch) / cfg.max_batch
        )
        t_end = time.time()
        for item in batch:
            t_sub, _dl = envelope_times(item)
            if t_sub is not None:
                h_lat.record(max(t_end - t_sub, 0.0))
        if interval and time.monotonic() - last_pub >= interval:
            stats_q.put(
                ("snap", worker_id,
                 _worker_payload(stats, engine, reg, incarnation))
            )
            last_pub = time.monotonic()
    stats_q.put(
        ("final", worker_id, _worker_payload(stats, engine, reg, incarnation))
    )


# ---------------------------------------------------------------------------
# server side
# ---------------------------------------------------------------------------


class ServingError(RuntimeError):
    """A request failed inside a worker; carries the worker's message."""


class WorkerDied(ServingError):
    """The worker serving this request died mid-flight; the supervisor
    failed the request back immediately instead of letting the client block
    until its timeout. Safe to retry (``execute(retries=...)`` does)."""


class ServerOverloaded(ServingError):
    """The request was shed at submit because the target queue is full
    (``max_inflight``). Deliberate load shedding, not a failure of the
    request itself — back off and retry (``execute(retries=...)`` does)."""


def backoff_delay(
    attempt: int,
    base_ms: float = 25.0,
    cap_ms: float = 2000.0,
    rng=random.random,
) -> float:
    """Jittered exponential backoff delay in **seconds** for retry number
    ``attempt`` (0-based): uniform in 50–100% of ``base_ms * 2**attempt``,
    capped at ``cap_ms``. The jitter decorrelates clients that were all
    shed by the same full queue — synchronized retries would just
    reproduce the overload spike they are backing off from.

    Example::

        >>> backoff_delay(0, base_ms=100, rng=lambda: 0.0)
        0.05
        >>> backoff_delay(2, base_ms=100, rng=lambda: 1.0)
        0.4
        >>> backoff_delay(10, base_ms=100, cap_ms=500, rng=lambda: 1.0)
        0.5
    """
    span_ms = min(base_ms * (2.0 ** attempt), cap_ms)
    return (0.5 + 0.5 * rng()) * span_ms / 1e3


class _StreamIterator:
    """Chunk iterator of one streamed top-k request. Cleanup (forgetting the
    request id so in-flight chunks are discarded, not buffered forever) is
    guaranteed whether the stream is fully consumed, closed early, errors,
    or is dropped before the first ``next()`` — a plain generator's
    ``finally`` never runs if the body is never entered. If the serving
    worker dies mid-stream, the supervisor's synthetic failure makes the
    next ``next()`` raise :class:`WorkerDied` promptly instead of stalling
    until the timeout."""

    def __init__(self, client: "CoocClient", rid: int, timeout: float):
        self._client = client
        self._rid = rid
        self._timeout = timeout
        self._in_flight = 1
        self._closed = False

    def __iter__(self):
        return self

    def __next__(self):
        if self._closed:
            raise StopIteration
        try:
            _, _, seq, last, ok, payload, meta = self._client._next_msg(
                self._rid, self._timeout
            )
        except Exception:
            self.close()
            raise
        self._client.last_meta = meta
        if last:
            self._in_flight = 0
        if not ok:
            self.close()
            self._client._raise(payload)
        if last:
            self.close()
        return payload

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._client._forget(self._rid, self._in_flight)

    def __del__(self):  # dropped without consumption
        self.close()


class CoocClient:
    """A client handle bound to one :class:`CoocServer`.

    Each concurrent client (thread) gets its own handle via
    ``server.client()``; a handle's methods are blocking RPCs and may be
    called from exactly one thread. ``last_meta`` exposes how the previous
    request was served (worker id, micro-batch size, coalesced requests).

    ``execute()`` mirrors ``QueryEngine.execute``: a batch of typed request
    objects in, one result per request out — the planner may split a request
    across routed workers and this handle reassembles the slices
    byte-identically.

    Example::

        client = server.client()
        ids, scores = client.topk([3, 17], k=10)
        client.last_meta["batch_requests"]   # how many requests shared the batch
    """

    def __init__(self, server: "CoocServer", client_id: int, box: "queue.Queue"):
        self._server = server
        self._client_id = client_id
        self._box = box
        self._req_ids = itertools.count()
        self._msgs: dict[int, list] = {}       # rid -> buffered messages
        self._positions: dict[int, dict] = {}  # rid -> {part: positions}
        self._discard: dict[int, int] = {}     # forgotten rid -> parts in flight
        self.last_meta: dict = {}

    # ------------------------------------------------------------- typed API
    def execute(
        self,
        requests,
        *,
        timeout: float = 60.0,
        retries: int = 0,
        retry_backoff_ms: float = 25.0,
    ) -> list:
        """Submit a batch of typed requests; returns one result per request
        (streamed top-k yields an iterator of chunks). All parts of all
        requests are submitted before any response is awaited, so distinct
        requests can share a worker micro-batch.

        ``retries`` re-submits the whole batch (with
        :func:`backoff_delay`-jittered exponential backoff) when it fails
        with :class:`ServerOverloaded` (shed at a full queue) or
        :class:`WorkerDied` (supervisor failed an in-flight request) —
        both are transient-by-design and idempotent to repeat. Timeouts
        are **never** retried (the request may still complete server-side),
        and a :class:`WorkerDied` raised *while consuming* a streamed
        iterator is not retried either — by then chunks may already have
        been handed to the caller."""
        requests = list(requests)
        attempt = 0
        while True:
            try:
                return self._execute_once(requests, timeout)
            except (ServerOverloaded, WorkerDied):
                if attempt >= retries:
                    raise
                time.sleep(backoff_delay(attempt, retry_backoff_ms))
                attempt += 1

    def _execute_once(self, requests, timeout: float) -> list:
        plan = self._server.planner.plan(requests)
        deadline = time.time() + timeout
        entries = []  # [rid, req, parts_submitted, parts_planned]
        try:
            for req, parts in zip(plan.requests, plan.parts):
                rid = next(self._req_ids)
                self._positions[rid] = {rp.part: rp.positions for rp in parts}
                entries.append([rid, req, 0, len(parts)])
                for rp in parts:
                    self._server._submit(
                        rp.worker,
                        make_envelope(
                            self._client_id, rid, rp.part, rp.parts,
                            rp.request, t_submit=time.time(),
                            deadline=deadline,
                        ),
                    )
                    entries[-1][2] += 1
        except Exception:
            # shed (or a dead fleet) mid-submit: nothing has been consumed
            # from the box yet, so forget every part already in flight and
            # a retry starts from a clean slate
            for rid, _req, submitted, _planned in entries:
                self._positions.pop(rid, None)
                self._forget(rid, submitted)
            raise
        out = []
        for idx, (rid, req, _submitted, _planned) in enumerate(entries):
            try:
                if isinstance(req, TopKRequest) and req.chunk is not None:
                    out.append(self._stream(rid, req, timeout))
                else:
                    out.append(self._assemble(rid, req, timeout))
            except Exception:
                # the failing request forgot itself; forget the already
                # submitted later siblings too, or their responses would
                # buffer in _msgs forever
                for later_rid, _, _, _ in entries[idx + 1:]:
                    planned = max(len(self._positions.pop(later_rid, {})), 1)
                    self._forget(later_rid, planned)
                raise
        return out

    def topk(self, terms, k: int = 10, *, score: str = "count",
             timeout: float = 60.0, retries: int = 0):
        """Top-k neighbours, served through the shared worker pool. Returns
        ``(ids (B, k), scores (B, k))`` exactly like ``QueryEngine.topk``."""
        return self.execute([TopKRequest(terms, k=k, score=score)],
                            timeout=timeout, retries=retries)[0]

    def topk_stream(
        self, terms, k: int, *, score: str = "count", chunk: int = 1024,
        timeout: float = 60.0,
    ):
        """Streaming top-k: iterator of score-ordered ``(ids, scores)``
        column blocks of width ≤ ``chunk``; concatenation along axis 1
        equals the monolithic ``topk`` result exactly."""
        return self.execute(
            [TopKRequest(terms, k=k, score=score, chunk=chunk)], timeout=timeout
        )[0]

    def pair_counts(self, pairs, *, timeout: float = 60.0,
                    retries: int = 0) -> np.ndarray:
        """Exact counts for a (B, 2) pair batch, served remotely."""
        return self.execute([PairCountsRequest(pairs)], timeout=timeout,
                            retries=retries)[0]

    def neighbours(self, t: int, *, timeout: float = 60.0, retries: int = 0):
        """The full merged ``(ids, counts)`` row of term ``t``, served
        remotely (out-of-vocab ids raise the engine's ValueError)."""
        return self.execute([NeighboursRequest(t)], timeout=timeout,
                            retries=retries)[0]

    # ------------------------------------------------------------- assembly
    def _next_msg(self, rid: int, timeout: float):
        """Next buffered/arriving message for ``rid`` (others are buffered;
        messages for forgotten request ids are dropped, not buffered)."""
        deadline = time.monotonic() + timeout
        while not self._msgs.get(rid):
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(f"no response for request {rid} in {timeout}s")
            try:
                got_rid, *msg = self._box.get(timeout=remaining)
            except queue.Empty:
                raise TimeoutError(
                    f"no response for request {rid} in {timeout}s"
                ) from None
            if got_rid in self._discard:
                if msg[3]:  # last flag: one in-flight part fully drained
                    self._discard[got_rid] -= 1
                    if self._discard[got_rid] <= 0:
                        del self._discard[got_rid]
                continue
            self._msgs.setdefault(got_rid, []).append(msg)
        return self._msgs[rid].pop(0)

    def _forget(self, rid: int, in_flight: int) -> None:
        """Stop expecting ``rid`` (error, timeout, shed retry, or a dropped
        stream): free its buffers and mark however many part-final messages
        are still in flight for discard, so a dead request id can never
        grow ``_msgs`` forever."""
        for msg in self._msgs.pop(rid, []):
            if msg[3]:  # last flag
                in_flight -= 1
        if in_flight > 0:
            self._discard[rid] = in_flight

    def _raise(self, payload):
        kind, message = payload
        if kind == "value_error":
            raise ValueError(message)  # mirror QueryEngine's local errors
        if kind == "worker_died":
            raise WorkerDied(message)
        if kind == "server_overloaded":
            raise ServerOverloaded(message)
        if kind == "deadline_expired":
            # the client-side deadline had already passed when the worker
            # dequeued it; surface the same type a local wait would have
            raise TimeoutError(message)
        raise ServingError(message)

    def _assemble(self, rid: int, req, timeout: float):
        """Collect all parts of a non-streamed request and scatter routed
        top-k slices back into their original row positions."""
        positions = self._positions.pop(rid, {})
        planned = max(len(positions), 1)
        done: dict[int, tuple] = {}
        finished = 0
        try:
            while finished < planned:
                part, nparts, seq, last, ok, payload, meta = self._next_msg(
                    rid, timeout
                )
                self.last_meta = meta
                if last:
                    finished += 1
                if not ok:
                    self._raise(payload)
                done[part] = payload
        except Exception:
            self._forget(rid, planned - finished)
            raise
        self._msgs.pop(rid, None)
        if planned == 1:
            return done[0]
        # routed top-k: scatter each worker's rows back by original position
        ids_p, scores_p = done[0]
        B = req.batch
        ids = np.empty((B, ids_p.shape[1]), dtype=ids_p.dtype)
        scores = np.empty((B, scores_p.shape[1]), dtype=scores_p.dtype)
        for part, (pids, pscores) in done.items():
            pos = positions[part]
            ids[pos] = pids
            scores[pos] = pscores
        return ids, scores

    def _stream(self, rid: int, req, timeout: float) -> _StreamIterator:
        """Lazy iterator over a streamed top-k's chunks, in score order.
        Dropping/closing the iterator at any point (even before the first
        ``next()``) forgets the rid, so unconsumed in-flight chunks are
        discarded instead of buffered forever."""
        self._positions.pop(rid, None)
        return _StreamIterator(self, rid, timeout)


class CoocServer:
    """Serve one on-disk store to many clients through shared-mmap worker
    processes with cross-client micro-batching, (optionally) hot-term
    routing, and a supervised fault-tolerance layer.

    Lifecycle: ``start()`` spawns the workers, the response router, and a
    supervisor thread; ``client()`` mints per-thread client handles;
    ``stats()`` is the live (and, after stop, final) aggregated view —
    counters summed and latency histograms merged across workers, with
    server-side queue-wait / execute / request-latency percentiles under
    ``"server_timing"`` and shed/respawn/deadline counters under
    ``"resilience"``; ``stop()`` drains the workers and returns the final
    stats.

    A worker that crashes is caught by the supervisor: its claimed
    (in-flight) requests fail back to their clients as :class:`WorkerDied`
    immediately, its queue backlog survives, the slot respawns up to
    ``max_respawns`` times, and its routed slice is served by siblings
    while the replacement warms. ``max_inflight`` bounds every request
    queue and sheds the overflow as :class:`ServerOverloaded` at submit.
    Usable as a context manager.

    Example::

        with CoocServer(path, workers=4, routing=True,
                        max_inflight=256) as server:
            ids, scores = server.client().topk([3], k=10)
            server.stats()["resilience"]     # shed / respawns / ...
        # __exit__ stopped the workers; server.stats() is now final
    """

    def __init__(
        self,
        store_path: str,
        *,
        workers: int = 2,
        batch_window_ms: float = 2.0,
        max_batch: int = 64,
        kernel: str = "numpy",
        cache_rows: int = 4096,
        routing: bool = False,
        stats_interval_s: float = 0.0,
        refresh_interval_ms: float = 0.0,
        max_inflight: int = 0,
        max_respawns: int = 2,
    ):
        from repro.store.segments import Store

        if not Store.exists(store_path):
            raise FileNotFoundError(f"no store at {store_path}")
        # the client-side planner: with routing, terms are hashed to the
        # worker that owns their cache row; without, one shared queue. The
        # planner's choices are authoritative — the worker config is built
        # from them, so plan and deployment cannot disagree (routing is
        # reported as inactive when workers == 1).
        self.planner = QueryPlanner(
            workers=workers, routing=routing, kernel=kernel
        )
        self.store_path = store_path
        self.config = ServingConfig(
            workers=workers,
            batch_window_ms=batch_window_ms,
            max_batch=max_batch,
            kernel=self.planner.kernel,
            cache_rows=cache_rows,
            routing=self.planner.routing,
            stats_interval_s=stats_interval_s,
            refresh_interval_ms=refresh_interval_ms,
            max_inflight=max_inflight,
            max_respawns=max_respawns,
        )
        self._stats_final: dict = {}
        self._worker_last: dict[int, dict] = {}   # freshest payload per worker
        self._worker_final: set[int] = set()
        self._worker_archive: list[dict] = []     # dead incarnations' last payloads
        self._procs: list = []
        self._boxes: dict[int, queue.Queue] = {}
        self._client_ids = itertools.count()
        self._router = None
        self._supervisor = None
        self._started = False
        # parent-side resilience telemetry + supervision state
        self._reg = obs.Registry(enabled=True)
        self._claims: dict[tuple, int] = {}       # in-flight tag -> worker id
        self._claims_lock = threading.Lock()
        self._failed_tags: set[tuple] = set()     # supervisor-failed; drop late msgs
        self._route_lock = threading.Lock()       # degraded/dead route state
        self._stats_lock = threading.Lock()       # _worker_last/_archive/_final
        self._degraded: set[int] = set()          # dead or warming: re-route
        self._dead: set[int] = set()              # respawn budget spent
        self._incarnation: dict[int, int] = {}    # wid -> current incarnation
        self._respawn_used: dict[int, int] = {}
        self._stopping = threading.Event()

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "CoocServer":
        if self._started:
            raise RuntimeError("server already started")
        self._procs = []
        self._worker_final = set()
        self._stopping.clear()
        # spawned children re-import repro.store.serving: spawn_friendly_env
        # makes the package root importable and hides a script-style
        # __main__ for the duration of the spawns (see store/spawn.py)
        with spawn_friendly_env() as ctx:
            # routed: one request queue per worker (the planner picks the
            # queue); unrouted: one shared queue every worker drains
            # (work stealing). max_inflight bounds each queue — the shared
            # queue gets the whole fleet's budget
            n_queues = self.config.workers if self.config.routing else 1
            per_q = self.config.max_inflight
            if per_q and n_queues == 1:
                per_q *= self.config.workers
            self._request_qs = [
                ctx.Queue(maxsize=per_q) if per_q else ctx.Queue()
                for _ in range(n_queues)
            ]
            self._response_q = ctx.Queue()
            self._stats_q = ctx.Queue()
            for i in range(self.config.workers):
                self._procs.append(self._spawn_worker(ctx, i, incarnation=0))
        self._router = threading.Thread(target=self._route, daemon=True)
        self._router.start()
        self._supervisor = threading.Thread(target=self._supervise, daemon=True)
        self._supervisor.start()
        self._started = True
        return self

    def _spawn_worker(self, ctx, worker_id: int, incarnation: int):
        n_queues = len(self._request_qs)
        p = ctx.Process(
            target=_worker_main,
            args=(
                worker_id,
                self.store_path,
                self.config,
                self._request_qs[worker_id % n_queues],
                self._response_q,
                self._stats_q,
                incarnation,
            ),
            daemon=True,
        )
        p.start()
        return p

    def _route(self) -> None:
        """Fan responses out of the single mp queue into per-client boxes,
        and keep the claims ledger: a ``claim`` records which worker holds
        which in-flight tags, a final response clears its tag, and a
        supervisor ``failtag`` delivers a synthetic :class:`WorkerDied`
        only if the tag is still claimed — flushed real responses that
        raced the death win, because they travel the same ordered queue."""
        while True:
            item = self._response_q.get()
            if _is_stop(item):
                return
            if item[0] == "claim":
                _, wid, inc, tags = item
                if inc < self._incarnation.get(wid, 0):
                    # pipe-buffered claim from an incarnation the supervisor
                    # already declared dead: its batch will never be
                    # answered, fail the tags now
                    for tag in tags:
                        self._deliver_failure(
                            tag, f"worker {wid} died mid-batch", wid
                        )
                    continue
                with self._claims_lock:
                    for tag in tags:
                        self._claims[tag] = wid
                continue
            if item[0] == "failtag":
                _, tag, message, wid = item
                with self._claims_lock:
                    owned = self._claims.pop(tag, None) is not None
                if owned:
                    self._deliver_failure(tag, message, wid)
                continue
            cid, rid, part, parts, seq, last, ok, payload, meta = item
            tag = (cid, rid, part, parts)
            if tag in self._failed_tags:
                # the supervisor already failed this tag to its client:
                # drop the late real answer instead of double-delivering
                if last:
                    self._failed_tags.discard(tag)
                    with self._claims_lock:
                        self._claims.pop(tag, None)
                continue
            if last:
                with self._claims_lock:
                    self._claims.pop(tag, None)
            box = self._boxes.get(cid)
            if box is not None:
                box.put((rid, part, parts, seq, last, ok, payload, meta))

    def _deliver_failure(
        self, tag, message: str, worker_id, *,
        kind: str = "worker_died", tombstone: bool = True,
    ) -> None:
        """Synthesize a final error message for ``tag`` into its client's
        box. ``tombstone`` guards against a flushed real answer arriving
        later (only possible for claimed tags; queue-drain failures can
        never be answered, so they skip the tombstone)."""
        cid, rid, part, parts = tag
        if tombstone:
            self._failed_tags.add(tag)
        self._reg.counter("serving/worker_died_failures").inc()
        box = self._boxes.get(cid)
        if box is not None:
            box.put((rid, part, parts, 0, True, False, (kind, message),
                     {"worker": worker_id, "supervisor": True}))

    # ----------------------------------------------------------- submission
    def _submit(self, worker: int | None, envelope) -> None:
        if not self._started:
            raise RuntimeError("server not started (call start())")
        qs = self._request_qs
        with self._route_lock:
            if len(self._dead) >= self.config.workers:
                raise WorkerDied(
                    "every worker is dead and the respawn budget is spent"
                )
            if len(qs) == 1:
                target_q, target_w = qs[0], None
            else:
                w = (worker if worker is not None else 0) % len(qs)
                target = w
                if w in self._degraded:
                    # the slot is dead or warming: serve its vocabulary
                    # slice from the next live worker (routing is a cache
                    # optimization — any worker answers any slice), falling
                    # back to the home queue if the whole fleet is warming
                    for off in range(1, len(qs)):
                        cand = (w + off) % len(qs)
                        if cand not in self._degraded:
                            target = cand
                            break
                    else:
                        if w in self._dead:
                            for off in range(1, len(qs)):
                                cand = (w + off) % len(qs)
                                if cand not in self._dead:
                                    target = cand
                                    break
                target_q, target_w = qs[target], target
        try:
            if self.config.max_inflight:
                target_q.put_nowait(envelope)
            else:
                target_q.put(envelope)
        except queue.Full:
            self._reg.counter("serving/shed").inc()
            where = "" if target_w is None else f" of worker {target_w}"
            raise ServerOverloaded(
                f"request queue{where} is full "
                f"(max_inflight={self.config.max_inflight}); shed at submit"
            ) from None

    def client(self) -> CoocClient:
        """Mint a client handle (one per concurrent client thread)."""
        cid = next(self._client_ids)
        box: queue.Queue = queue.Queue()
        self._boxes[cid] = box
        return CoocClient(self, cid, box)

    # ---------------------------------------------------------- supervision
    def _supervise(self) -> None:
        """Watch worker exitcodes: a dead worker's claimed requests fail
        back typed and fast, its slot respawns (budget allowing) on its
        intact queue, and its routed slice degrades onto siblings until
        the replacement reports ready."""
        while not self._stopping.wait(_SUPERVISE_INTERVAL_S):
            self._drain_stats_q()
            for wid in range(self.config.workers):
                if wid in self._dead:
                    continue
                p = self._procs[wid]
                if p.exitcode is None:
                    continue
                self._on_worker_death(wid, p.exitcode)

    def _on_worker_death(self, wid: int, exitcode) -> None:
        with self._route_lock:
            self._degraded.add(wid)
        # archive the dead incarnation's freshest snapshot (its counters
        # keep contributing to the aggregate) and bump the incarnation so
        # pipe-buffered snapshots from the corpse are ignored
        self._drain_stats_q()
        with self._stats_lock:
            payload = self._worker_last.pop(wid, None)
            if payload is not None:
                self._worker_archive.append(payload)
            self._worker_final.discard(wid)
        inc = self._incarnation.get(wid, 0) + 1
        self._incarnation[wid] = inc
        reason = f"worker {wid} died (exitcode {exitcode})"
        # fail the claimed tags through the response queue, not straight to
        # the boxes: the dead worker's flushed answers are already ahead of
        # the failtag in the same ordered pipe, so whatever it actually
        # answered wins and only the truly stranded tags fail
        with self._claims_lock:
            tags = [t for t, w in self._claims.items() if w == wid]
        for tag in tags:
            self._response_q.put((
                "failtag", tag,
                f"{reason}; in-flight request failed by supervisor", wid,
            ))
        used = self._respawn_used.get(wid, 0)
        if used < self.config.max_respawns:
            self._respawn_used[wid] = used + 1
            self._reg.counter("serving/respawns").inc()
            with spawn_friendly_env() as ctx:
                self._procs[wid] = self._spawn_worker(ctx, wid, incarnation=inc)
        else:
            with self._route_lock:
                self._dead.add(wid)
            if len(self._request_qs) > 1:
                self._drain_dead_queue(wid, reason)

    def _drain_dead_queue(self, wid: int, reason: str) -> None:
        """A slot whose respawn budget is spent leaves envelopes stranded on
        its routed queue: re-route each to a surviving worker, or fail it
        back typed if none can take it."""
        q = self._request_qs[wid % len(self._request_qs)]
        while True:
            try:
                item = q.get_nowait()
            except queue.Empty:
                return
            if _is_stop(item) or not isinstance(item, tuple) or len(item) < 5:
                continue
            tag = (item[0], item[1], item[2], item[3])
            try:
                self._submit(wid + 1, item)
            except ServerOverloaded as e:
                self._deliver_failure(
                    tag, str(e), wid, kind="server_overloaded", tombstone=False
                )
            except Exception as e:
                self._deliver_failure(
                    tag, f"{reason}; re-route failed: {e}", wid,
                    tombstone=False,
                )

    # ------------------------------------------------------------ telemetry
    def _absorb_stats_msg(self, kind: str, wid: int, payload) -> None:
        inc = (payload or {}).get("incarnation", 0)
        cur = self._incarnation.get(wid, 0)
        if kind == "ready":
            if inc >= cur:
                with self._route_lock:
                    self._degraded.discard(wid)
            return
        if inc < cur:
            return  # stale pipe data from a dead incarnation (archived)
        with self._stats_lock:
            self._worker_last[wid] = payload
            if kind == "final":
                self._worker_final.add(wid)

    def _drain_stats_q(self) -> None:
        """Pull every pending worker message off the stats queue. Each
        worker's freshest payload wins; ``("final", ...)`` marks a clean
        exit; ``("ready", ...)`` clears a warming slot's degraded flag."""
        while True:
            try:
                kind, wid, payload = self._stats_q.get_nowait()
            except queue.Empty:
                return
            self._absorb_stats_msg(kind, wid, payload)

    def stats(self) -> dict:
        """Aggregated serving stats: counters summed and latency histograms
        merged across workers (dead incarnations' archived snapshots keep
        counting). Live (from the freshest per-worker snapshots) while the
        server runs; final after :meth:`stop`.

        Keys of note: ``server_timing`` (queue-wait / execute /
        request-latency p50/p95/p99 in ms, from the merged histograms),
        ``resilience`` (requests shed at admission, worker respawns,
        supervisor-failed in-flight requests, deadline-expired skips, and
        the currently degraded worker slots), ``freshness`` (manifest
        generation, segment count per format version, seconds since the
        newest segment was created — the most advanced worker view wins, so
        it tracks a stream daemon's commits live), ``workers_lost`` (worker
        slots that never sent a final snapshot), ``storage`` (codec traffic
        on v2 compressed stores: blocks decoded, block-cache hit rate,
        bloom negative rate — zeros on raw v1), ``metrics`` (the raw merged
        snapshot — feed it to ``repro.obs.prometheus_text``),
        ``per_worker`` (each live worker's own counters, e.g. per-worker
        ``cache_hit_rate`` under routing)."""
        if not self._started:
            return self._stats_final
        self._drain_stats_q()
        return self._aggregate(live=True)

    def _aggregate(self, *, live: bool, workers_lost: int = 0) -> dict:
        with self._stats_lock:
            current = {w: self._worker_last[w] for w in sorted(self._worker_last)}
            payloads = list(self._worker_archive) + list(current.values())
        per_worker = {w: p["stats"] for w, p in current.items()}
        stat_dicts = [p["stats"] for p in payloads]
        agg = {
            k: sum(d[k] for d in stat_dicts)
            for k in stat_dicts[0]
            if k != "cache_hit_rate"
        } if stat_dicts else {}
        if agg:
            agg["max_batch_requests"] = max(
                d["max_batch_requests"] for d in stat_dicts
            )
            agg["avg_requests_per_batch"] = round(
                agg["requests"] / max(agg["batches"], 1), 2
            )
            agg["cache_hit_rate"] = round(
                agg["cache_hits"]
                / max(agg["cache_hits"] + agg["cache_misses"], 1),
                4,
            )
        metrics = obs.merge_snapshots(
            [p["metrics"] for p in payloads] + [self._reg.snapshot()]
        )
        timing = {}
        for key, hname in (
            ("queue_wait_ms", "serving/queue_wait_s"),
            ("execute_ms", "serving/execute_s"),
            ("request_latency_ms", "serving/request_latency_s"),
        ):
            state = metrics["histograms"].get(hname)
            if state:
                h = obs.Histogram.from_state(state)
                timing[key] = {
                    "p50": round(h.percentile(50) * 1e3, 3),
                    "p95": round(h.percentile(95) * 1e3, 3),
                    "p99": round(h.percentile(99) * 1e3, 3),
                    "mean": round(h.mean * 1e3, 3),
                    "count": h.count,
                }
        # freshness: the most advanced manifest view any worker has reported
        # (highest generation wins — a sibling mid-refresh may lag by one),
        # with staleness derived from the newest segment's creation stamp
        fresh_views = [p["freshness"] for p in payloads if p.get("freshness")]
        freshness = {}
        if fresh_views:
            freshness = dict(
                max(fresh_views, key=lambda f: f.get("generation", 0))
            )
            last_append = freshness.get("last_append_unix")
            freshness["seconds_since_last_append"] = (
                round(max(time.time() - last_append, 0.0), 3)
                if last_append else None
            )
        # storage-engine counters (v2 compressed segments; zeros on raw v1
        # stores): codec traffic plus derived block-cache / bloom hit rates
        ctr = metrics.get("counters", {})
        decoded = ctr.get("storage.blocks_decoded", 0)
        c_hits = ctr.get("storage.block_cache_hits", 0)
        c_miss = ctr.get("storage.block_cache_misses", 0)
        b_checks = ctr.get("storage.bloom_checks", 0)
        b_neg = ctr.get("storage.bloom_negative", 0)
        storage = {
            "blocks_decoded": decoded,
            "block_cache_hit_rate": round(c_hits / max(c_hits + c_miss, 1), 4),
            "bloom_checks": b_checks,
            "bloom_negative": b_neg,
            "bloom_negative_rate": round(b_neg / max(b_checks, 1), 4),
        }
        with self._route_lock:
            degraded = sorted(self._degraded | self._dead)
        resilience = {
            "shed": ctr.get("serving/shed", 0),
            "respawns": ctr.get("serving/respawns", 0),
            "worker_died_failures": ctr.get("serving/worker_died_failures", 0),
            "deadline_expired": ctr.get("serving/deadline_expired", 0),
            "degraded_workers": degraded,
            "max_inflight": self.config.max_inflight,
            "max_respawns": self.config.max_respawns,
        }
        return {
            "workers": self.config.workers,
            "kernel": self.config.kernel,
            "batch_window_ms": self.config.batch_window_ms,
            "routing": self.config.routing,
            "live": live,
            **agg,
            "workers_lost": workers_lost,
            "server_timing": timing,
            "resilience": resilience,
            "freshness": freshness,
            "storage": storage,
            "metrics": metrics,
            "per_worker": [per_worker[w] for w in sorted(per_worker)],
        }

    # -------------------------------------------------------------- shutdown
    def _put_sentinel(self, q) -> None:
        """Enqueue one stop sentinel without blocking ``stop()`` behind a
        full bounded queue: a backlog at shutdown is failed back to its
        clients typed, not waited on."""
        need = 1
        while need:
            try:
                q.put_nowait(_STOP)
                need -= 1
            except queue.Full:
                try:
                    item = q.get_nowait()
                except queue.Empty:
                    continue
                if _is_stop(item):
                    need += 1  # restore the sentinel we just displaced
                elif isinstance(item, tuple) and len(item) >= 5:
                    self._deliver_failure(
                        (item[0], item[1], item[2], item[3]),
                        "server stopping with the request still queued",
                        None, tombstone=False,
                    )

    def stop(self, timeout: float = 120.0) -> dict:
        """Drain the workers and return the final aggregated serving stats.

        A worker that died without its final snapshot no longer takes the
        whole ``stop()`` down: its freshest periodic snapshot (if any)
        stands in, and the loss is surfaced as ``stats()["workers_lost"]``
        — silent stats loss was the old failure mode. The dead-with-backlog
        case (worker dead while siblings keep the stats pipe busy) is
        detected every iteration, not only when the pipe goes quiet, so
        stop returns in milliseconds instead of burning the full
        ``timeout``."""
        if not self._started:
            return self._stats_final
        # supervision off first: worker exits at the stop sentinel are
        # clean shutdowns, not deaths to respawn
        self._stopping.set()
        if self._supervisor is not None:
            self._supervisor.join(timeout=5)
        if self.config.routing:
            for q in self._request_qs:
                self._put_sentinel(q)
        else:
            for _ in self._procs:
                self._put_sentinel(self._request_qs[0])
        expected = set(range(len(self._procs)))
        deadline = time.monotonic() + timeout
        while self._worker_final < expected and time.monotonic() < deadline:
            try:
                kind, wid, payload = self._stats_q.get(timeout=0.1)
                self._absorb_stats_msg(kind, wid, payload)
            except queue.Empty:
                pass
            missing = expected - self._worker_final
            if missing and all(
                self._procs[w].exitcode is not None for w in missing
            ):
                # every missing worker is already dead: its final snapshot
                # either sits in the pipe (grace drain below) or will never
                # come — in neither case is the 120s wait loop warranted
                grace = time.monotonic() + 0.5
                while (self._worker_final < expected
                       and time.monotonic() < min(grace, deadline)):
                    time.sleep(0.02)
                    self._drain_stats_q()
                break
        workers_lost = len(expected - self._worker_final)
        for p in self._procs:
            p.join(timeout=max(deadline - time.monotonic(), 0.1))
            if p.is_alive():
                p.terminate()
        self._response_q.put(_STOP)
        self._router.join(timeout=5)
        self._started = False
        self._stats_final = self._aggregate(
            live=False, workers_lost=workers_lost
        )
        return self._stats_final

    def __enter__(self) -> "CoocServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()
