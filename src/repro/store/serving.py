"""Multi-client co-occurrence serving: shared-mmap workers, micro-batched
kernel launches.

The query engine (store/query.py) already batches *within* one call; this
layer batches *across clients*, the way a real serving deployment amortizes
kernel launches over concurrent traffic:

    clients ──▶ request queue ──▶ worker processes ──▶ response queue ─▶ router
    (threads)   (shared, mp)      (N × Store + QueryEngine)  (mp)        (thread)

* **Shared mmap** — every worker process opens the same immutable segment
  files with ``np.memmap``; the OS page cache backs all mappings with one
  physical copy, so N workers serve a 100 GB store with ~one store's worth
  of resident pages. Nothing is pickled or copied per query but the request
  and its (B, k) result.
* **Micro-batching with a latency budget** — a worker takes the first
  request off the shared queue, then keeps draining for at most
  ``batch_window_ms`` (or until ``max_batch`` requests), coalesces
  compatible requests — same ``(k, score)`` for top-k, all pair lookups
  together — and executes each group as **one** batched kernel launch
  (numpy reference or the Pallas top-k gather, per ``kernel=``).
* **Warm/cold row routing** — each worker routes rows through its
  QueryEngine's LRU cache: hot (Zipf-head) rows are served from memory,
  cold rows fall through to the shared mmap. Per-worker hit/miss counters
  are aggregated into the server's final stats.

Example (driver-side; see launch/cooc_serve.py for the full workload)::

    server = CoocServer(store_path, workers=4, batch_window_ms=2.0,
                        kernel="pallas").start()
    client = server.client()                 # one per client thread
    ids, scores = client.topk([3, 17], k=10, score="pmi")
    stats = server.stop()                    # {"requests": ..., "batches": ...}

Workers are **spawned** (never forked): JAX runtimes do not survive a fork,
and a spawned worker importing the store from disk is exactly the
multi-process serving topology this layer exists to exercise.
"""

from __future__ import annotations

import dataclasses
import itertools
import multiprocessing as mp
import os
import queue
import sys
import threading
import time

import numpy as np

_STOP = None  # queue sentinel; one per worker, re-enqueued if drained early


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Tuning knobs of one serving deployment (picklable: it crosses the
    process boundary to every worker).

    Example::

        cfg = ServingConfig(workers=4, batch_window_ms=2.0, kernel="pallas")
    """

    workers: int = 2
    batch_window_ms: float = 2.0      # micro-batch latency budget
    max_batch: int = 64               # requests coalesced per launch, at most
    kernel: str = "numpy"             # "numpy" | "pallas" (see store/query.py)
    cache_rows: int = 4096            # per-worker LRU capacity

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.batch_window_ms < 0:
            raise ValueError("batch_window_ms must be >= 0")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")


# ---------------------------------------------------------------------------
# worker side
# ---------------------------------------------------------------------------


def _serve_batch(engine, batch, response_q, worker_id: int, stats: dict) -> None:
    """Coalesce one micro-batch and answer it with as few kernel launches as
    possible: one ``topk`` per distinct (k, score), one ``pair_counts`` for
    all pair lookups. Invalid requests get error responses and do not poison
    the rest of the batch."""
    stats["batches"] += 1
    stats["requests"] += len(batch)
    stats["max_batch_requests"] = max(stats["max_batch_requests"], len(batch))
    meta = {"worker": worker_id, "batch_requests": len(batch)}

    topk_groups: dict[tuple[int, str], list] = {}
    pair_reqs: list = []
    for kind, cid, rid, *body in batch:
        try:
            if kind == "topk":
                terms, k, score = body
                terms = np.atleast_1d(np.asarray(terms, dtype=np.int64))
                engine._check_terms(terms)  # the engine's canonical errors
                topk_groups.setdefault((int(k), score), []).append(
                    (cid, rid, terms)
                )
            elif kind == "pairs":
                (pairs,) = body
                pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
                engine._check_terms(pairs.reshape(-1))
                pair_reqs.append((cid, rid, pairs))
            else:
                raise ValueError(f"unknown request kind {kind!r}")
        except (ValueError, TypeError) as e:
            response_q.put((cid, rid, False, ("value_error", str(e)), meta))

    for (k, score), reqs in topk_groups.items():
        all_terms = np.concatenate([t for _, _, t in reqs])
        try:
            ids, scores = engine.topk(all_terms, k=k, score=score)
        except ValueError as e:  # e.g. unknown score name
            for cid, rid, _ in reqs:
                response_q.put((cid, rid, False, ("value_error", str(e)), meta))
            continue
        stats["topk_queries"] += len(all_terms)
        stats["topk_launches"] += 1
        off = 0
        gmeta = {**meta, "coalesced_requests": len(reqs)}
        for cid, rid, terms in reqs:
            n = len(terms)
            response_q.put(
                (cid, rid, True, (ids[off : off + n], scores[off : off + n]), gmeta)
            )
            off += n

    if pair_reqs:
        all_pairs = np.concatenate([p for _, _, p in pair_reqs])
        counts = engine.pair_counts(all_pairs)
        stats["pair_queries"] += len(all_pairs)
        stats["pair_launches"] += 1
        off = 0
        gmeta = {**meta, "coalesced_requests": len(pair_reqs)}
        for cid, rid, pairs in pair_reqs:
            n = len(pairs)
            response_q.put((cid, rid, True, counts[off : off + n], gmeta))
            off += n


def _worker_main(
    worker_id: int,
    store_path: str,
    cfg: ServingConfig,
    request_q,
    response_q,
    stats_q,
) -> None:
    """One serving worker: open the store (mmap — pages shared with every
    sibling via the OS page cache), then loop: block for a request, drain the
    queue under the latency budget, serve the coalesced batch."""
    from repro.store.query import QueryEngine
    from repro.store.segments import Store

    engine = QueryEngine(
        Store.open(store_path), cache_rows=cfg.cache_rows, kernel=cfg.kernel
    )
    stats = {
        "requests": 0,
        "batches": 0,
        "max_batch_requests": 0,
        "topk_queries": 0,
        "topk_launches": 0,
        "pair_queries": 0,
        "pair_launches": 0,
    }
    window_s = cfg.batch_window_ms / 1e3
    stop = False
    while not stop:
        req = request_q.get()
        if req is _STOP:
            break
        batch = [req]
        deadline = time.perf_counter() + window_s
        while len(batch) < cfg.max_batch:
            timeout = deadline - time.perf_counter()
            if timeout <= 0:
                break
            try:
                nxt = request_q.get(timeout=timeout)
            except queue.Empty:
                break
            if nxt is _STOP:
                request_q.put(_STOP)  # hand the sentinel to a sibling
                stop = True
                break
            batch.append(nxt)
        _serve_batch(engine, batch, response_q, worker_id, stats)
    stats.update(engine.stats)  # cache_hits / cache_misses
    stats_q.put((worker_id, stats))


# ---------------------------------------------------------------------------
# server side
# ---------------------------------------------------------------------------


class ServingError(RuntimeError):
    """A request failed inside a worker; carries the worker's message."""


class CoocClient:
    """A client handle bound to one :class:`CoocServer`.

    Each concurrent client (thread) gets its own handle via
    ``server.client()``; a handle's methods are blocking RPCs and may be
    called from exactly one thread. ``last_meta`` exposes how the previous
    request was served (worker id, micro-batch size, coalesced requests).

    Example::

        client = server.client()
        ids, scores = client.topk([3, 17], k=10)
        client.last_meta["batch_requests"]   # how many requests shared the batch
    """

    def __init__(self, server: "CoocServer", client_id: int, box: "queue.Queue"):
        self._server = server
        self._client_id = client_id
        self._box = box
        self._req_ids = itertools.count()
        self._pending: dict[int, tuple] = {}
        self.last_meta: dict = {}

    def topk(self, terms, k: int = 10, *, score: str = "count", timeout: float = 60.0):
        """Top-k neighbours, served through the shared worker pool. Returns
        ``(ids (B, k), scores (B, k))`` exactly like ``QueryEngine.topk``."""
        rid = next(self._req_ids)
        self._server._submit(
            ("topk", self._client_id, rid,
             np.asarray(terms, dtype=np.int64), int(k), score)
        )
        return self._wait(rid, timeout)

    def pair_counts(self, pairs, *, timeout: float = 60.0) -> np.ndarray:
        """Exact counts for a (B, 2) pair batch, served remotely."""
        rid = next(self._req_ids)
        self._server._submit(
            ("pairs", self._client_id, rid, np.asarray(pairs, dtype=np.int64))
        )
        return self._wait(rid, timeout)

    def _wait(self, rid: int, timeout: float):
        deadline = time.monotonic() + timeout
        while rid not in self._pending:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(f"no response for request {rid} in {timeout}s")
            try:
                got_rid, ok, payload, meta = self._box.get(timeout=remaining)
            except queue.Empty:
                raise TimeoutError(
                    f"no response for request {rid} in {timeout}s"
                ) from None
            self._pending[got_rid] = (ok, payload, meta)
        ok, payload, meta = self._pending.pop(rid)
        self.last_meta = meta
        if not ok:
            kind, message = payload
            if kind == "value_error":
                raise ValueError(message)  # mirror QueryEngine's local errors
            raise ServingError(message)
        return payload


class CoocServer:
    """Serve one on-disk store to many clients through shared-mmap worker
    processes with cross-client micro-batching.

    Lifecycle: ``start()`` spawns the workers and the response router;
    ``client()`` mints per-thread client handles; ``stop()`` drains the
    workers and returns aggregated serving stats. Usable as a context
    manager.

    Example::

        with CoocServer(path, workers=4, batch_window_ms=2.0) as server:
            ids, scores = server.client().topk([3], k=10)
        # __exit__ stopped the workers; server.stats holds the aggregate
    """

    def __init__(
        self,
        store_path: str,
        *,
        workers: int = 2,
        batch_window_ms: float = 2.0,
        max_batch: int = 64,
        kernel: str = "numpy",
        cache_rows: int = 4096,
    ):
        from repro.store.query import KERNELS
        from repro.store.segments import Store

        if not Store.exists(store_path):
            raise FileNotFoundError(f"no store at {store_path}")
        if kernel not in KERNELS:
            raise ValueError(f"unknown kernel {kernel!r}; have {KERNELS}")
        self.store_path = store_path
        self.config = ServingConfig(
            workers=workers,
            batch_window_ms=batch_window_ms,
            max_batch=max_batch,
            kernel=kernel,
            cache_rows=cache_rows,
        )
        self.stats: dict = {}
        self._procs: list = []
        self._boxes: dict[int, queue.Queue] = {}
        self._client_ids = itertools.count()
        self._router = None
        self._started = False

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "CoocServer":
        if self._started:
            raise RuntimeError("server already started")
        ctx = mp.get_context("spawn")
        self._request_q = ctx.Queue()
        self._response_q = ctx.Queue()
        self._stats_q = ctx.Queue()
        # spawned children re-import repro.store.serving: make sure the
        # package root is importable even when the parent relied on sys.path
        # (e.g. a conftest) rather than PYTHONPATH
        import repro

        src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        old_pp = os.environ.get("PYTHONPATH")
        parts = (old_pp.split(os.pathsep) if old_pp else [])
        if src_root not in parts:
            os.environ["PYTHONPATH"] = os.pathsep.join([src_root] + parts)
        # spawn re-RUNS the parent's __main__ in every child when the parent
        # is a plain script (no module spec): an unguarded script would
        # re-execute top-level code per worker (and trip the bootstrap
        # guard), and an interactive/stdin parent has a phantom "<stdin>"
        # path the child cannot open. Workers import everything from repro
        # and need nothing from __main__, so hide the path for the duration
        # of the spawns and skip the fix-up entirely.
        main_mod = sys.modules.get("__main__")
        hide_main = (
            main_mod is not None
            and getattr(main_mod, "__spec__", None) is None
            and getattr(main_mod, "__file__", None) is not None
        )
        saved_main_file = main_mod.__file__ if hide_main else None
        if hide_main:
            del main_mod.__file__
        try:
            for i in range(self.config.workers):
                p = ctx.Process(
                    target=_worker_main,
                    args=(
                        i,
                        self.store_path,
                        self.config,
                        self._request_q,
                        self._response_q,
                        self._stats_q,
                    ),
                    daemon=True,
                )
                p.start()
                self._procs.append(p)
        finally:
            if old_pp is None:
                os.environ.pop("PYTHONPATH", None)
            else:
                os.environ["PYTHONPATH"] = old_pp
            if hide_main:
                main_mod.__file__ = saved_main_file
        self._router = threading.Thread(target=self._route, daemon=True)
        self._router.start()
        self._started = True
        return self

    def _route(self) -> None:
        """Fan responses out of the single mp queue into per-client boxes."""
        while True:
            item = self._response_q.get()
            if item is _STOP:
                return
            cid, rid, ok, payload, meta = item
            box = self._boxes.get(cid)
            if box is not None:
                box.put((rid, ok, payload, meta))

    def _submit(self, req) -> None:
        if not self._started:
            raise RuntimeError("server not started (call start())")
        self._request_q.put(req)

    def client(self) -> CoocClient:
        """Mint a client handle (one per concurrent client thread)."""
        cid = next(self._client_ids)
        box: queue.Queue = queue.Queue()
        self._boxes[cid] = box
        return CoocClient(self, cid, box)

    def stop(self, timeout: float = 120.0) -> dict:
        """Drain the workers and return aggregated serving stats."""
        if not self._started:
            return self.stats
        for _ in self._procs:
            self._request_q.put(_STOP)
        per_worker = {}
        deadline = time.monotonic() + timeout
        for _ in self._procs:
            try:
                wid, stats = self._stats_q.get(
                    timeout=max(deadline - time.monotonic(), 0.1)
                )
            except queue.Empty:
                dead = [
                    (p.pid, p.exitcode)
                    for p in self._procs
                    if p.exitcode not in (0, None)
                ]
                for p in self._procs:
                    p.terminate()
                raise RuntimeError(
                    f"serving worker(s) failed to report stats within "
                    f"{timeout}s (dead workers: {dead or 'none'})"
                ) from None
            per_worker[wid] = stats
        for p in self._procs:
            p.join(timeout=max(deadline - time.monotonic(), 0.1))
            if p.is_alive():  # pragma: no cover - workers already reported
                p.terminate()
        self._response_q.put(_STOP)
        self._router.join(timeout=5)
        self._started = False

        agg = {
            k: sum(w[k] for w in per_worker.values())
            for k in next(iter(per_worker.values()))
        } if per_worker else {}
        if agg:
            agg["max_batch_requests"] = max(
                w["max_batch_requests"] for w in per_worker.values()
            )
            agg["avg_requests_per_batch"] = round(
                agg["requests"] / max(agg["batches"], 1), 2
            )
        self.stats = {
            "workers": self.config.workers,
            "kernel": self.config.kernel,
            "batch_window_ms": self.config.batch_window_ms,
            **agg,
            "per_worker": [per_worker[w] for w in sorted(per_worker)],
        }
        return self.stats

    def __enter__(self) -> "CoocServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()
