"""Batched query engine over a co-occurrence store.

Serving-side counterpart of the counting pipeline: pair-count point lookups,
and top-k neighbour queries scored by raw count, PMI, or Dice. Neighbour
rows are gathered from the mmap'd segments through a small LRU cache, padded
into a rectangular batch, and scored/top-k'd in one batched launch — the
same batched-gather discipline as the LM serving path (launch/serve.py),
applied to retrieval statistics.

Queries are typed request objects (store/requests.py): ``execute()`` takes a
batch of ``TopKRequest | PairCountsRequest | NeighboursRequest``, coalesces
compatible requests into single launches, and answers them through the same
``execute_groups`` path the multi-process serving workers use. The classic
``topk`` / ``pair_counts`` / ``neighbours`` methods remain as thin
byte-identical shims over that path.

Two interchangeable score-and-select backends (``kernel=``):

* ``"numpy"``  — the jitted reference: score the tile with jnp ops and rank
  with ``jax.lax.top_k`` (XLA, any backend);
* ``"pallas"`` — the fused Pallas launch (kernels/topk_gather.py) that keeps
  the tile in VMEM between scoring and selection; runs under the Pallas
  interpreter off-TPU, and is asserted **bit-identical** to the reference on
  every edge case (tests/test_topk_gather.py).

Scores (df = document frequency, D = total documents):
    count  c(t, n)                        — exact integer top-k
    pmi    log(c · D / (df_t · df_n))    — pointwise mutual information
    dice   2c / (df_t + df_n)            — Dice coefficient
"""

from __future__ import annotations

import functools
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.store.requests import (
    KERNELS,
    SCORES,
    NeighboursRequest,
    PairCountsRequest,
    TopKRequest,
    check_request_types,
    coalesce,
    execute_groups,
)
from repro.store.segments import Store


@functools.partial(jax.jit, static_argnames=("score", "k"))
def _score_topk(ids, cnts, df_t, df_n, num_docs, *, score: str, k: int):
    """Reference scorer: ids, cnts: (B, L) padded with id=-1 / cnt=0;
    df_t: (B,); df_n: (B, L).

    Returns (top_ids (B, k), top_scores (B, k)); padding slots score -inf
    (count: 0) and surface id -1."""
    valid = ids >= 0
    if score == "count":
        # integer path — exact, no float rounding in the ranking. int32 is
        # the widest integer top_k gets without x64; a pair count is bounded
        # by the store's document count, so this is exact below 2³¹ docs
        s = jnp.where(valid, cnts, 0).astype(jnp.int32)
    elif score == "pmi":
        s = jnp.log(
            cnts.astype(jnp.float32)
            * jnp.float32(num_docs)
            / (df_t[:, None].astype(jnp.float32) * df_n.astype(jnp.float32))
        )
        s = jnp.where(valid, s, -jnp.inf)
    elif score == "dice":
        s = (
            2.0
            * cnts.astype(jnp.float32)
            / (df_t[:, None] + df_n).astype(jnp.float32)
        )
        s = jnp.where(valid, s, -jnp.inf)
    else:
        raise ValueError(f"unknown score {score!r}; have {SCORES}")
    top_s, top_idx = jax.lax.top_k(s, k)
    top_ids = jnp.take_along_axis(ids, top_idx, axis=1)
    return top_ids, top_s


class QueryEngine:
    """Batched queries against a :class:`~repro.store.segments.Store` with an
    LRU row cache and a pluggable score-and-select kernel.

    The cache is the warm path: hot rows (Zipf head terms under real serving
    traffic) are answered from memory; cold rows fall through to the shared
    mmap'd segment files, touching only the pages a row needs. The cache
    auto-invalidates when the store's manifest version changes (append,
    ingest, compact).

    Args:
        store: an open :class:`Store`.
        cache_rows: LRU capacity (merged neighbour rows).
        kernel: ``"numpy"`` (jitted reference) or ``"pallas"`` (fused
            gather/top-k kernel, bit-identical results).
        interpret: Pallas interpreter mode; ``None`` auto-selects it off-TPU
            so the pallas path runs (and is tested) on CPU CI.
        registry: telemetry registry for ``query/execute`` spans and
            cache/kernel-dispatch counters; ``None`` uses the process-global
            one (disabled by default — see repro/obs). Serving workers pass
            their own so metrics can cross the process boundary.

    Example::

        store, _ = count_to_store("auto", collection, "/tmp/store")
        eng = QueryEngine(store, kernel="pallas")
        ids, scores = eng.topk([3, 17], k=5, score="pmi")
        counts = eng.pair_counts(np.array([[3, 17]]))
    """

    def __init__(
        self,
        store: Store,
        *,
        cache_rows: int = 4096,
        kernel: str = "numpy",
        interpret: bool | None = None,
        registry: "obs.Registry | None" = None,
    ):
        if kernel not in KERNELS:
            raise ValueError(f"unknown kernel {kernel!r}; have {KERNELS}")
        self.store = store
        self.cache_rows = cache_rows
        self.kernel = kernel
        self._interpret = (
            jax.default_backend() != "tpu" if interpret is None else interpret
        )
        self._cache: OrderedDict[int, tuple[np.ndarray, np.ndarray]] = OrderedDict()
        self._df = store.df()
        self._num_docs = max(store.num_docs, 1)
        self._store_version = store.version
        self.stats = {"cache_hits": 0, "cache_misses": 0}
        self._registry = registry

    @property
    def registry(self) -> "obs.Registry":
        """The engine's telemetry registry (a fixed one if passed at
        construction, otherwise whatever is globally installed now)."""
        return self._registry if self._registry is not None else obs.get_registry()

    # ----------------------------------------------------------- cache
    def _maybe_invalidate(self) -> None:
        if self.store.version != self._store_version:
            self._cache.clear()
            self._df = self.store.df()
            self._num_docs = max(self.store.num_docs, 1)
            self._store_version = self.store.version

    def _row(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Cached merged row of term ``t`` (no out-of-vocab validation —
        callers go through ``_check_terms`` first)."""
        self._maybe_invalidate()
        hit = self._cache.get(t)
        if hit is not None:
            self._cache.move_to_end(t)
            self.stats["cache_hits"] += 1
            return hit
        self.stats["cache_misses"] += 1
        ids, cnts = self.store.neighbours(t)
        row = (np.asarray(ids, dtype=np.int64), np.asarray(cnts, dtype=np.int64))
        self._cache[t] = row
        if len(self._cache) > self.cache_rows:
            self._cache.popitem(last=False)
        return row

    # --------------------------------------------------------- queries
    def _check_terms(self, terms: np.ndarray) -> None:
        V = self.store.vocab_size
        bad = terms[(terms < 0) | (terms >= V)]
        if bad.size:
            raise ValueError(
                f"out-of-vocab term id(s) {sorted(set(bad.tolist()))[:5]}; "
                f"store vocab_size is {V}"
            )

    def execute(self, requests) -> list:
        """Answer a batch of typed requests (store/requests.py) with as few
        kernel launches as possible — one ``topk`` launch per distinct
        ``(k, score)``, all pair lookups together. Returns one result per
        request, in order: ``(ids, scores)`` for top-k, a count vector for
        pairs, ``(ids, counts)`` for neighbours, and an **iterator of
        score-ordered chunks** for streamed top-k (``chunk=`` set).

        An invalid request (e.g. out-of-vocab term) raises the engine's
        canonical ``ValueError`` for the first offending request.

        Example::

            reqs = [TopKRequest([3, 17], k=5, score="pmi"),
                    PairCountsRequest(np.array([[3, 17]]))]
            (ids, scores), counts = eng.execute(reqs)
        """
        reqs = list(requests)
        check_request_types(reqs)
        results: dict[int, list] = {}
        errors: dict[int, str] = {}

        def emit(tag, ok, payload, *, seq=0, last=True, extra=None):
            if ok:
                results.setdefault(tag, []).append(payload)
            else:
                errors.setdefault(tag, payload[1])

        reg = self.registry
        qstats: dict | None = {} if reg.enabled else None
        hits0, misses0 = self.stats["cache_hits"], self.stats["cache_misses"]
        with reg.span("query/execute", requests=len(reqs), kernel=self.kernel):
            execute_groups(self, coalesce(list(enumerate(reqs))), emit, qstats)
        if qstats is not None:
            reg.counter("query.requests").inc(len(reqs))
            for key, n in qstats.items():
                # topk_launches / pair_launches are the kernel-dispatch
                # counters; the rest are per-query volumes
                reg.counter(f"query.{key}").inc(n)
            reg.counter("query.cache_hits").inc(
                self.stats["cache_hits"] - hits0
            )
            reg.counter("query.cache_misses").inc(
                self.stats["cache_misses"] - misses0
            )
        if errors:
            raise ValueError(errors[min(errors)])
        out = []
        for i, req in enumerate(reqs):
            if isinstance(req, TopKRequest) and req.chunk is not None:
                out.append(iter(results[i]))
            else:
                out.append(results[i][0])
        return out

    def neighbours(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Merged ``(neighbour_ids, counts)`` of term ``t``, LRU-cached.
        Shim over :class:`NeighboursRequest` (out-of-vocab ids raise the
        same ``ValueError`` as every other query).

        Example::

            ids, cnts = eng.neighbours(3)   # every co-occurring term of 3
        """
        return self.execute([NeighboursRequest(t)])[0]

    def pair_counts(self, pairs: np.ndarray) -> np.ndarray:
        """Exact counts for a ``(B, 2)`` batch of unordered term pairs.
        Shim over :class:`PairCountsRequest`.

        Example::

            eng.pair_counts(np.array([[3, 17], [5, 5]]))  # diagonal -> 0
        """
        return self.execute([PairCountsRequest(pairs)])[0]

    def topk(
        self, terms, k: int = 10, *, score: str = "count"
    ) -> tuple[np.ndarray, np.ndarray]:
        """Top-k neighbours for a batch of terms. Shim over
        :class:`TopKRequest` — byte-identical to the request path.

        Returns ``(ids (B, k), scores (B, k))``; rows with fewer than k
        neighbours are padded with id -1 (score 0 for count, -inf else).
        Results are identical for both kernels, including tie order (ties
        rank the lower candidate-slot index first, like ``jax.lax.top_k``).

        Example::

            ids, scores = eng.topk([3, 17], k=5, score="count")
        """
        return self.execute([TopKRequest(terms, k=k, score=score)])[0]

    def topk_stream(
        self, terms, k: int, *, score: str = "count", chunk: int = 1024
    ):
        """Streaming top-k: an iterator of score-ordered ``(ids, scores)``
        column blocks of width ≤ ``chunk``. Concatenating the chunks along
        axis 1 equals ``topk(terms, k, score=score)`` exactly — chunking is
        a transport feature (serving moves large-k responses across the
        process boundary block by block), not an approximation.

        Example::

            chunks = list(eng.topk_stream([3], k=5000, chunk=512))
            ids = np.concatenate([c[0] for c in chunks], axis=1)  # (1, 5000)
        """
        return self.execute([TopKRequest(terms, k=k, score=score, chunk=chunk)])[0]

    def _topk_batch(
        self, terms: np.ndarray, k: int, score: str
    ) -> tuple[np.ndarray, np.ndarray]:
        """The batched gather + score + select launch (validated inputs)."""
        rows = [self._row(int(t)) for t in terms]
        L = max((len(r[0]) for r in rows), default=0)
        # jit cache friendliness: round the pad length up to a power of two
        L = max(8, 1 << (L - 1).bit_length()) if L else 8
        B = len(terms)
        ids = np.full((B, L), -1, dtype=np.int64)
        cnts = np.zeros((B, L), dtype=np.int64)
        for b, (rids, rcnts) in enumerate(rows):
            ids[b, : len(rids)] = rids
            cnts[b, : len(rids)] = rcnts
        # clamp BOTH df sides to >=1: stores built without df metadata
        # (write_segment df=None) would otherwise divide by zero and tie
        # every pmi candidate at +inf
        df_n = np.where(ids >= 0, np.maximum(self._df[np.maximum(ids, 0)], 1), 1)
        df_t = np.maximum(self._df[terms], 1)
        kk = min(k, L)
        if self.kernel == "pallas":
            from repro.kernels.topk_gather import topk_gather

            top_ids, top_s = topk_gather(
                ids, cnts, df_t, df_n,
                num_docs=self._num_docs, score=score, k=kk,
                interpret=self._interpret,
            )
        else:
            top_ids, top_s = _score_topk(
                jnp.asarray(ids),
                jnp.asarray(cnts),
                jnp.asarray(df_t),
                jnp.asarray(df_n),
                self._num_docs,
                score=score,
                k=kk,
            )
        top_ids = np.asarray(top_ids)
        top_s = np.asarray(top_s)
        if k > top_ids.shape[1]:  # fewer candidates than k: pad out
            pad = k - top_ids.shape[1]
            top_ids = np.pad(top_ids, ((0, 0), (0, pad)), constant_values=-1)
            fill = 0 if score == "count" else -np.inf
            top_s = np.pad(top_s, ((0, 0), (0, pad)), constant_values=fill)
        return top_ids, top_s
