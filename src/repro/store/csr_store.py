"""Memory-mapped CSR pair-count segments.

A segment is an immutable directory holding the strict-upper co-occurrence
counts of one document batch as CSR arrays, memory-mapped at open so a
serving process touches only the pages a query needs:

    meta.json         vocab_size, nnz, num_docs, total_count, source
    row_ptr.bin       int64[V+1]   CSR row pointers (dense over the vocab)
    cols.bin          int32[nnz]   secondary term IDs, ascending per row
    counts.bin        int64[nnz]   exact pair counts
    df.bin            int64[V]     per-term document frequencies (0 if unknown)
    sym_row_ptr.bin   int64[V+1]   symmetric adjacency (t -> all neighbours,
    sym_cols.bin      int32[2nnz]   both directions), what top-k queries walk
    sym_counts.bin    int64[2nnz]

Lookup costs: ``row``/``neighbours`` are O(1) pointer arithmetic on the
mmap; ``pair_count`` is a binary search within one row, O(log deg). The
strict-upper CSR is the canonical artifact and round-trips with the paper's
binary pair format (``FileSink`` / ``read_pair_file``); the symmetric
adjacency is derived from it at write time so neighbourhood queries never
scan the whole matrix.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro import obs
from repro.core.types import FileSink, PairSink, group_bounds, read_pair_file

META_NAME = "meta.json"
FORMAT_VERSION = 1

_ARRAYS = {
    "row_ptr": np.int64,
    "cols": np.int32,
    "counts": np.int64,
    "df": np.int64,
    "sym_row_ptr": np.int64,
    "sym_cols": np.int32,
    "sym_counts": np.int64,
}


def _write_array(path: str, arr: np.ndarray, dtype) -> None:
    np.ascontiguousarray(arr, dtype=dtype).tofile(path)


def write_segment(
    out_dir: str,
    rows,
    vocab_size: int,
    *,
    df: np.ndarray | None = None,
    num_docs: int = 0,
    source: str = "",
    sym_chunk_pairs: int | None = None,
) -> str:
    """Materialize a segment from ``rows`` — an iterator of
    ``(primary, secondaries, counts)`` with strictly ascending primaries and,
    within each row, strictly ascending unique secondaries (the shape
    ``builder.merge_row_streams`` produces). Returns ``out_dir``.

    ``sym_chunk_pairs`` bounds the symmetric-adjacency build's working set
    (pairs streamed per chunk; default ``SYM_CHUNK_PAIRS``) — finalization
    memory is O(V + chunk) regardless of nnz.
    """
    with obs.get_registry().span("ingest/segment_write", vocab=vocab_size) as sp:
        nnz, nrows = _write_segment_files(
            out_dir, rows, vocab_size, df=df, num_docs=num_docs,
            source=source, sym_chunk_pairs=sym_chunk_pairs,
        )
        sp.set(nnz=nnz, rows=nrows)
    reg = obs.get_registry()
    reg.counter("ingest.rows_written").inc(nrows)
    reg.counter("ingest.pairs_written").inc(nnz)
    return out_dir


def _write_segment_files(
    out_dir, rows, vocab_size, *, df, num_docs, source, sym_chunk_pairs
) -> tuple[int, int]:
    os.makedirs(out_dir, exist_ok=True)
    V = vocab_size
    row_ptr = np.zeros(V + 1, dtype=np.int64)
    nnz = 0
    nrows = 0
    total = 0
    last_primary = -1
    # batch row payloads into ~8 MB writes: thousands of small rows must not
    # mean thousands of syscalls on the ingest hot path
    pend_cols: list[np.ndarray] = []
    pend_cnts: list[np.ndarray] = []
    pending = 0
    with open(os.path.join(out_dir, "cols.bin"), "wb") as fc, open(
        os.path.join(out_dir, "counts.bin"), "wb"
    ) as fn:
        def _flush_pending():
            nonlocal pending
            if pending:
                fc.write(np.concatenate(pend_cols).tobytes())
                fn.write(np.concatenate(pend_cnts).tobytes())
                pend_cols.clear()
                pend_cnts.clear()
                pending = 0

        for primary, secs, cnts in rows:
            if primary <= last_primary:
                raise ValueError(
                    f"rows must have strictly ascending primaries; "
                    f"got {primary} after {last_primary}"
                )
            last_primary = primary
            n = len(secs)
            if n == 0:
                continue
            row_ptr[primary + 1] = n
            nnz += n
            nrows += 1
            cnts64 = np.ascontiguousarray(cnts, dtype=np.int64)
            total += int(cnts64.sum())
            pend_cols.append(np.ascontiguousarray(secs, dtype=np.int32))
            pend_cnts.append(cnts64)
            pending += n
            if pending >= (1 << 20):
                _flush_pending()
        _flush_pending()
    np.cumsum(row_ptr, out=row_ptr)
    _write_array(os.path.join(out_dir, "row_ptr.bin"), row_ptr, np.int64)

    if df is None:
        df = np.zeros(V, dtype=np.int64)
    _write_array(os.path.join(out_dir, "df.bin"), df, np.int64)

    _write_symmetric(
        out_dir, row_ptr, V, nnz,
        chunk_pairs=sym_chunk_pairs or SYM_CHUNK_PAIRS,
    )

    meta = {
        "format_version": FORMAT_VERSION,
        "vocab_size": V,
        "nnz": nnz,
        "num_docs": int(num_docs),
        "total_count": total,
        "source": source,
    }
    with open(os.path.join(out_dir, META_NAME), "w") as f:
        json.dump(meta, f, indent=2)
    return nnz, nrows


# pairs streamed per chunk by the symmetric build (~20 MB of temporaries)
SYM_CHUNK_PAIRS = 1 << 20


def _write_symmetric(
    out_dir: str,
    row_ptr: np.ndarray,
    V: int,
    nnz: int,
    *,
    chunk_pairs: int = SYM_CHUNK_PAIRS,
) -> dict:
    """Derive the symmetric adjacency from the on-disk upper CSR: every pair
    (i, j, c) contributes j to row i and i to row j.

    Two-pass external-memory build, O(V + chunk_pairs) working memory
    regardless of nnz (the doubled-COO + lexsort build it replaces peaked at
    O(nnz)):

    * **Pass 1** streams ``cols.bin`` in chunks and bincounts incoming
      degrees; symmetric degree = upper out-degree + in-degree gives
      ``sym_row_ptr`` directly.
    * **Pass 2** streams the upper CSR again and scatters each chunk into
      preallocated mmapped ``sym_cols.bin``/``sym_counts.bin`` through
      per-row write cursors. Within a chunk the reverse direction (j ← i)
      is scattered before the forward direction (i → j): for any target row
      t every reverse contribution (i, t) sits at a stream position before
      row t's own forward entries, so cursor order writes each symmetric
      row already ascending — no sort of the output ever happens.

    Returns build stats: chunks processed and the peak per-chunk temporary
    length (tests assert the bound; everything else is O(V))."""
    sym_ptr_path = os.path.join(out_dir, "sym_row_ptr.bin")
    sym_cols_path = os.path.join(out_dir, "sym_cols.bin")
    sym_counts_path = os.path.join(out_dir, "sym_counts.bin")
    stats = {"chunks": 0, "chunk_pairs": chunk_pairs, "peak_temp_elems": 0}
    if nnz == 0:
        _write_array(sym_ptr_path, np.zeros(V + 1, dtype=np.int64), np.int64)
        open(sym_cols_path, "wb").close()
        open(sym_counts_path, "wb").close()
        return stats

    cols = np.memmap(os.path.join(out_dir, "cols.bin"), dtype=np.int32, mode="r")
    counts = np.memmap(
        os.path.join(out_dir, "counts.bin"), dtype=np.int64, mode="r"
    )

    # pass 1: symmetric degrees -> sym_row_ptr
    indeg = np.zeros(V, dtype=np.int64)
    for k0 in range(0, nnz, chunk_pairs):
        indeg += np.bincount(cols[k0:min(k0 + chunk_pairs, nnz)], minlength=V)
    sym_ptr = np.zeros(V + 1, dtype=np.int64)
    np.cumsum(np.diff(row_ptr) + indeg, out=sym_ptr[1:])
    _write_array(sym_ptr_path, sym_ptr, np.int64)

    # pass 2: cursor scatter into the preallocated mmapped outputs
    sym_cols = np.memmap(sym_cols_path, dtype=np.int32, mode="w+", shape=2 * nnz)
    sym_counts = np.memmap(
        sym_counts_path, dtype=np.int64, mode="w+", shape=2 * nnz
    )
    cursor = sym_ptr[:-1].copy()
    for k0 in range(0, nnz, chunk_pairs):
        k1 = min(k0 + chunk_pairs, nnz)
        j = np.asarray(cols[k0:k1])  # int32: halves the chunk sort traffic
        cv = np.asarray(counts[k0:k1])
        # row ids of entries [k0, k1): repeat each covered row by its overlap
        # with the chunk (two scalar searchsorteds, not one per entry)
        r0 = int(np.searchsorted(row_ptr, k0, side="right")) - 1
        r1 = int(np.searchsorted(row_ptr, k1 - 1, side="right")) - 1
        seg_lens = (
            np.minimum(row_ptr[r0 + 1:r1 + 2], k1)
            - np.maximum(row_ptr[r0:r1 + 1], k0)
        )
        rows = np.repeat(np.arange(r0, r1 + 1, dtype=np.int32), seg_lens)

        # reverse direction first (see docstring): row j gets col i
        order = np.argsort(j, kind="stable")  # i stays ascending per j
        js = j[order]
        gb = group_bounds(js)
        gs, glen = gb[:-1], np.diff(gb)
        pos = cursor[js] + (np.arange(len(js)) - np.repeat(gs, glen))
        sym_cols[pos] = rows[order]
        sym_counts[pos] = cv[order]
        cursor[js[gs]] += glen

        # forward direction: row i gets col j (rows nondecreasing in-chunk)
        fb = group_bounds(rows)
        fs, flen = fb[:-1], np.diff(fb)
        pos = cursor[rows] + (np.arange(len(rows)) - np.repeat(fs, flen))
        sym_cols[pos] = j
        sym_counts[pos] = cv
        cursor[rows[fs]] += flen

        stats["chunks"] += 1
        stats["peak_temp_elems"] = max(stats["peak_temp_elems"], k1 - k0)
    # no explicit msync: readers see the pages through the unified page
    # cache immediately (exactly like the tofile() build this replaced);
    # the OS writes dirty pages back asynchronously
    del sym_cols, sym_counts
    return stats


class CSRSegment:
    """Read-only memory-mapped view of one segment directory."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, META_NAME)) as f:
            self.meta = json.load(f)
        if self.meta["format_version"] != FORMAT_VERSION:
            raise ValueError(f"unsupported segment format {self.meta}")
        self.vocab_size = self.meta["vocab_size"]
        self.nnz = self.meta["nnz"]
        self.num_docs = self.meta["num_docs"]
        self.total_count = self.meta["total_count"]
        self._arrays: dict[str, np.ndarray] = {}

    def _arr(self, name: str) -> np.ndarray:
        if name not in self._arrays:
            path = os.path.join(self.path, f"{name}.bin")
            dtype = _ARRAYS[name]
            if os.path.getsize(path) == 0:  # mmap rejects empty files
                self._arrays[name] = np.zeros(0, dtype=dtype)
            else:
                self._arrays[name] = np.memmap(path, dtype=dtype, mode="r")
        return self._arrays[name]

    @property
    def df(self) -> np.ndarray:
        return self._arr("df")

    # ---------------------------------------------------------- lookups
    def row(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Strict-upper row of ``t``: (secondaries > t, counts)."""
        ptr = self._arr("row_ptr")
        lo, hi = int(ptr[t]), int(ptr[t + 1])
        return self._arr("cols")[lo:hi], self._arr("counts")[lo:hi]

    def neighbours(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """All co-occurring terms of ``t`` (both directions), ascending IDs."""
        ptr = self._arr("sym_row_ptr")
        lo, hi = int(ptr[t]), int(ptr[t + 1])
        return self._arr("sym_cols")[lo:hi], self._arr("sym_counts")[lo:hi]

    def pair_count(self, i: int, j: int) -> int:
        """Exact count of the unordered pair {i, j}; O(log deg)."""
        if i == j:
            return 0
        lo, hi = (i, j) if i < j else (j, i)
        secs, cnts = self.row(lo)
        k = np.searchsorted(secs, hi)
        if k < len(secs) and secs[k] == hi:
            return int(cnts[k])
        return 0

    def pair_counts(self, pairs: np.ndarray) -> np.ndarray:
        """Batched pair lookup: (B, 2) int array -> int64[B]."""
        pairs = np.asarray(pairs, dtype=np.int64)
        out = np.zeros(len(pairs), dtype=np.int64)
        ptr = self._arr("row_ptr")
        cols, counts = self._arr("cols"), self._arr("counts")
        lo = np.minimum(pairs[:, 0], pairs[:, 1])
        hi = np.maximum(pairs[:, 0], pairs[:, 1])
        for b in range(len(pairs)):
            if lo[b] == hi[b]:
                continue
            s, e = int(ptr[lo[b]]), int(ptr[lo[b] + 1])
            k = s + np.searchsorted(cols[s:e], hi[b])
            if k < e and cols[k] == hi[b]:
                out[b] = counts[k]
        return out

    # -------------------------------------------------------- iteration
    def iter_rows(self):
        """Yield (primary, secondaries, counts) for every nonempty row, the
        same shape ``PairSink.emit_row`` receives (and ``write_segment``
        consumes — segments merge with each other and with spill runs)."""
        ptr = self._arr("row_ptr")
        cols, counts = self._arr("cols"), self._arr("counts")
        for t in range(self.vocab_size):
            lo, hi = int(ptr[t]), int(ptr[t + 1])
            if hi > lo:
                yield t, np.asarray(cols[lo:hi]), np.asarray(counts[lo:hi])

    def to_pair_file(self, path: str) -> None:
        """Write the paper's binary pair format (FileSink round-trip)."""
        sink = FileSink(path)
        for primary, secs, cnts in self.iter_rows():
            if int(cnts.max()) >= 1 << 32:
                # FileSink stores u32 counts; refuse to corrupt the export
                raise OverflowError(
                    f"row {primary} holds a count >= 2^32; the paper's pair "
                    "format cannot represent it"
                )
            sink.emit_row(primary, secs, cnts)
        sink.close()

    def emit_to(self, sink: PairSink) -> None:
        for primary, secs, cnts in self.iter_rows():
            sink.emit_row(primary, secs, cnts)

    def dense(self) -> np.ndarray:
        """Dense strict-upper matrix (tests / small vocab only)."""
        mat = np.zeros((self.vocab_size, self.vocab_size), dtype=np.int64)
        for primary, secs, cnts in self.iter_rows():
            mat[primary, secs.astype(np.int64)] = cnts
        return mat


def segment_from_pair_file(
    pair_path: str,
    out_dir: str,
    vocab_size: int,
    *,
    df: np.ndarray | None = None,
    num_docs: int = 0,
) -> CSRSegment:
    """Convert a paper-format pair file (any row order, repeated primaries
    allowed) into a CSR segment, by routing it through the spill builder."""
    from repro.store.builder import SpillSink

    sink = SpillSink(vocab_size)
    try:
        for primary, secs, cnts in read_pair_file(pair_path):
            sink.emit_row(primary, secs.astype(np.int64), cnts.astype(np.int64))
        write_segment(
            out_dir,
            sink.merged_rows(),
            vocab_size,
            df=df,
            num_docs=num_docs,
            source=f"pair_file:{os.path.basename(pair_path)}",
        )
    finally:
        sink.close()
    return CSRSegment(out_dir)
