"""Memory-mapped CSR pair-count segments.

A segment is an immutable directory holding the strict-upper co-occurrence
counts of one document batch as CSR arrays, memory-mapped at open so a
serving process touches only the pages a query needs:

    meta.json         vocab_size, nnz, num_docs, total_count, source
    row_ptr.bin       int64[V+1]   CSR row pointers (dense over the vocab)
    cols.bin          int32[nnz]   secondary term IDs, ascending per row
    counts.bin        int64[nnz]   exact pair counts
    df.bin            int64[V]     per-term document frequencies (0 if unknown)
    sym_row_ptr.bin   int64[V+1]   symmetric adjacency (t -> all neighbours,
    sym_cols.bin      int32[2nnz]   both directions), what top-k queries walk
    sym_counts.bin    int64[2nnz]

Lookup costs: ``row``/``neighbours`` are O(1) pointer arithmetic on the
mmap; ``pair_count`` is a binary search within one row, O(log deg). The
strict-upper CSR is the canonical artifact and round-trips with the paper's
binary pair format (``FileSink`` / ``read_pair_file``); the symmetric
adjacency is derived from it at write time so neighbourhood queries never
scan the whole matrix.

The layout above is **format v1** (raw arrays). **Format v2** stores the
same logical arrays as block-compressed columns (repro.store.codec) with
zero-count rows elided and a blocked bloom filter over the pair keys
(repro.store.bloom); see docs/formats.md for the byte-level spec. Both
versions are read through :func:`open_segment`, which dispatches on the
``magic``/``format_version`` header in ``meta.json`` — every consumer above
the segment boundary (query engine, serving, compaction) is
version-oblivious. ``write_segment(..., version=2)`` produces v2 by
building the v1 arrays first (reusing the bounded-memory symmetric build)
and transcoding them in place; decode is exact, so queries are
byte-identical across versions.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from repro import obs
from repro.core.types import FileSink, PairSink, group_bounds, read_pair_file
from repro.store import bloom as bloom_mod
from repro.store import codec as codec_mod
from repro.store.codec import write_column

META_NAME = "meta.json"
SEGMENT_MAGIC = "cooc-seg"
FORMAT_VERSION = 1
SEGMENT_VERSIONS = (1, 2)
DEFAULT_SEGMENT_VERSION = 1

_ARRAYS = {
    "row_ptr": np.int64,
    "cols": np.int32,
    "counts": np.int64,
    "df": np.int64,
    "sym_row_ptr": np.int64,
    "sym_cols": np.int32,
    "sym_counts": np.int64,
}


def _write_array(path: str, arr: np.ndarray, dtype) -> None:
    np.ascontiguousarray(arr, dtype=dtype).tofile(path)


def write_segment(
    out_dir: str,
    rows,
    vocab_size: int,
    *,
    df: np.ndarray | None = None,
    num_docs: int = 0,
    source: str = "",
    sym_chunk_pairs: int | None = None,
    version: int | None = None,
) -> str:
    """Materialize a segment from ``rows`` — an iterator of
    ``(primary, secondaries, counts)`` with strictly ascending primaries and,
    within each row, strictly ascending unique secondaries (the shape
    ``builder.merge_row_streams`` produces). Returns ``out_dir``.

    ``sym_chunk_pairs`` bounds the symmetric-adjacency build's working set
    (pairs streamed per chunk; default ``SYM_CHUNK_PAIRS``) — finalization
    memory is O(V + chunk) regardless of nnz.

    ``version`` picks the on-disk format: 1 (raw arrays, the default) or
    2 (block-compressed columns + bloom filter; the v1 arrays are built
    first, then transcoded in place by :func:`compress_segment`).
    """
    version = DEFAULT_SEGMENT_VERSION if version is None else int(version)
    if version not in SEGMENT_VERSIONS:
        raise ValueError(
            f"unknown segment version {version}; this build writes "
            f"{SEGMENT_VERSIONS}"
        )
    with obs.get_registry().span(
        "ingest/segment_write", vocab=vocab_size, version=version
    ) as sp:
        nnz, nrows = _write_segment_files(
            out_dir, rows, vocab_size, df=df, num_docs=num_docs,
            source=source, sym_chunk_pairs=sym_chunk_pairs,
        )
        sp.set(nnz=nnz, rows=nrows)
        if version == 2:
            compress_segment(out_dir)
    reg = obs.get_registry()
    reg.counter("ingest.rows_written").inc(nrows)
    reg.counter("ingest.pairs_written").inc(nnz)
    return out_dir


def _write_segment_files(
    out_dir, rows, vocab_size, *, df, num_docs, source, sym_chunk_pairs
) -> tuple[int, int]:
    os.makedirs(out_dir, exist_ok=True)
    V = vocab_size
    row_ptr = np.zeros(V + 1, dtype=np.int64)
    nnz = 0
    nrows = 0
    total = 0
    last_primary = -1
    # batch row payloads into ~8 MB writes: thousands of small rows must not
    # mean thousands of syscalls on the ingest hot path
    pend_cols: list[np.ndarray] = []
    pend_cnts: list[np.ndarray] = []
    pending = 0
    with open(os.path.join(out_dir, "cols.bin"), "wb") as fc, open(
        os.path.join(out_dir, "counts.bin"), "wb"
    ) as fn:
        def _flush_pending():
            nonlocal pending
            if pending:
                fc.write(np.concatenate(pend_cols).tobytes())
                fn.write(np.concatenate(pend_cnts).tobytes())
                pend_cols.clear()
                pend_cnts.clear()
                pending = 0

        for primary, secs, cnts in rows:
            if primary <= last_primary:
                raise ValueError(
                    f"rows must have strictly ascending primaries; "
                    f"got {primary} after {last_primary}"
                )
            last_primary = primary
            n = len(secs)
            if n == 0:
                continue
            row_ptr[primary + 1] = n
            nnz += n
            nrows += 1
            cnts64 = np.ascontiguousarray(cnts, dtype=np.int64)
            total += int(cnts64.sum())
            pend_cols.append(np.ascontiguousarray(secs, dtype=np.int32))
            pend_cnts.append(cnts64)
            pending += n
            if pending >= (1 << 20):
                _flush_pending()
        _flush_pending()
    np.cumsum(row_ptr, out=row_ptr)
    _write_array(os.path.join(out_dir, "row_ptr.bin"), row_ptr, np.int64)

    if df is None:
        df = np.zeros(V, dtype=np.int64)
    _write_array(os.path.join(out_dir, "df.bin"), df, np.int64)

    _write_symmetric(
        out_dir, row_ptr, V, nnz,
        chunk_pairs=sym_chunk_pairs or SYM_CHUNK_PAIRS,
    )

    meta = {
        "magic": SEGMENT_MAGIC,
        "format_version": FORMAT_VERSION,
        "vocab_size": V,
        "nnz": nnz,
        "num_docs": int(num_docs),
        "total_count": total,
        "source": source,
        # wall-clock append time: Store.freshness() reports the newest
        # segment's age as seconds-since-last-append (v1→v2 transcode
        # preserves it — compression is not an append)
        "created_unix": time.time(),
    }
    with open(os.path.join(out_dir, META_NAME), "w") as f:
        json.dump(meta, f, indent=2)
    return nnz, nrows


# pairs streamed per chunk by the symmetric build (~20 MB of temporaries)
SYM_CHUNK_PAIRS = 1 << 20


def _write_symmetric(
    out_dir: str,
    row_ptr: np.ndarray,
    V: int,
    nnz: int,
    *,
    chunk_pairs: int = SYM_CHUNK_PAIRS,
) -> dict:
    """Derive the symmetric adjacency from the on-disk upper CSR: every pair
    (i, j, c) contributes j to row i and i to row j.

    Two-pass external-memory build, O(V + chunk_pairs) working memory
    regardless of nnz (the doubled-COO + lexsort build it replaces peaked at
    O(nnz)):

    * **Pass 1** streams ``cols.bin`` in chunks and bincounts incoming
      degrees; symmetric degree = upper out-degree + in-degree gives
      ``sym_row_ptr`` directly.
    * **Pass 2** streams the upper CSR again and scatters each chunk into
      preallocated mmapped ``sym_cols.bin``/``sym_counts.bin`` through
      per-row write cursors. Within a chunk the reverse direction (j ← i)
      is scattered before the forward direction (i → j): for any target row
      t every reverse contribution (i, t) sits at a stream position before
      row t's own forward entries, so cursor order writes each symmetric
      row already ascending — no sort of the output ever happens.

    Returns build stats: chunks processed and the peak per-chunk temporary
    length (tests assert the bound; everything else is O(V))."""
    sym_ptr_path = os.path.join(out_dir, "sym_row_ptr.bin")
    sym_cols_path = os.path.join(out_dir, "sym_cols.bin")
    sym_counts_path = os.path.join(out_dir, "sym_counts.bin")
    stats = {"chunks": 0, "chunk_pairs": chunk_pairs, "peak_temp_elems": 0}
    if nnz == 0:
        _write_array(sym_ptr_path, np.zeros(V + 1, dtype=np.int64), np.int64)
        open(sym_cols_path, "wb").close()
        open(sym_counts_path, "wb").close()
        return stats

    cols = np.memmap(os.path.join(out_dir, "cols.bin"), dtype=np.int32, mode="r")
    counts = np.memmap(
        os.path.join(out_dir, "counts.bin"), dtype=np.int64, mode="r"
    )

    # pass 1: symmetric degrees -> sym_row_ptr
    indeg = np.zeros(V, dtype=np.int64)
    for k0 in range(0, nnz, chunk_pairs):
        indeg += np.bincount(cols[k0:min(k0 + chunk_pairs, nnz)], minlength=V)
    sym_ptr = np.zeros(V + 1, dtype=np.int64)
    np.cumsum(np.diff(row_ptr) + indeg, out=sym_ptr[1:])
    _write_array(sym_ptr_path, sym_ptr, np.int64)

    # pass 2: cursor scatter into the preallocated mmapped outputs
    sym_cols = np.memmap(sym_cols_path, dtype=np.int32, mode="w+", shape=2 * nnz)
    sym_counts = np.memmap(
        sym_counts_path, dtype=np.int64, mode="w+", shape=2 * nnz
    )
    cursor = sym_ptr[:-1].copy()
    for k0 in range(0, nnz, chunk_pairs):
        k1 = min(k0 + chunk_pairs, nnz)
        j = np.asarray(cols[k0:k1])  # int32: halves the chunk sort traffic
        cv = np.asarray(counts[k0:k1])
        # row ids of entries [k0, k1): repeat each covered row by its overlap
        # with the chunk (two scalar searchsorteds, not one per entry)
        r0 = int(np.searchsorted(row_ptr, k0, side="right")) - 1
        r1 = int(np.searchsorted(row_ptr, k1 - 1, side="right")) - 1
        seg_lens = (
            np.minimum(row_ptr[r0 + 1:r1 + 2], k1)
            - np.maximum(row_ptr[r0:r1 + 1], k0)
        )
        rows = np.repeat(np.arange(r0, r1 + 1, dtype=np.int32), seg_lens)

        # reverse direction first (see docstring): row j gets col i
        order = np.argsort(j, kind="stable")  # i stays ascending per j
        js = j[order]
        gb = group_bounds(js)
        gs, glen = gb[:-1], np.diff(gb)
        pos = cursor[js] + (np.arange(len(js)) - np.repeat(gs, glen))
        sym_cols[pos] = rows[order]
        sym_counts[pos] = cv[order]
        cursor[js[gs]] += glen

        # forward direction: row i gets col j (rows nondecreasing in-chunk)
        fb = group_bounds(rows)
        fs, flen = fb[:-1], np.diff(fb)
        pos = cursor[rows] + (np.arange(len(rows)) - np.repeat(fs, flen))
        sym_cols[pos] = j
        sym_counts[pos] = cv
        cursor[rows[fs]] += flen

        stats["chunks"] += 1
        stats["peak_temp_elems"] = max(stats["peak_temp_elems"], k1 - k0)
    # no explicit msync: readers see the pages through the unified page
    # cache immediately (exactly like the tofile() build this replaced);
    # the OS writes dirty pages back asynchronously
    del sym_cols, sym_counts
    return stats


class CSRSegment:
    """Read-only memory-mapped view of one segment directory."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, META_NAME)) as f:
            self.meta = json.load(f)
        if self.meta["format_version"] != FORMAT_VERSION:
            raise ValueError(f"unsupported segment format {self.meta}")
        self.vocab_size = self.meta["vocab_size"]
        self.nnz = self.meta["nnz"]
        self.num_docs = self.meta["num_docs"]
        self.total_count = self.meta["total_count"]
        self._arrays: dict[str, np.ndarray] = {}
        # open every mmap now: once constructed, this segment stays fully
        # readable even if a concurrent compaction unlinks the directory
        # (POSIX keeps mapped files alive until the last mapping drops)
        for name in _ARRAYS:
            self._arr(name)

    def _arr(self, name: str) -> np.ndarray:
        if name not in self._arrays:
            path = os.path.join(self.path, f"{name}.bin")
            dtype = _ARRAYS[name]
            if os.path.getsize(path) == 0:  # mmap rejects empty files
                self._arrays[name] = np.zeros(0, dtype=dtype)
            else:
                self._arrays[name] = np.memmap(path, dtype=dtype, mode="r")
        return self._arrays[name]

    @property
    def df(self) -> np.ndarray:
        return self._arr("df")

    # ---------------------------------------------------------- lookups
    def row(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Strict-upper row of ``t``: (secondaries > t, counts)."""
        ptr = self._arr("row_ptr")
        lo, hi = int(ptr[t]), int(ptr[t + 1])
        return self._arr("cols")[lo:hi], self._arr("counts")[lo:hi]

    def neighbours(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """All co-occurring terms of ``t`` (both directions), ascending IDs."""
        ptr = self._arr("sym_row_ptr")
        lo, hi = int(ptr[t]), int(ptr[t + 1])
        return self._arr("sym_cols")[lo:hi], self._arr("sym_counts")[lo:hi]

    def pair_count(self, i: int, j: int) -> int:
        """Exact count of the unordered pair {i, j}; O(log deg)."""
        if i == j:
            return 0
        lo, hi = (i, j) if i < j else (j, i)
        secs, cnts = self.row(lo)
        k = np.searchsorted(secs, hi)
        if k < len(secs) and secs[k] == hi:
            return int(cnts[k])
        return 0

    def pair_counts(self, pairs: np.ndarray) -> np.ndarray:
        """Batched pair lookup: (B, 2) int array -> int64[B]."""
        pairs = np.asarray(pairs, dtype=np.int64)
        out = np.zeros(len(pairs), dtype=np.int64)
        ptr = self._arr("row_ptr")
        cols, counts = self._arr("cols"), self._arr("counts")
        lo = np.minimum(pairs[:, 0], pairs[:, 1])
        hi = np.maximum(pairs[:, 0], pairs[:, 1])
        for b in range(len(pairs)):
            if lo[b] == hi[b]:
                continue
            s, e = int(ptr[lo[b]]), int(ptr[lo[b] + 1])
            k = s + np.searchsorted(cols[s:e], hi[b])
            if k < e and cols[k] == hi[b]:
                out[b] = counts[k]
        return out

    # -------------------------------------------------------- iteration
    def iter_rows(self):
        """Yield (primary, secondaries, counts) for every nonempty row, the
        same shape ``PairSink.emit_row`` receives (and ``write_segment``
        consumes — segments merge with each other and with spill runs)."""
        ptr = self._arr("row_ptr")
        cols, counts = self._arr("cols"), self._arr("counts")
        for t in range(self.vocab_size):
            lo, hi = int(ptr[t]), int(ptr[t + 1])
            if hi > lo:
                yield t, np.asarray(cols[lo:hi]), np.asarray(counts[lo:hi])

    def to_pair_file(self, path: str) -> None:
        """Write the paper's binary pair format (FileSink round-trip)."""
        sink = FileSink(path)
        for primary, secs, cnts in self.iter_rows():
            if int(cnts.max()) >= 1 << 32:
                # FileSink stores u32 counts; refuse to corrupt the export
                raise OverflowError(
                    f"row {primary} holds a count >= 2^32; the paper's pair "
                    "format cannot represent it"
                )
            sink.emit_row(primary, secs, cnts)
        sink.close()

    def emit_to(self, sink: PairSink) -> None:
        for primary, secs, cnts in self.iter_rows():
            sink.emit_row(primary, secs, cnts)

    def dense(self) -> np.ndarray:
        """Dense strict-upper matrix (tests / small vocab only)."""
        mat = np.zeros((self.vocab_size, self.vocab_size), dtype=np.int64)
        for primary, secs, cnts in self.iter_rows():
            mat[primary, secs.astype(np.int64)] = cnts
        return mat


# ---------------------------------------------------------------------------
# format v2: block-compressed columns + bloom filter
# ---------------------------------------------------------------------------

# v2 column files: (name, decoded dtype, mode, codec). Monotone columns
# bitpack their deltas (narrow, uniform); per-row column ids delta+varint
# (small positive deltas, negative restarts at row boundaries absorbed by
# zigzag); counts varint raw (mostly tiny).
_V2_COLUMNS = {
    "terms": (np.int32, "delta", "bitpack"),
    "row_ptr": (np.int64, "delta", "bitpack"),
    "cols": (np.int32, "delta", "varint"),
    "counts": (np.int64, "raw", "varint"),
    "sym_terms": (np.int32, "delta", "bitpack"),
    "sym_row_ptr": (np.int64, "delta", "bitpack"),
    "sym_cols": (np.int32, "delta", "varint"),
    "sym_counts": (np.int64, "raw", "varint"),
    "df": (np.int64, "raw", "varint"),
}

_V1_FILES = (
    "row_ptr.bin", "cols.bin", "counts.bin", "df.bin",
    "sym_row_ptr.bin", "sym_cols.bin", "sym_counts.bin",
)


def segment_bytes(path: str) -> int:
    """Total on-disk bytes of a segment directory (any format)."""
    return sum(
        os.path.getsize(os.path.join(path, f))
        for f in os.listdir(path)
        if os.path.isfile(os.path.join(path, f))
    )


def _elide_rows(row_ptr: np.ndarray):
    """Dense V+1 row pointers -> (nonzero term ids, row_ptr over them)."""
    lens = np.diff(row_ptr)
    terms = np.nonzero(lens)[0].astype(np.int64)
    rp = np.zeros(len(terms) + 1, dtype=np.int64)
    np.cumsum(lens[terms], out=rp[1:])
    return terms, rp


def compress_segment(
    seg_dir: str,
    *,
    block: int = codec_mod.DEFAULT_BLOCK,
    bits_per_key: int = bloom_mod.DEFAULT_BITS_PER_KEY,
    chunk_pairs: int = SYM_CHUNK_PAIRS,
) -> str:
    """Transcode a v1 segment directory to v2 **in place**: each raw array
    becomes a block-compressed column with zero-count rows elided, a bloom
    filter over the upper pair keys is added, and the raw ``.bin`` files
    are removed. Streams the nnz-sized arrays in chunks — O(V + chunk)
    memory like the v1 build itself. Exact: decoding reproduces every
    array byte for byte."""
    with open(os.path.join(seg_dir, META_NAME)) as f:
        meta = json.load(f)
    if meta["format_version"] != 1:
        raise ValueError(
            f"compress_segment needs a v1 segment, got {meta['format_version']}"
        )
    V, nnz = meta["vocab_size"], meta["nnz"]
    raw_bytes = sum(
        os.path.getsize(os.path.join(seg_dir, f)) for f in _V1_FILES
    )

    def _mm(name, dtype):
        path = os.path.join(seg_dir, name)
        if os.path.getsize(path) == 0:
            return np.zeros(0, dtype=dtype)
        return np.memmap(path, dtype=dtype, mode="r")

    def _col(name, values):
        dtype, mode, cdc = _V2_COLUMNS[name]
        write_column(
            os.path.join(seg_dir, f"{name}.z"),
            np.asarray(values, dtype=dtype) if not hasattr(values, "dtype")
            else values,
            mode=mode, codec=cdc, block=block,
        )

    with obs.get_registry().span("ingest/segment_compress", nnz=nnz):
        for prefix in ("", "sym_"):
            row_ptr = np.fromfile(
                os.path.join(seg_dir, f"{prefix}row_ptr.bin"), dtype=np.int64
            )
            terms, rp = _elide_rows(row_ptr)
            _col(f"{prefix}terms", terms.astype(np.int32))
            _col(f"{prefix}row_ptr", rp)
            _col(f"{prefix}cols", _mm(f"{prefix}cols.bin", np.int32))
            _col(f"{prefix}counts", _mm(f"{prefix}counts.bin", np.int64))
            if prefix == "":
                upper_terms, upper_rp = terms, rp
        _col("df", np.fromfile(os.path.join(seg_dir, "df.bin"), dtype=np.int64))

        # bloom over packed upper pair keys i*V + j, streamed in chunks
        filt = bloom_mod.BloomFilter.create(nnz, bits_per_key=bits_per_key)
        cols = _mm("cols.bin", np.int32)
        for k0 in range(0, nnz, chunk_pairs):
            k1 = min(k0 + chunk_pairs, nnz)
            r0 = int(np.searchsorted(upper_rp, k0, side="right")) - 1
            r1 = int(np.searchsorted(upper_rp, k1 - 1, side="right")) - 1
            seg_lens = (
                np.minimum(upper_rp[r0 + 1:r1 + 2], k1)
                - np.maximum(upper_rp[r0:r1 + 1], k0)
            )
            rows = np.repeat(upper_terms[r0:r1 + 1], seg_lens)
            keys = rows.astype(np.uint64) * np.uint64(V) + np.asarray(
                cols[k0:k1]
            ).astype(np.uint64)
            filt.add(keys)
        filt.save(os.path.join(seg_dir, "bloom.bin"))

    meta.update(
        magic=SEGMENT_MAGIC,
        format_version=2,
        block_size=block,
        bloom_bits_per_key=bits_per_key,
        raw_bytes=raw_bytes,
    )
    tmp = os.path.join(seg_dir, META_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=2)
    os.replace(tmp, os.path.join(seg_dir, META_NAME))
    for name in _V1_FILES:
        os.unlink(os.path.join(seg_dir, name))
    return seg_dir


class CompressedSegment:
    """Read-only view of a v2 (compressed) segment directory.

    Same query surface as :class:`CSRSegment` — ``row``/``neighbours``
    return the identical arrays (dtypes included), so everything above the
    segment boundary is format-oblivious. Point and range reads decode only
    the blocks they span, through one LRU :class:`~repro.store.codec.BlockCache`
    shared by all columns of the segment; ``pair_count``/``pair_counts``
    consult the bloom filter first so cold misses never decode a row."""

    def __init__(self, path: str, *, registry=None, cache_blocks: int = 256):
        self.path = path
        with open(os.path.join(path, META_NAME)) as f:
            self.meta = json.load(f)
        if self.meta["format_version"] != 2:
            raise ValueError(f"unsupported segment format {self.meta}")
        self.vocab_size = self.meta["vocab_size"]
        self.nnz = self.meta["nnz"]
        self.num_docs = self.meta["num_docs"]
        self.total_count = self.meta["total_count"]
        self._registry = registry
        self._cache = codec_mod.BlockCache(cache_blocks, registry=registry)
        self._columns: dict[str, codec_mod.CompressedColumn] = {}
        self._bloom = None
        self._df = None
        # open every column + the bloom filter now (mmaps + header parses):
        # like CSRSegment, an opened segment survives a concurrent
        # compaction unlinking its directory
        for name in _V2_COLUMNS:
            self._col(name)
        _ = self.bloom

    @property
    def registry(self):
        return self._registry if self._registry is not None else obs.get_registry()

    def _col(self, name: str) -> codec_mod.CompressedColumn:
        col = self._columns.get(name)
        if col is None:
            col = codec_mod.CompressedColumn(
                os.path.join(self.path, f"{name}.z"),
                cache=self._cache, tag=name, registry=self._registry,
            )
            self._columns[name] = col
        return col

    @property
    def bloom(self) -> bloom_mod.BloomFilter:
        if self._bloom is None:
            self._bloom = bloom_mod.BloomFilter.load(
                os.path.join(self.path, "bloom.bin")
            )
        return self._bloom

    @property
    def df(self) -> np.ndarray:
        # decoded once and memoized: df is read whole (store-level sums)
        if self._df is None:
            self._df = self._col("df").decode_all()
        return self._df

    # ---------------------------------------------------------- lookups
    def _row_from(self, prefix: str, t: int):
        i = self._col(f"{prefix}terms").find(t)
        if i < 0:
            return (
                np.zeros(0, dtype=np.int32), np.zeros(0, dtype=np.int64)
            )
        ptr = self._col(f"{prefix}row_ptr").slice(i, i + 2)
        lo, hi = int(ptr[0]), int(ptr[1])
        return (
            self._col(f"{prefix}cols").slice(lo, hi),
            self._col(f"{prefix}counts").slice(lo, hi),
        )

    def row(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Strict-upper row of ``t``: (secondaries > t, counts)."""
        return self._row_from("", t)

    def neighbours(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """All co-occurring terms of ``t`` (both directions), ascending IDs."""
        return self._row_from("sym_", t)

    def _pair_keys(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        return lo.astype(np.uint64) * np.uint64(self.vocab_size) + hi.astype(
            np.uint64
        )

    def pair_count(self, i: int, j: int) -> int:
        """Exact count of the unordered pair {i, j}; bloom-gated."""
        if i == j:
            return 0
        lo, hi = (i, j) if i < j else (j, i)
        reg = self.registry
        reg.counter("storage.bloom_checks").inc()
        if not self.bloom.contains(
            self._pair_keys(np.array([lo]), np.array([hi]))
        )[0]:
            reg.counter("storage.bloom_negative").inc()
            return 0
        secs, cnts = self.row(lo)
        k = np.searchsorted(secs, hi)
        if k < len(secs) and secs[k] == hi:
            return int(cnts[k])
        return 0

    def pair_counts(self, pairs: np.ndarray) -> np.ndarray:
        """Batched pair lookup: (B, 2) int array -> int64[B]. The bloom
        filter screens the whole batch first; only maybe-present pairs
        decode their row."""
        pairs = np.asarray(pairs, dtype=np.int64)
        out = np.zeros(len(pairs), dtype=np.int64)
        if len(pairs) == 0:
            return out
        lo = np.minimum(pairs[:, 0], pairs[:, 1])
        hi = np.maximum(pairs[:, 0], pairs[:, 1])
        valid = lo < hi
        reg = self.registry
        reg.counter("storage.bloom_checks").inc(int(valid.sum()))
        maybe = valid.copy()
        maybe[valid] = self.bloom.contains(
            self._pair_keys(lo[valid], hi[valid])
        )
        reg.counter("storage.bloom_negative").inc(
            int(valid.sum() - maybe.sum())
        )
        for b in np.nonzero(maybe)[0]:
            secs, cnts = self.row(int(lo[b]))
            k = np.searchsorted(secs, hi[b])
            if k < len(secs) and secs[k] == hi[b]:
                out[b] = cnts[k]
        return out

    # -------------------------------------------------------- iteration
    def iter_rows(self):
        """Yield (primary, secondaries, counts) for every nonempty row —
        identical shape to :meth:`CSRSegment.iter_rows`, so segments of
        either format merge with each other through the same paths."""
        terms = self._col("terms").decode_all()
        rp = self._col("row_ptr").decode_all()
        cols, counts = self._col("cols"), self._col("counts")
        for k in range(len(terms)):
            lo, hi = int(rp[k]), int(rp[k + 1])
            yield int(terms[k]), cols.slice(lo, hi), counts.slice(lo, hi)

    def to_pair_file(self, path: str) -> None:
        """Write the paper's binary pair format (FileSink round-trip)."""
        sink = FileSink(path)
        for primary, secs, cnts in self.iter_rows():
            if int(cnts.max()) >= 1 << 32:
                raise OverflowError(
                    f"row {primary} holds a count >= 2^32; the paper's pair "
                    "format cannot represent it"
                )
            sink.emit_row(primary, secs, cnts)
        sink.close()

    def emit_to(self, sink: PairSink) -> None:
        for primary, secs, cnts in self.iter_rows():
            sink.emit_row(primary, secs, cnts)

    def dense(self) -> np.ndarray:
        """Dense strict-upper matrix (tests / small vocab only)."""
        mat = np.zeros((self.vocab_size, self.vocab_size), dtype=np.int64)
        for primary, secs, cnts in self.iter_rows():
            mat[primary, secs.astype(np.int64)] = cnts
        return mat


def open_segment(path: str, *, registry=None, cache_blocks: int = 256):
    """Open a segment directory of any supported format. Dispatches on the
    ``magic``/``format_version`` header in meta.json: v1 -> raw mmapped
    :class:`CSRSegment`, v2 -> :class:`CompressedSegment`. An unknown
    version (a newer writer, or a corrupt header) raises a clear error
    instead of attempting a garbage decode."""
    with open(os.path.join(path, META_NAME)) as f:
        meta = json.load(f)
    # pre-magic v1 segments carry no magic field; anything else must match
    magic = meta.get("magic", SEGMENT_MAGIC)
    if magic != SEGMENT_MAGIC:
        raise ValueError(
            f"not a co-occurrence segment (magic {magic!r}) at {path}"
        )
    version = meta.get("format_version")
    if version == 1:
        return CSRSegment(path)
    if version == 2:
        return CompressedSegment(
            path, registry=registry, cache_blocks=cache_blocks
        )
    raise ValueError(
        f"unsupported segment format_version {version!r} at {path}; "
        f"this build reads versions {SEGMENT_VERSIONS}"
    )


def segment_from_pair_file(
    pair_path: str,
    out_dir: str,
    vocab_size: int,
    *,
    df: np.ndarray | None = None,
    num_docs: int = 0,
    version: int | None = None,
):
    """Convert a paper-format pair file (any row order, repeated primaries
    allowed) into a CSR segment, by routing it through the spill builder."""
    from repro.store.builder import SpillSink

    sink = SpillSink(vocab_size)
    try:
        for primary, secs, cnts in read_pair_file(pair_path):
            sink.emit_row(primary, secs.astype(np.int64), cnts.astype(np.int64))
        write_segment(
            out_dir,
            sink.merged_rows(),
            vocab_size,
            df=df,
            num_docs=num_docs,
            source=f"pair_file:{os.path.basename(pair_path)}",
            version=version,
        )
    finally:
        sink.close()
    return open_segment(out_dir)
