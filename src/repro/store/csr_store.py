"""Memory-mapped CSR pair-count segments.

A segment is an immutable directory holding the strict-upper co-occurrence
counts of one document batch as CSR arrays, memory-mapped at open so a
serving process touches only the pages a query needs:

    meta.json         vocab_size, nnz, num_docs, total_count, source
    row_ptr.bin       int64[V+1]   CSR row pointers (dense over the vocab)
    cols.bin          int32[nnz]   secondary term IDs, ascending per row
    counts.bin        int64[nnz]   exact pair counts
    df.bin            int64[V]     per-term document frequencies (0 if unknown)
    sym_row_ptr.bin   int64[V+1]   symmetric adjacency (t -> all neighbours,
    sym_cols.bin      int32[2nnz]   both directions), what top-k queries walk
    sym_counts.bin    int64[2nnz]

Lookup costs: ``row``/``neighbours`` are O(1) pointer arithmetic on the
mmap; ``pair_count`` is a binary search within one row, O(log deg). The
strict-upper CSR is the canonical artifact and round-trips with the paper's
binary pair format (``FileSink`` / ``read_pair_file``); the symmetric
adjacency is derived from it at write time so neighbourhood queries never
scan the whole matrix.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.types import FileSink, PairSink, read_pair_file

META_NAME = "meta.json"
FORMAT_VERSION = 1

_ARRAYS = {
    "row_ptr": np.int64,
    "cols": np.int32,
    "counts": np.int64,
    "df": np.int64,
    "sym_row_ptr": np.int64,
    "sym_cols": np.int32,
    "sym_counts": np.int64,
}


def _write_array(path: str, arr: np.ndarray, dtype) -> None:
    np.ascontiguousarray(arr, dtype=dtype).tofile(path)


def write_segment(
    out_dir: str,
    rows,
    vocab_size: int,
    *,
    df: np.ndarray | None = None,
    num_docs: int = 0,
    source: str = "",
) -> str:
    """Materialize a segment from ``rows`` — an iterator of
    ``(primary, secondaries, counts)`` with strictly ascending primaries and,
    within each row, strictly ascending unique secondaries (the shape
    ``builder.merge_row_streams`` produces). Returns ``out_dir``.
    """
    os.makedirs(out_dir, exist_ok=True)
    V = vocab_size
    row_ptr = np.zeros(V + 1, dtype=np.int64)
    nnz = 0
    total = 0
    last_primary = -1
    with open(os.path.join(out_dir, "cols.bin"), "wb") as fc, open(
        os.path.join(out_dir, "counts.bin"), "wb"
    ) as fn:
        for primary, secs, cnts in rows:
            if primary <= last_primary:
                raise ValueError(
                    f"rows must have strictly ascending primaries; "
                    f"got {primary} after {last_primary}"
                )
            last_primary = primary
            n = len(secs)
            if n == 0:
                continue
            row_ptr[primary + 1] = n
            nnz += n
            total += int(np.asarray(cnts, dtype=np.int64).sum())
            fc.write(np.ascontiguousarray(secs, dtype=np.int32).tobytes())
            fn.write(np.ascontiguousarray(cnts, dtype=np.int64).tobytes())
    np.cumsum(row_ptr, out=row_ptr)
    _write_array(os.path.join(out_dir, "row_ptr.bin"), row_ptr, np.int64)

    if df is None:
        df = np.zeros(V, dtype=np.int64)
    _write_array(os.path.join(out_dir, "df.bin"), df, np.int64)

    _write_symmetric(out_dir, row_ptr, V, nnz)

    meta = {
        "format_version": FORMAT_VERSION,
        "vocab_size": V,
        "nnz": nnz,
        "num_docs": int(num_docs),
        "total_count": total,
        "source": source,
    }
    with open(os.path.join(out_dir, META_NAME), "w") as f:
        json.dump(meta, f, indent=2)
    return out_dir


def _write_symmetric(out_dir: str, row_ptr: np.ndarray, V: int, nnz: int) -> None:
    """Derive the symmetric adjacency from the on-disk upper CSR: every pair
    (i, j, c) contributes j to row i and i to row j. One vectorized pass.

    NOTE: this materializes O(nnz) working arrays (doubled COO + lexsort),
    so segment *finalization* peaks at O(nnz) memory even though counting
    and spilling stay within the SpillSink budget. An external-memory
    adjacency build is a ROADMAP open item."""
    cols = np.fromfile(os.path.join(out_dir, "cols.bin"), dtype=np.int32)
    counts = np.fromfile(os.path.join(out_dir, "counts.bin"), dtype=np.int64)
    rows = np.repeat(
        np.arange(V, dtype=np.int32), np.diff(row_ptr).astype(np.int64)
    )
    # doubled COO (both directions), lexsorted to (row, col) order — neighbour
    # IDs come out ascending per row, ready for binary search
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    v2 = np.concatenate([counts, counts])
    order = np.lexsort((c2, r2))
    sym_cols = c2[order].astype(np.int32)
    sym_counts = v2[order]
    sym_ptr = np.zeros(V + 1, dtype=np.int64)
    np.cumsum(np.bincount(r2, minlength=V), out=sym_ptr[1:])
    _write_array(os.path.join(out_dir, "sym_row_ptr.bin"), sym_ptr, np.int64)
    _write_array(os.path.join(out_dir, "sym_cols.bin"), sym_cols, np.int32)
    _write_array(os.path.join(out_dir, "sym_counts.bin"), sym_counts, np.int64)


class CSRSegment:
    """Read-only memory-mapped view of one segment directory."""

    def __init__(self, path: str):
        self.path = path
        with open(os.path.join(path, META_NAME)) as f:
            self.meta = json.load(f)
        if self.meta["format_version"] != FORMAT_VERSION:
            raise ValueError(f"unsupported segment format {self.meta}")
        self.vocab_size = self.meta["vocab_size"]
        self.nnz = self.meta["nnz"]
        self.num_docs = self.meta["num_docs"]
        self.total_count = self.meta["total_count"]
        self._arrays: dict[str, np.ndarray] = {}

    def _arr(self, name: str) -> np.ndarray:
        if name not in self._arrays:
            path = os.path.join(self.path, f"{name}.bin")
            dtype = _ARRAYS[name]
            if os.path.getsize(path) == 0:  # mmap rejects empty files
                self._arrays[name] = np.zeros(0, dtype=dtype)
            else:
                self._arrays[name] = np.memmap(path, dtype=dtype, mode="r")
        return self._arrays[name]

    @property
    def df(self) -> np.ndarray:
        return self._arr("df")

    # ---------------------------------------------------------- lookups
    def row(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """Strict-upper row of ``t``: (secondaries > t, counts)."""
        ptr = self._arr("row_ptr")
        lo, hi = int(ptr[t]), int(ptr[t + 1])
        return self._arr("cols")[lo:hi], self._arr("counts")[lo:hi]

    def neighbours(self, t: int) -> tuple[np.ndarray, np.ndarray]:
        """All co-occurring terms of ``t`` (both directions), ascending IDs."""
        ptr = self._arr("sym_row_ptr")
        lo, hi = int(ptr[t]), int(ptr[t + 1])
        return self._arr("sym_cols")[lo:hi], self._arr("sym_counts")[lo:hi]

    def pair_count(self, i: int, j: int) -> int:
        """Exact count of the unordered pair {i, j}; O(log deg)."""
        if i == j:
            return 0
        lo, hi = (i, j) if i < j else (j, i)
        secs, cnts = self.row(lo)
        k = np.searchsorted(secs, hi)
        if k < len(secs) and secs[k] == hi:
            return int(cnts[k])
        return 0

    def pair_counts(self, pairs: np.ndarray) -> np.ndarray:
        """Batched pair lookup: (B, 2) int array -> int64[B]."""
        pairs = np.asarray(pairs, dtype=np.int64)
        out = np.zeros(len(pairs), dtype=np.int64)
        ptr = self._arr("row_ptr")
        cols, counts = self._arr("cols"), self._arr("counts")
        lo = np.minimum(pairs[:, 0], pairs[:, 1])
        hi = np.maximum(pairs[:, 0], pairs[:, 1])
        for b in range(len(pairs)):
            if lo[b] == hi[b]:
                continue
            s, e = int(ptr[lo[b]]), int(ptr[lo[b] + 1])
            k = s + np.searchsorted(cols[s:e], hi[b])
            if k < e and cols[k] == hi[b]:
                out[b] = counts[k]
        return out

    # -------------------------------------------------------- iteration
    def iter_rows(self):
        """Yield (primary, secondaries, counts) for every nonempty row, the
        same shape ``PairSink.emit_row`` receives (and ``write_segment``
        consumes — segments merge with each other and with spill runs)."""
        ptr = self._arr("row_ptr")
        cols, counts = self._arr("cols"), self._arr("counts")
        for t in range(self.vocab_size):
            lo, hi = int(ptr[t]), int(ptr[t + 1])
            if hi > lo:
                yield t, np.asarray(cols[lo:hi]), np.asarray(counts[lo:hi])

    def to_pair_file(self, path: str) -> None:
        """Write the paper's binary pair format (FileSink round-trip)."""
        sink = FileSink(path)
        for primary, secs, cnts in self.iter_rows():
            if int(cnts.max()) >= 1 << 32:
                # FileSink stores u32 counts; refuse to corrupt the export
                raise OverflowError(
                    f"row {primary} holds a count >= 2^32; the paper's pair "
                    "format cannot represent it"
                )
            sink.emit_row(primary, secs, cnts)
        sink.close()

    def emit_to(self, sink: PairSink) -> None:
        for primary, secs, cnts in self.iter_rows():
            sink.emit_row(primary, secs, cnts)

    def dense(self) -> np.ndarray:
        """Dense strict-upper matrix (tests / small vocab only)."""
        mat = np.zeros((self.vocab_size, self.vocab_size), dtype=np.int64)
        for primary, secs, cnts in self.iter_rows():
            mat[primary, secs.astype(np.int64)] = cnts
        return mat


def segment_from_pair_file(
    pair_path: str,
    out_dir: str,
    vocab_size: int,
    *,
    df: np.ndarray | None = None,
    num_docs: int = 0,
) -> CSRSegment:
    """Convert a paper-format pair file (any row order, repeated primaries
    allowed) into a CSR segment, by routing it through the spill builder."""
    from repro.store.builder import SpillSink

    sink = SpillSink(vocab_size)
    try:
        for primary, secs, cnts in read_pair_file(pair_path):
            sink.emit_row(primary, secs.astype(np.int64), cnts.astype(np.int64))
        write_segment(
            out_dir,
            sink.merged_rows(),
            vocab_size,
            df=df,
            num_docs=num_docs,
            source=f"pair_file:{os.path.basename(pair_path)}",
        )
    finally:
        sink.close()
    return CSRSegment(out_dir)
