"""Blocked bloom filter over packed pair keys for cold ``pair_counts``.

A v2 segment stores one filter over every upper-triangle pair it holds,
keyed ``i * vocab_size + j`` (``i < j``). A ``pair_counts`` batch probes
the filter first: pairs the filter rejects are *definitely* absent and are
answered 0 without touching the row columns — the common case for cold
random lookups, where the raw-segment path would fault in ``row_ptr`` and
``cols`` pages just to find nothing.

The filter is *blocked* (Putze et al.): each key hashes to one 512-bit
block (a cache line) and sets ``k`` bits **within that block**, so a probe
costs one memory access instead of ``k``. Build and probe are fully
vectorized — block ids and all ``k`` bit positions are derived from two
rounds of the splitmix64 finalizer, bits are set with
``np.bitwise_or.at`` and tested with one gather per round.

With the default 12 bits/key and k=6 the false-positive rate lands around
1% (blocked filters pay a small factor over the classic bound); false
*negatives* are impossible, which is what the byte-identity gate relies
on: a positive merely falls through to the exact row lookup.

File layout (``bloom.bin``, little-endian)::

    magic   u32   0x314D4C42 ("BLM1")
    k       u32   bits set per key
    blocks  u64   number of 512-bit blocks
    keys    u64   number of keys inserted
    words   u64[blocks * 8]

Example::

    >>> import numpy as np
    >>> f = BloomFilter.build(np.array([7, 99], dtype=np.uint64))
    >>> f.contains(np.array([7, 8], dtype=np.uint64)).tolist()
    [True, False]
"""

from __future__ import annotations

import numpy as np

BLOOM_MAGIC = 0x314D4C42  # "BLM1"
WORDS_PER_BLOCK = 8  # 8 x u64 = 512 bits = one cache line
DEFAULT_BITS_PER_KEY = 12
DEFAULT_K = 6

_U = np.uint64
_HEADER_BYTES = 24


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64 (wrapping arithmetic)."""
    z = x + _U(0x9E3779B97F4A7C15)
    z = (z ^ (z >> _U(30))) * _U(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> _U(27))) * _U(0x94D049BB133111EB)
    return z ^ (z >> _U(31))


class BloomFilter:
    """In-memory or mmapped blocked bloom filter (see module docstring)."""

    def __init__(self, words: np.ndarray, *, k: int = DEFAULT_K, n_keys: int = 0):
        if len(words) % WORDS_PER_BLOCK:
            raise ValueError("word count must be a multiple of 8")
        self.words = words
        self.n_blocks = len(words) // WORDS_PER_BLOCK
        self.k = k
        self.n_keys = n_keys

    # ------------------------------------------------------------ hashing
    def _positions(self, keys: np.ndarray):
        """(word indices, bit masks) of the k probe bits of each key:
        shapes (n, k). Block from one mix round, the k 9-bit in-block
        positions from a second."""
        keys = np.asarray(keys, dtype=np.uint64)
        h1 = _mix64(keys)
        block = (h1 % _U(self.n_blocks)).astype(np.int64)
        h2 = _mix64(h1 ^ _U(0xD6E8FEB86659FD93))
        shifts = (_U(9) * np.arange(self.k, dtype=np.uint64))[None, :]
        pos = ((h2[:, None] >> shifts) & _U(511)).astype(np.int64)
        word = block[:, None] * WORDS_PER_BLOCK + (pos >> 6)
        mask = _U(1) << (pos & 63).astype(np.uint64)
        return word, mask

    # ------------------------------------------------------------ build
    @classmethod
    def create(cls, n_keys: int, *, bits_per_key: int = DEFAULT_BITS_PER_KEY,
               k: int = DEFAULT_K) -> "BloomFilter":
        """An empty filter sized for ``n_keys`` (add with :meth:`add`)."""
        bits = max(int(n_keys) * bits_per_key, 512)
        n_blocks = (bits + 511) // 512
        words = np.zeros(n_blocks * WORDS_PER_BLOCK, dtype=np.uint64)
        return cls(words, k=k, n_keys=0)

    def add(self, keys: np.ndarray) -> None:
        """Insert a batch of keys (chunk-friendly: call repeatedly while
        streaming an nnz-sized key space)."""
        keys = np.asarray(keys, dtype=np.uint64)
        if len(keys) == 0:
            return
        word, mask = self._positions(keys)
        np.bitwise_or.at(self.words, word.ravel(), mask.ravel())
        self.n_keys += len(keys)

    @classmethod
    def build(cls, keys: np.ndarray, *, bits_per_key: int = DEFAULT_BITS_PER_KEY,
              k: int = DEFAULT_K) -> "BloomFilter":
        f = cls.create(len(keys), bits_per_key=bits_per_key, k=k)
        f.add(keys)
        return f

    # ------------------------------------------------------------ query
    def contains(self, keys: np.ndarray) -> np.ndarray:
        """Boolean mask: False = definitely absent, True = maybe present."""
        keys = np.asarray(keys, dtype=np.uint64)
        if len(keys) == 0:
            return np.zeros(0, dtype=bool)
        word, mask = self._positions(keys)
        hit = (np.asarray(self.words)[word] & mask) == mask
        return hit.all(axis=1)

    # ------------------------------------------------------------ disk
    def save(self, path: str) -> None:
        header = np.zeros(_HEADER_BYTES, dtype=np.uint8)
        header[0:4] = np.array([BLOOM_MAGIC], dtype="<u4").view(np.uint8)
        header[4:8] = np.array([self.k], dtype="<u4").view(np.uint8)
        header[8:16] = np.array([self.n_blocks], dtype="<u8").view(np.uint8)
        header[16:24] = np.array([self.n_keys], dtype="<u8").view(np.uint8)
        with open(path, "wb") as f:
            f.write(header.tobytes())
            f.write(np.ascontiguousarray(self.words).tobytes())

    @classmethod
    def load(cls, path: str) -> "BloomFilter":
        """mmap-backed load: probes touch only the blocks they hash to."""
        raw = np.memmap(path, dtype=np.uint8, mode="r")
        if len(raw) < _HEADER_BYTES:
            raise ValueError(f"not a bloom filter (truncated): {path}")
        header = np.asarray(raw[:_HEADER_BYTES])
        if int(header[0:4].view("<u4")[0]) != BLOOM_MAGIC:
            raise ValueError(f"bad bloom magic in {path}")
        k = int(header[4:8].view("<u4")[0])
        n_blocks = int(header[8:16].view("<u8")[0])
        n_keys = int(header[16:24].view("<u8")[0])
        words = raw[_HEADER_BYTES:_HEADER_BYTES + 8 * n_blocks * WORDS_PER_BLOCK]
        return cls(words.view(np.uint64), k=k, n_keys=n_keys)
