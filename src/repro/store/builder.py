"""Spill-and-merge store builder.

``SpillSink`` implements the ``PairSink`` protocol, so **any** counting
method in ``core/cooc.py`` (and any per-shard worker of
``core/distributed.py``) can stream its output here instead of into a dense
V×V matrix. Rows are buffered as packed int64 pair keys under a configurable
memory budget; when the budget is hit, the buffer is sorted, duplicate pairs
are aggregated, and the result is spilled to disk as a sorted run in the
paper's binary pair format (§2 NAÏVE's "sorted runs + merge" discipline,
generalized to every method). Finalization k-way-merges all runs plus the
live buffer into an immutable CSR segment. Counting and merging stay within
O(budget) memory regardless of the distinct-pair count; the one O(nnz)
step left is the segment's symmetric-adjacency derivation (see
csr_store._write_symmetric).
"""

from __future__ import annotations

import heapq
import os
import shutil
import tempfile

import numpy as np

from repro.core.types import FileSink, iter_pair_file


def sum_by_key(keys: np.ndarray, cnts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Aggregate duplicate keys: returns (sorted unique keys, summed int64
    counts). The one aggregation primitive behind spilling, run merging, and
    multi-segment neighbourhood merging."""
    order = np.argsort(keys, kind="stable")
    keys, cnts = keys[order], np.asarray(cnts, dtype=np.int64)[order]
    uniq, start = np.unique(keys, return_index=True)
    return uniq, np.add.reduceat(cnts, start)


def _iter_run(path: str):
    """Stream int64 rows from a run file (paper binary format, primaries
    strictly ascending within a run)."""
    for primary, secs, cnts in iter_pair_file(path):
        yield int(primary), secs.astype(np.int64), cnts.astype(np.int64)


def merge_row_streams(streams):
    """K-way merge of row streams (each with strictly ascending primaries and
    sorted unique secondaries). Yields (primary, secondaries, counts) with
    strictly ascending primaries, duplicate pairs summed — the exact input
    shape ``csr_store.write_segment`` expects. Streams are consumed lazily,
    so memory is O(k · max row), not O(total pairs)."""
    streams = [iter(s) for s in streams]
    heap = []
    for idx, it in enumerate(streams):
        first = next(it, None)
        if first is not None:
            heap.append((first[0], idx, first))
    heapq.heapify(heap)
    while heap:
        primary = heap[0][0]
        secs_parts, cnts_parts = [], []
        while heap and heap[0][0] == primary:
            _, idx, (_, secs, cnts) = heapq.heappop(heap)
            secs_parts.append(secs)
            cnts_parts.append(cnts)
            nxt = next(streams[idx], None)
            if nxt is not None:
                heapq.heappush(heap, (nxt[0], idx, nxt))
        if len(secs_parts) == 1:
            secs = np.asarray(secs_parts[0], dtype=np.int64)
            cnts = np.asarray(cnts_parts[0], dtype=np.int64)
        else:
            secs, cnts = sum_by_key(
                np.concatenate(secs_parts).astype(np.int64),
                np.concatenate(cnts_parts),
            )
        yield primary, secs, cnts


def _rows_from_sorted_keys(keys: np.ndarray, cnts: np.ndarray, V: int):
    """Split sorted unique packed keys into per-primary rows."""
    if len(keys) == 0:
        return
    primaries = keys // V
    secondaries = keys % V
    starts = np.concatenate(
        [[0], np.nonzero(np.diff(primaries))[0] + 1, [len(keys)]]
    )
    for s, e in zip(starts[:-1], starts[1:]):
        if e > s:
            yield int(primaries[s]), secondaries[s:e], cnts[s:e]


class SpillSink:
    """PairSink that spills sorted aggregated runs to disk under a memory
    budget (measured in buffered pair entries, ~16 bytes each)."""

    def __init__(
        self,
        vocab_size: int,
        *,
        memory_budget_pairs: int = 4 << 20,
        spill_dir: str | None = None,
    ):
        if memory_budget_pairs < 1:
            raise ValueError("memory_budget_pairs must be >= 1")
        self.vocab_size = vocab_size
        self.memory_budget_pairs = memory_budget_pairs
        self._own_dir = spill_dir is None
        self.spill_dir = spill_dir or tempfile.mkdtemp(prefix="cooc_spill_")
        os.makedirs(self.spill_dir, exist_ok=True)
        self.runs: list[str] = []
        self._keys: list[np.ndarray] = []
        self._cnts: list[np.ndarray] = []
        self._buffered = 0
        self.stats = {"spills": 0, "pairs_in": 0, "spilled_bytes": 0}

    # ------------------------------------------------------ PairSink API
    def emit_row(self, primary, secondaries, counts):
        if len(secondaries) == 0:
            return
        keys = np.int64(primary) * self.vocab_size + np.asarray(
            secondaries, dtype=np.int64
        )
        self._push(keys, counts)

    def emit_col(self, secondary, primaries, counts):
        """Column-order emission (FREQ-SPLIT tail path)."""
        if len(primaries) == 0:
            return
        keys = np.asarray(primaries, dtype=np.int64) * self.vocab_size + np.int64(
            secondary
        )
        self._push(keys, counts)

    def _push(self, keys: np.ndarray, counts) -> None:
        self._keys.append(keys)
        self._cnts.append(np.asarray(counts, dtype=np.int64))
        self._buffered += len(keys)
        self.stats["pairs_in"] += len(keys)
        if self._buffered >= self.memory_budget_pairs:
            self._spill()

    # ------------------------------------------------------ context manager
    def __enter__(self) -> "SpillSink":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ---------------------------------------------------------- spilling
    def _drain_buffer(self) -> tuple[np.ndarray, np.ndarray]:
        """Sort + aggregate the live buffer into unique (key, count) arrays."""
        keys = np.concatenate(self._keys)
        cnts = np.concatenate(self._cnts)
        self._keys, self._cnts, self._buffered = [], [], 0
        return sum_by_key(keys, cnts)

    def _spill(self) -> None:
        if self._buffered == 0:
            return
        keys, cnts = self._drain_buffer()
        if len(cnts) and int(cnts.max()) >= 1 << 32:
            # the run format stores counts as u32 (paper format); a single
            # buffer can only exceed that when fed pre-aggregated counts
            raise OverflowError(
                f"aggregated count {int(cnts.max())} exceeds the u32 run "
                "format; lower memory_budget_pairs or pre-split the input"
            )
        path = os.path.join(self.spill_dir, f"run_{len(self.runs):05d}.bin")
        with FileSink(path) as run_sink:
            for primary, secs, row_cnts in _rows_from_sorted_keys(
                keys, cnts, self.vocab_size
            ):
                run_sink.emit_row(primary, secs, row_cnts)
        self.runs.append(path)
        self.stats["spills"] += 1
        self.stats["spilled_bytes"] += os.path.getsize(path)

    def flush(self) -> None:
        """Force the live buffer to disk as a sorted run. After a flush the
        run files alone carry the sink's full state — the PlanExecutor uses
        this to make completed shards' spill directories restart-safe."""
        self._spill()

    # --------------------------------------------------------- finalize
    def merged_rows(self):
        """Iterator of fully merged (primary, secondaries, counts) rows
        across all spilled runs and the live buffer. May be consumed once."""
        streams = [_iter_run(p) for p in self.runs]
        if self._buffered:
            keys, cnts = self._drain_buffer()
            streams.append(_rows_from_sorted_keys(keys, cnts, self.vocab_size))
        return merge_row_streams(streams)

    def finalize_segment(
        self,
        out_dir: str,
        *,
        df: np.ndarray | None = None,
        num_docs: int = 0,
        source: str = "spill",
    ):
        """Merge everything into a CSR segment at ``out_dir`` and clean up
        the spill files. Returns the opened ``CSRSegment``."""
        from repro.store.csr_store import CSRSegment, write_segment

        write_segment(
            out_dir,
            self.merged_rows(),
            self.vocab_size,
            df=df,
            num_docs=num_docs,
            source=source,
        )
        self.close()
        return CSRSegment(out_dir)

    def close(self) -> None:
        """Delete spill files (and the spill dir if we created it)."""
        for p in self.runs:
            if os.path.exists(p):
                os.remove(p)
        self.runs = []
        self._keys, self._cnts, self._buffered = [], [], 0
        if self._own_dir and os.path.isdir(self.spill_dir):
            shutil.rmtree(self.spill_dir, ignore_errors=True)
