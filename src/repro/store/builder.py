"""Spill-and-merge store builder.

``SpillSink`` implements the ``PairSink`` protocol, so **any** counting
method in ``core/cooc.py`` (and any per-shard worker of
``core/distributed.py``) can stream its output here instead of into a dense
V×V matrix. Rows are buffered as packed int64 pair keys under a configurable
memory budget; when the budget is hit, the buffer is **radix-partitioned**
by primary range (``primary >> pshift`` — at most 256 buckets spanning the
vocabulary), each small bucket is sorted and aggregated independently, and
every nonempty bucket is spilled as its own sorted run in the paper's binary
pair format (§2 NAÏVE's "sorted runs + merge" discipline, generalized to
every method). Because bucket boundaries align with primary ranges,
finalization merges run files *per bucket* — the k-way heap only ever spans
one bucket's runs — instead of one global merge over every run, and the
buffer, its bucket tags, and the partition scratch are all preallocated once
and reused across spills. Counting, spilling, and merging stay within
O(budget) memory regardless of the distinct-pair count; the segment's
symmetric-adjacency derivation is likewise external-memory (see
csr_store._write_symmetric).
"""

from __future__ import annotations

import glob as _glob
import heapq
import os
import re
import shutil
import tempfile

import numpy as np

from repro import obs
from repro.core.types import group_bounds, iter_pair_file

# radix partition width: at most 2^BUCKET_BITS primary-range buckets
BUCKET_BITS = 8

# a completed spill shard's directory (promoted atomically by the executor
# that owns it); in-flight attempts live in wip_* directories that this
# pattern deliberately does not match, so run discovery never sees partials
SHARD_DIR_RE = re.compile(r"^shard_(\d+)$")
_RUN_NAME_RE = re.compile(r"^run_\d+_b(\d+)\.bin$")


def shard_dir_name(shard: int) -> str:
    """Canonical name of a completed spill shard's run directory."""
    return f"shard_{shard:05d}"


def wip_dir_name(shard: int, worker: str) -> str:
    """Name of one worker's in-flight attempt at a shard — distinct per
    (shard, worker) so concurrent attempts (a straggler plus its backup
    task) never collide, and never matched by :data:`SHARD_DIR_RE` so a
    crashed attempt's partial runs are invisible to run discovery."""
    return f"wip_{worker}_{shard:05d}"


def discover_bucket_runs(spill_root: str) -> tuple[dict[int, list[str]], bool]:
    """Group every completed shard's run files by radix bucket.

    Walks ``spill_root/shard_*/run_*_b*.bin`` — the naming every SpillSink
    uses, whichever process wrote it — and returns ``(by_bucket, legacy)``.
    ``legacy`` is True when a pre-bucketing run file (no ``_b`` suffix, from
    a resumed old spill directory) is present, in which case the caller must
    fall back to one global k-way merge; ``by_bucket`` then maps bucket -1
    to every run path. Paths are sorted, so the grouping is deterministic
    across processes."""
    runs = sorted(
        p
        for d in _glob.glob(os.path.join(spill_root, "shard_*"))
        if SHARD_DIR_RE.match(os.path.basename(d))
        for p in _glob.glob(os.path.join(d, "run_*.bin"))
    )
    by_bucket: dict[int, list[str]] = {}
    for p in runs:
        m = _RUN_NAME_RE.match(os.path.basename(p))
        if m is None:
            return {-1: runs}, True
        by_bucket.setdefault(int(m.group(1)), []).append(p)
    return by_bucket, False


def write_rows_run(path: str, rows, V: int, *,
                   buffer_pairs: int = 1 << 20) -> int:
    """Stream merged (primary, secondaries, counts) rows into one run-format
    file (the exact bytes ``_write_run`` would produce for the same rows),
    buffering ~``buffer_pairs`` pairs between writes so a huge bucket never
    materializes in memory. Counts must fit the run format's u32 — final
    merged counts, like spilled ones, are checked. Returns the pair count.

    The parallel finalizer uses this to persist one bucket's fully merged
    rows as a resumable intermediate: re-reading it with ``_iter_run``
    yields back exactly the rows that went in."""
    total = 0
    pend_keys: list[np.ndarray] = []
    pend_cnts: list[np.ndarray] = []
    pending = 0
    with open(path, "wb") as f:

        def _flush():
            nonlocal pending
            if not pending:
                return
            keys = np.concatenate(pend_keys)
            cnts = np.concatenate(pend_cnts)
            pend_keys.clear()
            pend_cnts.clear()
            pending = 0
            _write_run_into(f, keys, cnts, V)

        for primary, secs, cnts in rows:
            cnts = np.asarray(cnts, dtype=np.int64)
            if len(cnts) and int(cnts.max()) >= 1 << 32:
                raise OverflowError(
                    f"merged count {int(cnts.max())} exceeds the u32 run "
                    "format"
                )
            pend_keys.append(
                np.int64(primary) * V + np.asarray(secs, dtype=np.int64)
            )
            pend_cnts.append(cnts)
            pending += len(cnts)
            total += len(cnts)
            if pending >= buffer_pairs:
                _flush()
        _flush()
    return total


def sum_by_key(keys: np.ndarray, cnts: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Aggregate duplicate keys: returns (sorted unique keys, summed int64
    counts). The one aggregation primitive behind spilling, run merging, and
    multi-segment neighbourhood merging. One stable sort; duplicate-group
    boundaries come from a ``diff`` over the sorted keys (``np.unique`` would
    sort a second time)."""
    cnts = np.asarray(cnts, dtype=np.int64)
    if len(keys) == 0:
        return np.asarray(keys, dtype=np.int64).copy(), cnts.copy()
    order = np.argsort(keys, kind="stable")
    keys, cnts = keys[order], cnts[order]
    starts = group_bounds(keys)[:-1]
    return keys[starts], np.add.reduceat(cnts, starts)


def _iter_run(path: str):
    """Stream int64 rows from a run file (paper binary format, primaries
    strictly ascending within a run)."""
    for primary, secs, cnts in iter_pair_file(path):
        yield int(primary), secs.astype(np.int64), cnts.astype(np.int64)


def _write_run(path: str, keys: np.ndarray, cnts: np.ndarray, V: int) -> None:
    """Write sorted unique packed keys as one run file (paper binary format)
    in a single ``tofile`` — the whole file image is assembled with two
    scatter assignments instead of per-row struct packing + writes."""
    with open(path, "wb") as f:
        _write_run_into(f, keys, cnts, V)


def _write_run_into(f, keys: np.ndarray, cnts: np.ndarray, V: int) -> None:
    """One run-format image of whole rows appended to an open file. Chunks
    written back to back stay a valid run as long as every chunk holds whole
    rows and primaries ascend across chunks (``write_rows_run`` guarantees
    both)."""
    prims = keys // V
    bounds = group_bounds(prims)
    starts = bounds[:-1]
    ns = np.diff(bounds)
    npairs = len(keys)
    nrows = len(starts)
    out = np.empty(2 * nrows + 2 * npairs, dtype=np.uint32)
    # record r sits after r headers and pairs_before(r) tuples (2 words each)
    hdr = 2 * np.arange(nrows, dtype=np.int64) + 2 * starts
    out[hdr] = prims[starts]
    out[hdr + 1] = ns
    # pair p of row r(p) lands at 2·r(p) + 2 + 2·p
    rpp = np.repeat(np.arange(nrows, dtype=np.int64), ns)
    sec_pos = 2 * rpp + 2 + 2 * np.arange(npairs, dtype=np.int64)
    out[sec_pos] = keys % V
    out[sec_pos + 1] = cnts
    out.tofile(f)


def _load_run(path: str, V: int) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized whole-run parse: one ``np.fromfile`` plus an O(rows)
    header walk, returning the run's (packed int64 keys, int64 counts) —
    sorted unique, exactly as spilled. The per-pair struct unpacking of
    ``iter_pair_file`` is the merge phase's Python hot spot; this replaces
    it with three fancy-index gathers."""
    words = np.fromfile(path, dtype=np.uint32)
    if len(words) == 0:
        return np.zeros(0, dtype=np.int64), np.zeros(0, dtype=np.int64)
    offs = []
    k = 0
    while k < len(words):  # one step per row, not per pair
        offs.append(k)
        k += 2 + 2 * int(words[k + 1])
    offs = np.asarray(offs, dtype=np.int64)
    prim = words[offs].astype(np.int64)
    ns = words[offs + 1].astype(np.int64)
    lens = 2 * ns
    pos = np.zeros(len(offs) + 1, dtype=np.int64)
    np.cumsum(lens, out=pos[1:])
    idx = np.arange(pos[-1], dtype=np.int64) + np.repeat(offs + 2 - pos[:-1], lens)
    tup = words[idx]
    keys = np.repeat(prim, ns) * V + tup[0::2]
    return keys, tup[1::2].astype(np.int64)


def merge_bucket_runs(by_bucket, V: int, *, cap_pairs: int, live=None):
    """Merged (primary, secondaries, counts) rows across bucket-partitioned
    runs, walking buckets in ascending order (buckets cover disjoint
    ascending primary ranges, so concatenation is globally sorted).

    A bucket whose total pairs fit ``cap_pairs`` is merged **in memory** —
    every run loaded with the vectorized ``_load_run``, one ``sum_by_key``
    — which is the common case by construction (a bucket holds ~1/256 of
    the key space). Oversized buckets fall back to the streaming k-way heap
    merge, so memory stays O(cap_pairs) no matter how skewed the keys are.

    ``by_bucket`` maps bucket -> [run paths]; ``live`` (optional) maps
    bucket -> (sorted unique keys, counts) for a sink's unspilled buffer.
    """
    live = dict(live or {})
    reg = obs.get_registry()
    for b in sorted(set(by_bucket) | set(live)):
        paths = by_bucket.get(b, [])
        lk = live.pop(b, None)
        # run bytes = 8·pairs + 8·rows, so size//8 never underestimates
        est = sum(os.path.getsize(p) // 8 for p in paths)
        est += len(lk[0]) if lk else 0
        reg.counter("ingest.runs_merged").inc(len(paths))
        if est <= cap_pairs:
            # the merge work is the eager part (load + aggregate); the span
            # closes before the rows are yielded so a slow consumer does not
            # inflate the merge timing
            with reg.span("ingest/bucket_merge", bucket=b, runs=len(paths)):
                parts = [_load_run(p, V) for p in paths]
                if lk is not None:
                    parts.append(lk)
                if len(parts) == 1:
                    keys, cnts = parts[0]  # a lone run is already aggregated
                else:
                    keys = np.concatenate([p[0] for p in parts])
                    cnts = np.concatenate([p[1] for p in parts])
                    # a term-order producer (LIST-SCAN) emits globally
                    # ascending keys, so consecutive spills cover disjoint
                    # ascending ranges: one diff check replaces the whole
                    # merge sort
                    if not bool((np.diff(keys) > 0).all()):
                        keys, cnts = sum_by_key(keys, cnts)
            yield from _rows_from_sorted_keys(keys, cnts, V)
        else:
            streams = [_iter_run(p) for p in paths]
            if lk is not None:
                streams.append(_rows_from_sorted_keys(lk[0], lk[1], V))
            if len(streams) == 1:
                yield from streams[0]
            else:
                yield from merge_row_streams(streams)


def merge_row_streams(streams):
    """K-way merge of row streams (each with strictly ascending primaries and
    sorted unique secondaries). Yields (primary, secondaries, counts) with
    strictly ascending primaries, duplicate pairs summed — the exact input
    shape ``csr_store.write_segment`` expects. Streams are consumed lazily,
    so memory is O(k · max row), not O(total pairs)."""
    streams = [iter(s) for s in streams]
    heap = []
    for idx, it in enumerate(streams):
        first = next(it, None)
        if first is not None:
            heap.append((first[0], idx, first))
    heapq.heapify(heap)
    while heap:
        primary = heap[0][0]
        secs_parts, cnts_parts = [], []
        while heap and heap[0][0] == primary:
            _, idx, (_, secs, cnts) = heapq.heappop(heap)
            secs_parts.append(secs)
            cnts_parts.append(cnts)
            nxt = next(streams[idx], None)
            if nxt is not None:
                heapq.heappush(heap, (nxt[0], idx, nxt))
        if len(secs_parts) == 1:
            secs = np.asarray(secs_parts[0], dtype=np.int64)
            cnts = np.asarray(cnts_parts[0], dtype=np.int64)
        else:
            secs, cnts = sum_by_key(
                np.concatenate(secs_parts).astype(np.int64),
                np.concatenate(cnts_parts),
            )
        yield primary, secs, cnts


def _rows_from_sorted_keys(keys: np.ndarray, cnts: np.ndarray, V: int):
    """Split sorted unique packed keys into per-primary rows."""
    if len(keys) == 0:
        return
    primaries = keys // V
    secondaries = keys % V
    bounds = group_bounds(primaries)
    for s, e in zip(bounds[:-1], bounds[1:]):
        yield int(primaries[s]), secondaries[s:e], cnts[s:e]


class SpillSink:
    """PairSink that spills sorted aggregated runs to disk under a memory
    budget (measured in buffered pair entries; the live buffer costs 18
    bytes per budgeted pair — packed key, count, bucket tag — plus, on the
    first unsorted spill only, 16 bytes per pair of partition scratch)."""

    def __init__(
        self,
        vocab_size: int,
        *,
        memory_budget_pairs: int = 4 << 20,
        spill_dir: str | None = None,
    ):
        if memory_budget_pairs < 1:
            raise ValueError("memory_budget_pairs must be >= 1")
        self.vocab_size = vocab_size
        self.memory_budget_pairs = memory_budget_pairs
        self._own_dir = spill_dir is None
        self.spill_dir = spill_dir or tempfile.mkdtemp(prefix="cooc_spill_")
        os.makedirs(self.spill_dir, exist_ok=True)
        # primary-range radix: bucket = primary >> pshift, <= 2^BUCKET_BITS
        # buckets spanning the vocabulary
        self._pshift = max(0, int(vocab_size).bit_length() - BUCKET_BITS)
        self.num_buckets = ((max(vocab_size, 1) - 1) >> self._pshift) + 1
        # run files, as (bucket, path); every run holds one bucket's primary
        # range, sorted — finalization merges runs bucket by bucket
        self.runs: list[tuple[int, str]] = []
        self._spills = 0
        # scratch reused across the sink's whole life: the live buffer, its
        # bucket tags, and the partition output (filled by np.take)
        cap = memory_budget_pairs
        self._buf_keys = np.empty(cap, dtype=np.int64)
        self._buf_cnts = np.empty(cap, dtype=np.int64)
        self._buf_bkt = np.empty(cap, dtype=np.uint16)
        # partition scratch is only needed on the unsorted spill path —
        # allocated on first use (term-order producers never pay for it)
        self._part_keys: np.ndarray | None = None
        self._part_cnts: np.ndarray | None = None
        self._buffered = 0
        # term-order producers (LIST-SCAN and friends) emit strictly
        # ascending keys; while the streak holds, spilling skips the radix
        # argsort + aggregation entirely (searchsorted bucket split instead)
        self._buf_sorted = True
        self._last_key = -1
        self.stats = {"spills": 0, "pairs_in": 0, "spilled_bytes": 0,
                      "bucket_runs": 0, "sorted_spills": 0}

    # ------------------------------------------------------ PairSink API
    def _reserve(self, n: int) -> int:
        """Make room for ``n`` entries. Returns the buffer write offset, or
        -1 for an oversize emission (larger than the whole buffer) that the
        caller must hand to ``_oversize`` instead."""
        if n > len(self._buf_keys) - self._buffered:
            self._spill()
        return -1 if n > len(self._buf_keys) else self._buffered

    def _commit(self, u: int, n: int) -> None:
        """Account for ``n`` entries just packed at offset ``u``: advance the
        buffer, update the ascending-emission streak, count the pairs."""
        self._buffered = u + n
        self._note_keys(self._buf_keys[u:u + n])
        self.stats["pairs_in"] += n

    def _oversize(self, keys, counts, bkt) -> None:
        """Partition an oversize emission straight to run files."""
        self._partition_spill(keys, np.asarray(counts), bkt)
        self.stats["pairs_in"] += len(keys)

    def emit_row(self, primary, secondaries, counts):
        """Row-order emission: keys are packed straight into the preallocated
        buffer (no intermediate int64 copies of ``secondaries``/``counts``)."""
        n = len(secondaries)
        if n == 0:
            return
        u = self._reserve(n)
        if u < 0:
            keys = np.int64(primary) * self.vocab_size + np.asarray(
                secondaries, dtype=np.int64
            )
            bkt = np.full(n, primary >> self._pshift, dtype=np.uint16)
            self._oversize(keys, counts, bkt)
            return
        buf = self._buf_keys[u:u + n]
        np.add(secondaries, np.int64(primary) * self.vocab_size, out=buf)
        self._buf_cnts[u:u + n] = counts
        self._buf_bkt[u:u + n] = primary >> self._pshift
        self._commit(u, n)

    def emit_col(self, secondary, primaries, counts):
        """Column-order emission (FREQ-SPLIT tail path)."""
        n = len(primaries)
        if n == 0:
            return
        primaries = np.asarray(primaries)
        u = self._reserve(n)
        if u < 0:
            keys = primaries.astype(np.int64) * self.vocab_size + np.int64(
                secondary
            )
            bkt = (primaries >> self._pshift).astype(np.uint16)
            self._oversize(keys, counts, bkt)
            return
        buf = self._buf_keys[u:u + n]
        np.multiply(primaries, np.int64(self.vocab_size), out=buf)
        np.add(buf, np.int64(secondary), out=buf)
        self._buf_cnts[u:u + n] = counts
        np.right_shift(primaries, self._pshift, out=self._buf_bkt[u:u + n],
                       casting="unsafe")
        self._commit(u, n)

    def emit_keys(self, keys, counts):
        """Batch fast path for vectorized producers: pre-packed pair keys
        (``primary * vocab_size + secondary``) in one call, skipping per-row
        splitting entirely. Semantically identical to the equivalent
        ``emit_row`` calls (same buffer contents in the same order); the
        counting hot loops use it when the sink offers it."""
        n = len(keys)
        if n == 0:
            return
        u = self._reserve(n)
        if u < 0:
            keys = np.asarray(keys, dtype=np.int64)
            bkt = ((keys // self.vocab_size) >> self._pshift).astype(np.uint16)
            self._oversize(keys, counts, bkt)
            return
        self._buf_keys[u:u + n] = keys
        self._buf_cnts[u:u + n] = counts
        np.right_shift(
            self._buf_keys[u:u + n] // self.vocab_size, self._pshift,
            out=self._buf_bkt[u:u + n], casting="unsafe",
        )
        self._commit(u, n)

    def _note_keys(self, buf: np.ndarray) -> None:
        """Track the ascending-emission streak: one O(1) range check plus an
        O(n) diff — while it holds, the spill's radix argsort and
        ``sum_by_key`` are skipped (the buffer is already sorted unique)."""
        if self._buf_sorted:
            if int(buf[0]) > self._last_key and (
                len(buf) == 1 or bool((np.diff(buf) > 0).all())
            ):
                self._last_key = int(buf[-1])
            else:
                self._buf_sorted = False

    # ------------------------------------------------------ context manager
    def __enter__(self) -> "SpillSink":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    # ---------------------------------------------------------- spilling
    def _partition(self, keys, cnts, bkt, *, is_sorted: bool = False):
        """Partition (keys, cnts) by primary-range bucket, yielding
        (bucket, sorted unique keys, summed counts) per nonempty bucket.

        ``is_sorted`` (the ascending-emission streak held for this buffer):
        bucket boundaries come from one ``searchsorted`` over the already
        sorted unique keys — no argsort, no aggregation. Otherwise a radix
        MSB pass (stable argsort of the 16-bit bucket tags into the reused
        scratch arrays) groups the buckets and each small bucket is
        aggregated independently — never a sort of the whole key space."""
        n = len(keys)
        if is_sorted:
            edges = (
                np.arange(1, self.num_buckets, dtype=np.int64) << self._pshift
            ) * self.vocab_size
            bounds = np.concatenate([[0], np.searchsorted(keys, edges), [n]])
            cnts = np.asarray(cnts, dtype=np.int64)
            for b in range(self.num_buckets):
                s, e = bounds[b], bounds[b + 1]
                if e > s:
                    yield int(b), keys[s:e], cnts[s:e]
            return
        order = np.argsort(bkt, kind="stable")  # 16-bit tags: cheap MSB sort
        if self._part_keys is None:
            cap = len(self._buf_keys)
            self._part_keys = np.empty(cap, dtype=np.int64)
            self._part_cnts = np.empty(cap, dtype=np.int64)
        pk = self._part_keys[:n] if n <= len(self._part_keys) else np.empty(
            n, dtype=np.int64
        )
        pc = self._part_cnts[:n] if n <= len(self._part_cnts) else np.empty(
            n, dtype=np.int64
        )
        np.take(keys, order, out=pk)
        np.take(np.asarray(cnts, dtype=np.int64), order, out=pc)
        sizes = np.bincount(bkt, minlength=self.num_buckets)
        bounds = np.zeros(self.num_buckets + 1, dtype=np.int64)
        np.cumsum(sizes, out=bounds[1:])
        for b in np.nonzero(sizes)[0]:
            s, e = bounds[b], bounds[b + 1]
            yield int(b), *sum_by_key(pk[s:e], pc[s:e])

    def _check_u32(self, cnts: np.ndarray) -> None:
        if len(cnts) and int(cnts.max()) >= 1 << 32:
            # the run format stores counts as u32 (paper format); a single
            # buffer can only exceed that when fed pre-aggregated counts
            raise OverflowError(
                f"aggregated count {int(cnts.max())} exceeds the u32 run "
                "format; lower memory_budget_pairs or pre-split the input"
            )

    def _partition_spill(self, keys, cnts, bkt, *, is_sorted=False) -> None:
        """Partition one batch by bucket and write each nonempty bucket as
        its own sorted run file."""
        spill_id = self._spills
        self._spills += 1
        if is_sorted:
            self.stats["sorted_spills"] += 1
        nruns0 = len(self.runs)
        bytes0 = self.stats["spilled_bytes"]
        with obs.get_registry().span(
            "ingest/spill", pairs=len(keys), sorted=is_sorted
        ) as sp:
            for b, bkeys, bcnts in self._partition(keys, cnts, bkt,
                                                   is_sorted=is_sorted):
                self._check_u32(bcnts)
                path = os.path.join(
                    self.spill_dir, f"run_{spill_id:05d}_b{b:04d}.bin"
                )
                _write_run(path, bkeys, bcnts, self.vocab_size)
                self.runs.append((b, path))
                self.stats["spilled_bytes"] += os.path.getsize(path)
            sp.set(runs=len(self.runs) - nruns0)
        self.stats["spills"] += 1
        self.stats["bucket_runs"] = len(self.runs)
        reg = obs.get_registry()
        reg.counter("ingest.spills").inc()
        reg.counter("ingest.bytes_spilled").inc(
            self.stats["spilled_bytes"] - bytes0
        )
        reg.counter("ingest.bucket_runs").inc(len(self.runs) - nruns0)

    def _spill(self) -> None:
        if self._buffered == 0:
            return
        n = self._buffered
        was_sorted = self._buf_sorted
        self._buffered = 0
        # each run stands alone: the next buffer starts a fresh streak
        self._buf_sorted = True
        self._last_key = -1
        self._partition_spill(
            self._buf_keys[:n], self._buf_cnts[:n], self._buf_bkt[:n],
            is_sorted=was_sorted,
        )

    def flush(self) -> None:
        """Force the live buffer to disk as sorted bucket runs. After a flush
        the run files alone carry the sink's full state — the PlanExecutor
        uses this to make completed shards' spill directories restart-safe."""
        self._spill()

    # --------------------------------------------------------- finalize
    def merged_rows(self):
        """Iterator of fully merged (primary, secondaries, counts) rows
        across all spilled runs and the live buffer. May be consumed once.

        Buckets partition the primary range in ascending order, so the merge
        walks buckets one at a time — in memory when the bucket fits the
        merge cap (4× the spill budget), via the streaming heap otherwise —
        never holding more than one bucket's pairs at once
        (see ``merge_bucket_runs``)."""
        runs_by_bucket: dict[int, list[str]] = {}
        for b, path in self.runs:
            runs_by_bucket.setdefault(b, []).append(path)
        live: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        if self._buffered:
            n = self._buffered
            was_sorted = self._buf_sorted
            self._buffered = 0
            live = {
                b: (bkeys, bcnts)
                for b, bkeys, bcnts in self._partition(
                    self._buf_keys[:n], self._buf_cnts[:n], self._buf_bkt[:n],
                    is_sorted=was_sorted,
                )
            }
        yield from merge_bucket_runs(
            runs_by_bucket, self.vocab_size,
            cap_pairs=4 * self.memory_budget_pairs, live=live,
        )

    def finalize_segment(
        self,
        out_dir: str,
        *,
        df: np.ndarray | None = None,
        num_docs: int = 0,
        source: str = "spill",
        version: int | None = None,
    ):
        """Merge everything into a CSR segment at ``out_dir`` and clean up
        the spill files. Returns the opened segment (``version`` picks the
        on-disk format, see ``csr_store.write_segment``)."""
        from repro.store.csr_store import open_segment, write_segment

        write_segment(
            out_dir,
            self.merged_rows(),
            self.vocab_size,
            df=df,
            num_docs=num_docs,
            source=source,
            version=version,
        )
        self.close()
        return open_segment(out_dir)

    def close(self) -> None:
        """Delete spill files (and the spill dir if we created it)."""
        for _, p in self.runs:
            if os.path.exists(p):
                os.remove(p)
        self.runs = []
        self._buffered = 0
        if self._own_dir and os.path.isdir(self.spill_dir):
            shutil.rmtree(self.spill_dir, ignore_errors=True)
