"""Persistent, queryable co-occurrence store.

Layers: ``builder`` (SpillSink: budgeted spill-and-merge from any PairSink
producer) → ``csr_store`` (immutable mmap CSR segments) → ``segments``
(LSM manifest: incremental append, shard ingest, compaction) → ``query``
(batched pair/top-k/PMI engine, numpy or Pallas kernel) → ``serving``
(multi-process shared-mmap workers with cross-client micro-batching).
See docs/architecture.md for the dataflow and docs/formats.md for the
on-disk layout.
"""

from repro.store.builder import SpillSink, merge_row_streams
from repro.store.csr_store import CSRSegment, segment_from_pair_file, write_segment
from repro.store.query import QueryEngine
from repro.store.segments import Store
from repro.store.serving import CoocClient, CoocServer, ServingConfig

__all__ = [
    "SpillSink",
    "merge_row_streams",
    "CSRSegment",
    "segment_from_pair_file",
    "write_segment",
    "QueryEngine",
    "Store",
    "CoocServer",
    "CoocClient",
    "ServingConfig",
]
