"""Persistent, queryable co-occurrence store.

Layers: ``builder`` (SpillSink: budgeted spill-and-merge from any PairSink
producer) → ``codec``/``bloom`` (block-compressed columns, blocked bloom
filters) → ``csr_store`` (immutable segments: v1 raw mmap or v2
compressed, one ``open_segment`` dispatch) → ``segments`` (LSM manifest:
incremental append, shard ingest, size-tiered foreground/background
compaction; ``compaction`` adds the tier-pressure daemon that keeps a
continuously growing store converged) → ``requests`` (typed query requests, QueryPlanner
routing/coalescing, one execution path) → ``query`` (batched
pair/top-k/PMI engine, numpy or Pallas kernel) → ``serving``
(multi-process shared-mmap workers with cross-client micro-batching,
hot-term routing, streaming top-k, and supervised fault tolerance:
worker respawn, admission control, deadline propagation).
See docs/architecture.md for the dataflow, docs/formats.md for the
on-disk layout, and docs/serving.md for the query API + wire protocol.
"""

from repro.store.bloom import BloomFilter
from repro.store.builder import SpillSink, merge_row_streams
from repro.store.compaction import CompactionDaemon, CompactionPolicy
from repro.store.codec import BlockCache, CompressedColumn, write_column
from repro.store.csr_store import (
    CompressedSegment,
    CSRSegment,
    compress_segment,
    open_segment,
    segment_bytes,
    segment_from_pair_file,
    write_segment,
)
from repro.store.query import QueryEngine
from repro.store.requests import (
    NeighboursRequest,
    PairCountsRequest,
    QueryPlan,
    QueryPlanner,
    TopKRequest,
    route_term,
)
from repro.store.segments import CompactionHandle, Store
from repro.store.serving import (
    CoocClient,
    CoocServer,
    ServerOverloaded,
    ServingConfig,
    ServingError,
    WorkerDied,
)

__all__ = [
    "SpillSink",
    "merge_row_streams",
    "BloomFilter",
    "BlockCache",
    "CompressedColumn",
    "write_column",
    "CSRSegment",
    "CompressedSegment",
    "compress_segment",
    "open_segment",
    "segment_bytes",
    "segment_from_pair_file",
    "write_segment",
    "QueryEngine",
    "Store",
    "CompactionHandle",
    "CompactionDaemon",
    "CompactionPolicy",
    "TopKRequest",
    "PairCountsRequest",
    "NeighboursRequest",
    "QueryPlan",
    "QueryPlanner",
    "route_term",
    "CoocServer",
    "CoocClient",
    "ServingConfig",
    "ServingError",
    "WorkerDied",
    "ServerOverloaded",
]
