"""Block-wise compressed integer columns for v2 CSR segments.

A *column* is an immutable on-disk sequence of integers (the CSR ``cols``,
``counts``, ``row_ptr``, … arrays) stored as fixed-size **blocks** behind a
per-block offset directory, so a point or range read decodes only the
blocks it spans — the random-access discipline of the raw mmap arrays,
kept, at a fraction of the bytes:

    header      32 B      magic, codec, mode, dtype, block size, n values
    offsets     u64[B+1]  payload byte offset of each block (B = #blocks)
    anchors     i64[B]    first value of each block (delta restart points;
                          doubles as a block-level index for binary search)
    payload               concatenated per-block encodings

Two codecs (both lossless, both vectorized end to end — no per-value
Python):

* ``varint`` — LEB128 with zigzag: each value in 1–10 bytes, 7 payload bits
  per byte. The workhorse for counts (mostly tiny) and for column deltas.
* ``bitpack`` — per-block frame-of-reference: subtract the block minimum
  and pack every value at the block's exact bit width via
  ``np.packbits``. The workhorse for monotone columns (``row_ptr``, term
  ids) whose deltas are narrow and uniform.

Two modes:

* ``raw``   — values encoded directly;
* ``delta`` — consecutive differences encoded (zigzag handles the negative
  jumps at CSR row boundaries); each block restarts from its anchor, so
  decoding one block never touches another.

:class:`CompressedColumn` is the reader: ``slice(lo, hi)`` decodes only the
covering blocks (through a shared :class:`BlockCache` LRU), and ``find``
binary-searches a sorted column by bisecting the anchor directory first and
decoding exactly one block. Telemetry lands on the ambient
:class:`repro.obs.Registry` (``storage.blocks_decoded``,
``storage.block_cache_hits`` / ``_misses``) or a registry injected by the
owning segment, so serving workers report codec traffic cross-process.

Example::

    >>> import numpy as np, tempfile, os
    >>> path = os.path.join(tempfile.mkdtemp(), "c.z")
    >>> write_column(path, np.array([3, 9, 27, 81]), mode="delta",
    ...              codec="varint")
    >>> CompressedColumn(path).slice(1, 3).tolist()
    [9, 27]
"""

from __future__ import annotations

import os
from collections import OrderedDict

import numpy as np

from repro import obs

COLUMN_MAGIC = 0x315A4F43  # "COZ1" little-endian
DEFAULT_BLOCK = 1024

CODECS = ("varint", "bitpack")
MODES = ("raw", "delta")
_DTYPES = {0: np.int32, 1: np.int64}
_DTYPE_CODES = {np.dtype(np.int32): 0, np.dtype(np.int64): 1}

_U = np.uint64
_ONE = _U(1)
_SEVEN = _U(7)


# ---------------------------------------------------------------------------
# zigzag + varint (vectorized LEB128)
# ---------------------------------------------------------------------------


def zigzag_encode(v: np.ndarray) -> np.ndarray:
    """int64 -> uint64 zigzag (0, -1, 1, -2, … -> 0, 1, 2, 3, …)."""
    v = np.ascontiguousarray(v, dtype=np.int64)
    u = v.view(np.uint64)
    return (u << _ONE) ^ np.where(v < 0, _U(0xFFFFFFFFFFFFFFFF), _U(0))


def zigzag_decode(u: np.ndarray) -> np.ndarray:
    """uint64 zigzag -> int64 (exact inverse of :func:`zigzag_encode`)."""
    u = np.asarray(u, dtype=np.uint64)
    return ((u >> _ONE) ^ (_U(0) - (u & _ONE))).view(np.int64)


def varint_encode(u: np.ndarray) -> np.ndarray:
    """uint64 values -> one LEB128 byte stream (uint8 array). Vectorized:
    per-value byte counts by repeated shift, then one scatter of 7-bit
    chunks with continuation bits."""
    u = np.asarray(u, dtype=np.uint64)
    n = len(u)
    if n == 0:
        return np.zeros(0, dtype=np.uint8)
    nbytes = np.ones(n, dtype=np.int64)
    x = u >> _SEVEN
    while x.any():  # <= 9 rounds for 64-bit values
        nbytes += x != 0
        x >>= _SEVEN
    starts = np.zeros(n, dtype=np.int64)
    np.cumsum(nbytes[:-1], out=starts[1:])
    total = int(starts[-1] + nbytes[-1])
    val_of = np.repeat(np.arange(n, dtype=np.int64), nbytes)
    byte_in = np.arange(total, dtype=np.int64) - np.repeat(starts, nbytes)
    chunk = (u[val_of] >> (_SEVEN * byte_in.astype(np.uint64))) & _U(0x7F)
    cont = byte_in < (nbytes[val_of] - 1)
    return (chunk.astype(np.uint8) | (cont.astype(np.uint8) << 7))


def varint_decode(b: np.ndarray) -> np.ndarray:
    """LEB128 byte stream -> uint64 values. Vectorized: value boundaries
    from the continuation bits, then one ``np.add.reduceat`` of shifted
    7-bit chunks."""
    b = np.asarray(b, dtype=np.uint8)
    if len(b) == 0:
        return np.zeros(0, dtype=np.uint64)
    ends = np.nonzero(b < 128)[0]
    if len(ends) == 0 or ends[-1] != len(b) - 1:
        raise ValueError("truncated varint stream")
    starts = np.empty(len(ends), dtype=np.int64)
    starts[0] = 0
    starts[1:] = ends[:-1] + 1
    lens = ends - starts + 1
    byte_in = np.arange(len(b), dtype=np.int64) - np.repeat(starts, lens)
    chunks = (b & 0x7F).astype(np.uint64) << (
        _SEVEN * byte_in.astype(np.uint64)
    )
    return np.add.reduceat(chunks, starts)


# ---------------------------------------------------------------------------
# frame-of-reference bitpacking
# ---------------------------------------------------------------------------


def bitpack_encode(u: np.ndarray) -> np.ndarray:
    """uint64 values -> ``[width u8 | ref u64 | packed bits]`` (uint8 array).
    Frame of reference: values are stored as ``v - min(v)`` at the block's
    exact bit width (width 0 when all values are equal)."""
    u = np.asarray(u, dtype=np.uint64)
    if len(u) == 0:
        return np.zeros(0, dtype=np.uint8)
    ref = u.min()
    d = u - ref
    width = int(d.max()).bit_length()
    head = np.zeros(9, dtype=np.uint8)
    head[0] = width
    head[1:9] = np.array([ref], dtype="<u8").view(np.uint8)
    if width == 0:
        return head
    shifts = np.arange(width - 1, -1, -1, dtype=np.uint64)
    bits = ((d[:, None] >> shifts) & _ONE).astype(np.uint8)
    return np.concatenate([head, np.packbits(bits.ravel())])


def bitpack_decode(b: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`bitpack_encode` for a block of ``n`` values."""
    b = np.asarray(b, dtype=np.uint8)
    if n == 0:
        return np.zeros(0, dtype=np.uint64)
    width = int(b[0])
    ref = b[1:9].copy().view("<u8")[0]
    if width == 0:
        return np.full(n, ref, dtype=np.uint64)
    bits = np.unpackbits(b[9:], count=n * width).reshape(n, width)
    shifts = np.arange(width - 1, -1, -1, dtype=np.uint64)
    vals = (bits.astype(np.uint64) << shifts).sum(axis=1, dtype=np.uint64)
    return vals + ref


# ---------------------------------------------------------------------------
# column writer
# ---------------------------------------------------------------------------

_HEADER_BYTES = 32


def _encode_block(vals: np.ndarray, prev: int, codec: str, mode: str):
    """Encode one block (int64 values). ``prev`` is the last value of the
    preceding block (ignored for the first block / raw mode)."""
    if mode == "delta":
        d = np.empty(len(vals), dtype=np.int64)
        d[0] = 0  # the anchor carries the first value
        np.subtract(vals[1:], vals[:-1], out=d[1:])
        u = zigzag_encode(d)
    else:
        u = zigzag_encode(vals)
    return varint_encode(u) if codec == "varint" else bitpack_encode(u)


def write_column(
    path: str,
    values,
    *,
    mode: str = "raw",
    codec: str = "varint",
    block: int = DEFAULT_BLOCK,
    chunk_blocks: int = 1024,
) -> int:
    """Write ``values`` (any 1-D integer array / memmap) as a compressed
    column file. Streams ``chunk_blocks`` blocks at a time, so encoding a
    memmapped nnz-sized array never materializes it whole. Returns the
    encoded file size in bytes.

    ``mode="delta"`` requires nothing of the data (zigzag absorbs negative
    jumps) but pays off when consecutive values are close; ``find`` on the
    reader additionally requires the column to be globally non-decreasing.
    """
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r}; have {CODECS}")
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; have {MODES}")
    if block < 2:
        raise ValueError("block size must be >= 2")
    n = len(values)
    out_dtype = np.dtype(values.dtype) if hasattr(values, "dtype") else None
    if out_dtype not in _DTYPE_CODES:
        out_dtype = np.dtype(np.int64)
    n_blocks = (n + block - 1) // block
    header = np.zeros(_HEADER_BYTES, dtype=np.uint8)
    header[0:4] = np.array([COLUMN_MAGIC], dtype="<u4").view(np.uint8)
    header[4] = 1  # column format version
    header[5] = CODECS.index(codec)
    header[6] = MODES.index(mode)
    header[7] = _DTYPE_CODES[out_dtype]
    header[8:12] = np.array([block], dtype="<u4").view(np.uint8)
    header[12:20] = np.array([n], dtype="<u8").view(np.uint8)
    offsets = np.zeros(n_blocks + 1, dtype=np.uint64)
    anchors = np.zeros(n_blocks, dtype=np.int64)
    dir_bytes = offsets.nbytes + anchors.nbytes
    with open(path, "wb") as f:
        f.write(header.tobytes())
        f.seek(_HEADER_BYTES + dir_bytes)
        pos = 0
        for c0 in range(0, n, block * chunk_blocks):
            c1 = min(c0 + block * chunk_blocks, n)
            vals = np.ascontiguousarray(values[c0:c1], dtype=np.int64)
            for b0 in range(0, len(vals), block):
                k = (c0 + b0) // block
                bv = vals[b0:b0 + block]
                anchors[k] = bv[0]
                payload = _encode_block(bv, 0, codec, mode)
                f.write(payload.tobytes())
                pos += len(payload)
                offsets[k + 1] = pos
        f.seek(_HEADER_BYTES)
        f.write(offsets.tobytes())
        f.write(anchors.tobytes())
    return _HEADER_BYTES + dir_bytes + pos


# ---------------------------------------------------------------------------
# block cache
# ---------------------------------------------------------------------------


class BlockCache:
    """Small LRU over decoded blocks, shared by every column of a segment
    (keys are ``(column_tag, block_index)``). Capacity is counted in blocks
    — at the default 1024-value blocks, 256 cached blocks ≈ 2 MB of decoded
    int64 — so a serving worker's steady state touches the page cache only
    for genuinely cold blocks."""

    def __init__(self, max_blocks: int = 256, registry=None):
        self.max_blocks = max_blocks
        self._blocks: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self._registry = registry

    @property
    def registry(self):
        return self._registry if self._registry is not None else obs.get_registry()

    def get(self, key: tuple):
        hit = self._blocks.get(key)
        if hit is not None:
            self._blocks.move_to_end(key)
            self.registry.counter("storage.block_cache_hits").inc()
        else:
            self.registry.counter("storage.block_cache_misses").inc()
        return hit

    def put(self, key: tuple, block: np.ndarray) -> None:
        self._blocks[key] = block
        if len(self._blocks) > self.max_blocks:
            self._blocks.popitem(last=False)

    def clear(self) -> None:
        self._blocks.clear()


# ---------------------------------------------------------------------------
# column reader
# ---------------------------------------------------------------------------


class CompressedColumn:
    """Read-only view of one compressed column file. The file is mmapped;
    ``slice``/``at`` decode only the blocks the request spans, through the
    shared :class:`BlockCache` when one is attached."""

    def __init__(
        self,
        path: str,
        *,
        cache: BlockCache | None = None,
        tag: str | None = None,
        registry=None,
    ):
        self.path = path
        self._cache = cache
        self._tag = tag if tag is not None else path
        self._registry = registry
        raw = np.memmap(path, dtype=np.uint8, mode="r")
        if len(raw) < _HEADER_BYTES:
            raise ValueError(f"not a compressed column (truncated): {path}")
        header = np.asarray(raw[:_HEADER_BYTES])
        if int(header[0:4].view("<u4")[0]) != COLUMN_MAGIC:
            raise ValueError(f"bad column magic in {path}")
        if int(header[4]) != 1:
            raise ValueError(f"unsupported column version {header[4]} in {path}")
        self.codec = CODECS[int(header[5])]
        self.mode = MODES[int(header[6])]
        self.dtype = np.dtype(_DTYPES[int(header[7])])
        self.block = int(header[8:12].view("<u4")[0])
        self.n = int(header[12:20].view("<u8")[0])
        n_blocks = (self.n + self.block - 1) // self.block
        self.n_blocks = n_blocks
        o0 = _HEADER_BYTES
        o1 = o0 + 8 * (n_blocks + 1)
        o2 = o1 + 8 * n_blocks
        self._offsets = raw[o0:o1].view(np.uint64)
        self.anchors = raw[o1:o2].view(np.int64)
        self._payload = raw[o2:]

    def __len__(self) -> int:
        return self.n

    @property
    def registry(self):
        return self._registry if self._registry is not None else obs.get_registry()

    # -------------------------------------------------------------- decode
    def _decode_block(self, k: int) -> np.ndarray:
        """Decoded int64 values of block ``k`` (cached)."""
        key = (self._tag, k)
        if self._cache is not None:
            hit = self._cache.get(key)
            if hit is not None:
                return hit
        lo, hi = int(self._offsets[k]), int(self._offsets[k + 1])
        raw = self._payload[lo:hi]
        n = min(self.block, self.n - k * self.block)
        if self.codec == "varint":
            u = varint_decode(np.asarray(raw))
            if len(u) != n:
                raise ValueError(
                    f"block {k} of {self.path} decoded {len(u)} values, "
                    f"expected {n}"
                )
        else:
            u = bitpack_decode(np.asarray(raw), n)
        vals = zigzag_decode(u)
        if self.mode == "delta":
            vals = vals.copy()
            vals[0] = self.anchors[k]
            np.cumsum(vals, out=vals)
        self.registry.counter("storage.blocks_decoded").inc()
        if self._cache is not None:
            self._cache.put(key, vals)
        return vals

    def slice(self, lo: int, hi: int) -> np.ndarray:
        """``values[lo:hi]`` decoded from the covering blocks only."""
        lo = max(int(lo), 0)
        hi = min(int(hi), self.n)
        if hi <= lo:
            return np.zeros(0, dtype=self.dtype)
        b0, b1 = lo // self.block, (hi - 1) // self.block
        if b0 == b1:
            vals = self._decode_block(b0)
        else:
            vals = np.concatenate(
                [self._decode_block(k) for k in range(b0, b1 + 1)]
            )
        out = vals[lo - b0 * self.block: hi - b0 * self.block]
        return out.astype(self.dtype, copy=False)

    def at(self, i: int) -> int:
        """Single value (decodes one block)."""
        if not 0 <= i < self.n:
            raise IndexError(i)
        return int(self._decode_block(i // self.block)[i % self.block])

    def decode_all(self) -> np.ndarray:
        """The whole column as one array (bulk readers: df, iter_rows)."""
        return self.slice(0, self.n)

    # -------------------------------------------------------------- search
    def find(self, v: int) -> int:
        """Index of ``v`` in a sorted (non-decreasing) column, or -1.
        Bisects the anchor directory, then decodes exactly one block."""
        if self.n == 0:
            return -1
        k = int(np.searchsorted(self.anchors, v, side="right")) - 1
        if k < 0:
            return -1
        vals = self._decode_block(k)
        j = int(np.searchsorted(vals, v, side="left"))
        if j < len(vals) and int(vals[j]) == v:
            return k * self.block + j
        return -1
