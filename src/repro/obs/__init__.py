"""Zero-dependency telemetry: spans, counters, and cross-process metrics.

The observability layer behind the ingest, query, and serving pipelines
(ISSUE 6): nested wall-time :class:`Span` context managers, ``Counter`` /
``Gauge`` / mergeable log-bucket ``Histogram`` metrics, a process-local
:class:`Registry`, and two exporters — Chrome ``trace_event`` JSON
(``chrome://tracing`` / Perfetto) and Prometheus text.

Telemetry is **off by default**: the global registry starts disabled and
every instrumented call site degrades to a no-op (the ingest throughput
gate in CI runs with telemetry disabled and doubles as the overhead
regression test). Drivers enable it with ``--trace-out`` /
``--metrics-interval`` (see launch/cooc_run.py, launch/cooc_serve.py), and
benchmarks/tests use :func:`scoped`.

See docs/observability.md for the span taxonomy and metric names.
"""

from repro.obs.export import (
    chrome_trace,
    load_trace,
    prometheus_text,
    span_names,
    write_trace,
)
from repro.obs.metrics import Counter, Gauge, Histogram, merge_snapshots
from repro.obs.registry import (
    NULL_METRIC,
    NULL_SPAN,
    Registry,
    Span,
    configure,
    get_registry,
    scoped,
    set_registry,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "Span",
    "merge_snapshots",
    "configure",
    "get_registry",
    "set_registry",
    "scoped",
    "chrome_trace",
    "write_trace",
    "load_trace",
    "span_names",
    "prometheus_text",
    "NULL_SPAN",
    "NULL_METRIC",
]
