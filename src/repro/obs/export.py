"""Telemetry exporters: Chrome ``trace_event`` JSON and Prometheus text.

Two formats, one registry:

* :func:`chrome_trace` / :func:`write_trace` — the span event log as a
  Chrome trace (``{"traceEvents": [...]}`` with complete ``"ph": "X"``
  events), loadable in ``chrome://tracing`` or https://ui.perfetto.dev —
  answers "where did the time go" for one run visually;
* :func:`prometheus_text` — every counter/gauge/histogram of a snapshot as
  Prometheus exposition text (histograms as quantile-labelled summaries),
  what ``--metrics-interval`` dumps periodically and a scraper would
  ingest.

Both work on plain snapshot dicts too, so the serving parent can export
metrics merged from worker processes it never shared memory with.
"""

from __future__ import annotations

import json
import os
import re

from repro.obs.metrics import Histogram

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def chrome_trace(reg) -> dict:
    """The registry's span log as a Chrome trace dict. Thread ids are
    compacted to small integers (first-seen order); span attributes ride in
    ``args``; counters are attached as one final metadata event so a trace
    is self-contained.

    Example::

        trace = reg.chrome_trace()
        {e["ph"] for e in trace["traceEvents"]} <= {"X", "M"}   # True
    """
    pid = os.getpid()
    tids: dict[int, int] = {}
    events = []
    for e in reg.span_events():
        tid = tids.setdefault(e["tid"], len(tids))
        events.append(
            {
                "name": e["name"],
                "cat": e["name"].split("/", 1)[0],
                "ph": "X",
                "ts": round(e["ts_us"], 3),
                "dur": round(e["dur_us"], 3),
                "pid": pid,
                "tid": tid,
                "args": {**e["args"], "depth": e["depth"]},
            }
        )
    snap = reg.snapshot()
    meta = {
        "name": "repro.obs",
        "ph": "M",
        "pid": pid,
        "tid": 0,
        "ts": 0,
        "args": {
            "counters": snap["counters"],
            "dropped_events": snap["dropped_events"],
            "epoch_unix": reg.epoch_unix,
        },
    }
    return {"traceEvents": events + [meta], "displayTimeUnit": "ms"}


def write_trace(reg, path: str) -> str:
    """Write the Chrome trace JSON to ``path``; returns the path."""
    with open(path, "w") as f:
        json.dump(chrome_trace(reg), f)
    return path


def load_trace(path: str) -> dict:
    """Parse a trace file back; raises if it is not a valid trace (used by
    the CI telemetry smoke step)."""
    with open(path) as f:
        trace = json.load(f)
    if "traceEvents" not in trace:
        raise ValueError(f"{path} is not a Chrome trace (no traceEvents)")
    return trace


def span_names(trace: dict) -> set[str]:
    """The distinct span names of a loaded trace ("X" events only)."""
    return {e["name"] for e in trace["traceEvents"] if e.get("ph") == "X"}


def _metric_name(name: str, prefix: str) -> str:
    return _NAME_RE.sub("_", f"{prefix}_{name}" if prefix else name)


def prometheus_text(snapshot: dict, *, prefix: str = "repro") -> str:
    """A metrics snapshot as Prometheus exposition text. Counters become
    ``counter`` samples, gauges ``gauge``, histograms summary-style
    ``{quantile=...}`` samples plus ``_sum``/``_count`` (quantiles come
    from the mergeable log buckets, so scraped values match what
    ``CoocServer.stats()`` reports).

    Example::

        text = prometheus_text({"counters": {"ingest.spills": 3},
                                "gauges": {}, "histograms": {}})
        "repro_ingest_spills 3" in text      # True
    """
    lines: list[str] = []
    for name in sorted(snapshot.get("counters", {})):
        m = _metric_name(name, prefix)
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {snapshot['counters'][name]}")
    for name in sorted(snapshot.get("gauges", {})):
        m = _metric_name(name, prefix)
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {snapshot['gauges'][name]:g}")
    for name in sorted(snapshot.get("histograms", {})):
        h = Histogram.from_state(snapshot["histograms"][name])
        m = _metric_name(name, prefix)
        lines.append(f"# TYPE {m} summary")
        for q in (0.5, 0.95, 0.99):
            lines.append(f'{m}{{quantile="{q}"}} {h.percentile(q * 100):g}')
        lines.append(f"{m}_sum {h.total:g}")
        lines.append(f"{m}_count {h.count}")
    return "\n".join(lines) + "\n"
