"""Process-local metric primitives: Counter, Gauge, and a log-bucket
Histogram whose percentiles survive merging.

The histogram is the load-bearing piece: latency distributions must travel
from N serving worker processes to the parent and aggregate into fleet
percentiles *without* shipping samples. Values land in fixed logarithmic
buckets (``SUBDIV`` buckets per octave, so bucket edges are powers of
``2**(1/SUBDIV)``); merging two histograms is bucket-wise addition, and any
quantile of the merge equals the quantile of the pooled samples to within
one bucket's relative width (≈ 9% at the default ``SUBDIV = 8``) — the
property tests/test_obs.py asserts directly.

Every metric serializes to a plain-dict ``state()`` (picklable, JSON-able)
and reconstructs with ``from_state`` — that is the wire format the serving
workers publish over the stats queue.
"""

from __future__ import annotations

import math

# log-bucket resolution: SUBDIV buckets per octave -> bucket edges at
# 2**(i/SUBDIV); relative quantile error is bounded by 2**(1/SUBDIV) - 1
SUBDIV = 8
_MIN_IDX = -30 * SUBDIV        # ~1 ns: everything smaller collapses here
_MAX_IDX = 34 * SUBDIV         # ~5e9 s: everything larger collapses here


def bucket_index(value: float) -> int:
    """The fixed log bucket a value falls in (non-positive values clamp to
    the smallest bucket — latencies are never negative, but a clock can
    read 0.0 on coarse timers)."""
    if value <= 0.0:
        return _MIN_IDX
    i = int(math.floor(math.log2(value) * SUBDIV))
    return _MIN_IDX if i < _MIN_IDX else (_MAX_IDX if i > _MAX_IDX else i)


def bucket_mid(idx: int) -> float:
    """Geometric midpoint of bucket ``idx`` (the reported quantile value)."""
    return 2.0 ** ((idx + 0.5) / SUBDIV)


class Counter:
    """Monotonic counter. ``inc`` is the only mutator; merge is addition."""

    __slots__ = ("value",)

    def __init__(self, value: int = 0):
        self.value = value

    def inc(self, n: int = 1) -> None:
        self.value += n

    def state(self):
        return self.value


class Gauge:
    """Last-write-wins instantaneous value (e.g. batch-window occupancy)."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = float(v)

    def state(self):
        return self.value


class Histogram:
    """Fixed log-bucket histogram: p50/p95/p99 come from merges, not stored
    samples.

    ``record`` costs one ``log2`` plus a dict increment; ``merge`` adds
    bucket counts, so per-worker histograms aggregate into exact pooled
    bucket counts (quantiles agree with pooled samples to within one
    bucket's relative width). ``min``/``max``/``sum`` are tracked exactly.

    Example::

        h = Histogram()
        for v in (0.001, 0.002, 0.004):
            h.record(v)
        h.count, round(h.percentile(50), 3)     # (3, ~0.002)
    """

    __slots__ = ("buckets", "count", "total", "vmin", "vmax")

    def __init__(self):
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def record(self, value: float) -> None:
        idx = bucket_index(value)
        self.buckets[idx] = self.buckets.get(idx, 0) + 1
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value

    # ------------------------------------------------------------ queries
    def percentile(self, q: float) -> float:
        """The q-th percentile (q in (0, 100]): geometric midpoint of the
        bucket holding the rank-``ceil(q/100 * count)`` sample, clamped to
        the exact observed [min, max] so tiny histograms don't report
        values outside what was recorded."""
        if self.count == 0:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = 0
        for idx in sorted(self.buckets):
            seen += self.buckets[idx]
            if seen >= rank:
                return min(max(bucket_mid(idx), self.vmin), self.vmax)
        return self.vmax  # pragma: no cover - rank <= count always hits

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> dict:
        """JSON-able digest: count/sum/mean plus p50/p95/p99."""
        return {
            "count": self.count,
            "sum": round(self.total, 6),
            "mean": round(self.mean, 6),
            "min": round(self.vmin, 6) if self.count else 0.0,
            "max": round(self.vmax, 6) if self.count else 0.0,
            "p50": round(self.percentile(50), 6),
            "p95": round(self.percentile(95), 6),
            "p99": round(self.percentile(99), 6),
        }

    # ------------------------------------------------------------ merging
    def merge(self, other: "Histogram") -> "Histogram":
        """Absorb ``other`` (bucket-wise addition); returns self."""
        for idx, n in other.buckets.items():
            self.buckets[idx] = self.buckets.get(idx, 0) + n
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    def state(self) -> dict:
        return {
            "buckets": dict(self.buckets),
            "count": self.count,
            "total": self.total,
            "vmin": self.vmin,
            "vmax": self.vmax,
        }

    @classmethod
    def from_state(cls, state: dict) -> "Histogram":
        h = cls()
        # JSON round-trips stringify dict keys; accept both
        h.buckets = {int(k): int(v) for k, v in state["buckets"].items()}
        h.count = state["count"]
        h.total = state["total"]
        h.vmin = state["vmin"]
        h.vmax = state["vmax"]
        return h


def merge_snapshots(snapshots) -> dict:
    """Merge registry snapshots (``Registry.snapshot()`` dicts) from N
    processes into one: counters add, gauges keep the last non-None value,
    histograms merge bucket-wise. The parent serving process uses this to
    turn per-worker snapshots into fleet-level stats.

    Example::

        merged = merge_snapshots([w1.snapshot(), w2.snapshot()])
        Histogram.from_state(merged["histograms"]["lat"]).percentile(99)
    """
    out = {"counters": {}, "gauges": {}, "histograms": {}, "dropped_events": 0}
    base_epoch = None
    for snap in snapshots:
        if not snap:
            continue
        for name, v in snap.get("counters", {}).items():
            out["counters"][name] = out["counters"].get(name, 0) + v
        for name, v in snap.get("gauges", {}).items():
            out["gauges"][name] = v
        for name, state in snap.get("histograms", {}).items():
            if name in out["histograms"]:
                merged = Histogram.from_state(out["histograms"][name])
                merged.merge(Histogram.from_state(state))
                out["histograms"][name] = merged.state()
            else:
                out["histograms"][name] = dict(state)
        out["dropped_events"] += snap.get("dropped_events", 0)
        # span events (snapshots taken with include_events=True) concatenate
        # onto the first contributing snapshot's timeline: each later
        # snapshot's events are shifted by its unix-epoch offset, so one
        # merged snapshot holds a coherent multi-process span log
        events = snap.get("events")
        if events:
            epoch = snap.get("epoch_unix", 0.0)
            if base_epoch is None:
                base_epoch = epoch
                out["epoch_unix"] = epoch
            shift_us = (epoch - base_epoch) * 1e6
            out.setdefault("events", []).extend(
                dict(e, ts_us=e["ts_us"] + shift_us) for e in events
            )
    return out
