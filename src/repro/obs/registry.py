"""Spans and the process-local telemetry Registry.

One :class:`Registry` per process holds every counter/gauge/histogram plus
the finished-span event log. Telemetry is **off by default**: the module
global starts disabled, and a disabled registry hands out shared no-op
singletons — ``span()`` returns a reusable null context manager and
``counter()``/``histogram()``/``gauge()`` return a null metric — so the
instrumented hot paths cost one attribute check when nothing is listening
(the ``BENCH_ingest.json`` throughput gate runs with telemetry disabled and
doubles as the overhead regression test).

Spans nest per thread (a thread-local stack provides parent/depth), carry
attributes, and land in the event log as Chrome ``trace_event``-shaped
records; exporters (obs/export.py) turn the log into a ``chrome://tracing``
/ Perfetto file and the metric tables into Prometheus text.

Example::

    reg = Registry(enabled=True)
    with reg.span("ingest/count", shard=0):
        reg.counter("ingest.pairs_in").inc(128)
    reg.span_events()[0]["name"]            # 'ingest/count'
"""

from __future__ import annotations

import contextlib
import threading
import time

from repro.obs.metrics import Counter, Gauge, Histogram


class _NullSpan:
    """Shared no-op span: the disabled path allocates nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs) -> None:
        pass


class _NullMetric:
    """Shared no-op counter/gauge/histogram for disabled registries."""

    __slots__ = ()
    value = 0
    count = 0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def record(self, v) -> None:
        pass


NULL_SPAN = _NullSpan()
NULL_METRIC = _NullMetric()


class Span:
    """A nested wall-time span (context manager).

    Timing uses ``time.perf_counter`` relative to the registry's epoch;
    nesting depth comes from a per-thread stack, so concurrent client
    threads each get a coherent span tree. ``set(**attrs)`` adds/overrides
    attributes mid-flight (e.g. a result count known only at the end).
    """

    __slots__ = ("_reg", "name", "attrs", "_t0", "_depth")

    def __init__(self, reg: "Registry", name: str, attrs: dict):
        self._reg = reg
        self.name = name
        self.attrs = attrs
        self._t0 = 0.0
        self._depth = 0

    def __enter__(self) -> "Span":
        stack = self._reg._stack()
        self._depth = len(stack)
        stack.append(self.name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        end = time.perf_counter()
        self._reg._stack().pop()
        self._reg._record_span(self, self._t0, end - self._t0)
        return False

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)


class Registry:
    """Process-local home of every metric and span event.

    * ``counter``/``gauge``/``histogram`` create-or-return named metrics;
    * ``span`` opens a nested wall-time span;
    * ``snapshot()`` is the picklable cross-process wire format (merged
      with :func:`repro.obs.metrics.merge_snapshots`);
    * ``chrome_trace()``/``prometheus_text()`` are the two export formats
      (see obs/export.py and docs/observability.md).

    A disabled registry (``enabled=False``) hands out shared no-op objects:
    the instrumented code paths run, but record nothing and allocate
    nothing.
    """

    def __init__(self, *, enabled: bool = True, max_events: int = 200_000):
        self.enabled = enabled
        self.max_events = max_events
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}
        self._events: list[dict] = []
        self.dropped_events = 0
        self._lock = threading.Lock()
        self._tls = threading.local()
        # span timestamps are perf_counter-relative to this epoch; the unix
        # epoch anchors the trace in wall-clock time for display
        self._epoch = time.perf_counter()
        self.epoch_unix = time.time()

    # ------------------------------------------------------------ metrics
    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return NULL_METRIC
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter())
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return NULL_METRIC
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge())
        return g

    def histogram(self, name: str) -> Histogram:
        if not self.enabled:
            return NULL_METRIC
        h = self._histograms.get(name)
        if h is None:
            with self._lock:
                h = self._histograms.setdefault(name, Histogram())
        return h

    # -------------------------------------------------------------- spans
    def span(self, name: str, **attrs):
        """Open a nested wall-time span; no-op when disabled.

        Example::

            with reg.span("ingest/count", shard=3) as sp:
                sp.set(pairs=n)
        """
        if not self.enabled:
            return NULL_SPAN
        return Span(self, name, attrs)

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _record_span(self, span: Span, t0: float, dur: float) -> None:
        if len(self._events) >= self.max_events:
            self.dropped_events += 1
            return
        self._events.append(
            {
                "name": span.name,
                "ts_us": (t0 - self._epoch) * 1e6,
                "dur_us": dur * 1e6,
                "tid": threading.get_ident(),
                "depth": span._depth,
                "args": span.attrs,
            }
        )

    def span_events(self) -> list[dict]:
        """The finished-span log (insertion order = completion order)."""
        return list(self._events)

    def stage_totals(self, prefix: str = "") -> dict[str, float]:
        """Total seconds per span name (optionally filtered by prefix) —
        what the benchmarks print as their per-stage breakdown tables.
        Nested spans are totalled under their own names, so a stage's
        number is its inclusive wall time.

        Example::

            reg.stage_totals("ingest/")    # {'ingest/count': 1.2, ...}
        """
        out: dict[str, float] = {}
        for e in self._events:
            if e["name"].startswith(prefix):
                out[e["name"]] = out.get(e["name"], 0.0) + e["dur_us"] / 1e6
        return out

    # ---------------------------------------------------------- snapshots
    def snapshot(self, *, include_events: bool = False) -> dict:
        """Picklable state of every metric (the cross-process wire format —
        serving workers publish these over the stats queue). Span events
        are omitted unless asked for: traces are a single-process artifact,
        metrics are what crosses process boundaries."""
        snap = {
            "counters": {n: c.state() for n, c in self._counters.items()},
            "gauges": {n: g.state() for n, g in self._gauges.items()},
            "histograms": {n: h.state() for n, h in self._histograms.items()},
            "dropped_events": self.dropped_events,
        }
        if include_events:
            snap["events"] = self.span_events()
            # the events' ts_us are relative to THIS registry's epoch; the
            # unix anchor lets an absorbing registry re-base them onto its
            # own timeline (cross-process trace merging)
            snap["epoch_unix"] = self.epoch_unix
        return snap

    def absorb(self, snapshot: dict, *, source: str | None = None) -> None:
        """Merge a snapshot's metrics into this registry (counters add,
        histograms merge bucket-wise) — the parent-side half of the
        worker-snapshot protocol. Span events, when the snapshot carries
        them (``snapshot(include_events=True)``), are re-based onto this
        registry's timeline via the snapshot's unix epoch anchor and
        appended — so one parent trace shows every worker's ingest spans.
        ``source`` tags absorbed events' args (e.g. the worker name)."""
        for name, v in snapshot.get("counters", {}).items():
            self.counter(name).inc(v)
        for name, v in snapshot.get("gauges", {}).items():
            self.gauge(name).set(v)
        for name, state in snapshot.get("histograms", {}).items():
            self.histogram(name).merge(Histogram.from_state(state))
        self.dropped_events += snapshot.get("dropped_events", 0)
        events = snapshot.get("events") or []
        if events:
            shift_us = (
                snapshot.get("epoch_unix", self.epoch_unix) - self.epoch_unix
            ) * 1e6
            for e in events:
                if len(self._events) >= self.max_events:
                    self.dropped_events += 1
                    continue
                e = dict(e, ts_us=e["ts_us"] + shift_us)
                if source is not None:
                    e["args"] = {**e.get("args", {}), "proc": source}
                self._events.append(e)

    # ------------------------------------------------------------ exports
    def chrome_trace(self) -> dict:
        from repro.obs.export import chrome_trace

        return chrome_trace(self)

    def write_trace(self, path: str) -> str:
        from repro.obs.export import write_trace

        return write_trace(self, path)

    def prometheus_text(self) -> str:
        from repro.obs.export import prometheus_text

        return prometheus_text(self.snapshot())


# ---------------------------------------------------------------------------
# the process-global default registry (disabled until configured)
# ---------------------------------------------------------------------------

_default = Registry(enabled=False)


def get_registry() -> Registry:
    """The process-global registry instrumented code records into. Starts
    disabled — every span/metric call is a no-op until :func:`configure`
    (or :func:`set_registry`) installs an enabled one."""
    return _default


def set_registry(reg: Registry) -> Registry:
    global _default
    _default = reg
    return reg


def configure(*, enabled: bool = True, max_events: int = 200_000) -> Registry:
    """Install (and return) a fresh global registry — how the drivers turn
    telemetry on for ``--trace-out`` / ``--metrics-interval``."""
    return set_registry(Registry(enabled=enabled, max_events=max_events))


@contextlib.contextmanager
def scoped(reg: Registry | None = None):
    """Temporarily install ``reg`` (default: a fresh enabled registry) as
    the global registry — how benchmarks and tests collect span timings
    without leaking state:

    Example::

        with scoped() as reg:
            run_instrumented_thing()
        reg.stage_totals("ingest/")
    """
    reg = reg or Registry(enabled=True)
    old = get_registry()
    set_registry(reg)
    try:
        yield reg
    finally:
        set_registry(old)
