"""Continuous-ingest streaming: micro-segment tailer with bounded
visibility lag (ISSUE 9).

The batch pipeline builds a store once; this package keeps one *current*:
a :class:`StreamIngestor` tails a document source, seals micro-segments on
a size-or-age trigger so every document is queryable within a configured
lag budget, and records its position in a manifest-resident
:class:`StreamCursor` advanced atomically with each segment commit
(exactly-once across crashes). The companion
:class:`~repro.store.compaction.CompactionDaemon` folds the resulting
micro-segment tail back down so read amplification stays bounded.

See docs/streaming.md for the lag contract and crash-resume guarantees.
"""

from repro.stream.cursor import CursorState, StreamCursor, StreamCursorConflict
from repro.stream.daemon import StreamConfig, StreamIngestor
from repro.stream.source import (
    FileTailSource,
    QueueSource,
    collection_to_feed,
    write_feed,
)

__all__ = [
    "CursorState",
    "StreamCursor",
    "StreamCursorConflict",
    "StreamConfig",
    "StreamIngestor",
    "FileTailSource",
    "QueueSource",
    "collection_to_feed",
    "write_feed",
]
