"""Document sources for the continuous-ingest streaming subsystem.

A source is anything the :class:`~repro.stream.daemon.StreamIngestor` can
tail: it hands out *complete* documents in arrival order, each paired with
the **offset** the stream cursor must record so a restarted daemon resumes
exactly after it. Two implementations cover the production and test
topologies:

* :class:`FileTailSource` — tails an append-only feed file (one document
  per line, whitespace-separated integer term IDs). Offsets are byte
  offsets, so they stay valid across process restarts; a partially written
  last line is never consumed (the tailer only advances past a ``\\n``),
  which makes concurrent ``write_feed`` appends safe without any locking.
* :class:`QueueSource` — an in-process deque for unit tests and embedded
  producers. Offsets are document ordinals; ``close()`` marks the end of
  the stream so a draining ingestor can tell "idle" from "done".

Both yield raw term-ID arrays; per-document preprocessing (dedup + sort,
the ``Collection`` invariant) happens in the ingestor so every source stays
a dumb byte/array mover.
"""

from __future__ import annotations

import collections
import os

import numpy as np


def write_feed(path: str, docs, *, append: bool = True) -> int:
    """Append documents (iterable of term-ID sequences) to a feed file in
    the one-line-per-document format :class:`FileTailSource` tails. Returns
    the file's end offset after the write. Each line is written atomically
    enough for a tailer (a single buffered write, flushed), and a document
    with no terms becomes an empty line — still a document."""
    mode = "a" if append else "w"
    with open(path, mode, encoding="ascii") as f:
        for terms in docs:
            f.write(" ".join(str(int(t)) for t in np.asarray(terms).ravel()))
            f.write("\n")
        f.flush()
        os.fsync(f.fileno())
        return f.tell()


def collection_to_feed(path: str, c, *, append: bool = False) -> int:
    """Write a whole :class:`~repro.data.corpus.Collection` as a feed file
    (document order preserved). The batch-vs-stream identity checks build
    their feeds with this."""
    return write_feed(
        path, (c.doc(d) for d in range(c.num_docs)), append=append
    )


class QueueSource:
    """In-process document source (tests, embedded producers).

    ``push`` enqueues one document; ``poll`` drains what has arrived.
    Offsets are running document ordinals — durable resume across processes
    is :class:`FileTailSource`'s job, but ``seek`` still replays the
    contract (it may only land on the current head, which catches a cursor
    that drifted from the source).
    """

    def __init__(self):
        self._docs: collections.deque = collections.deque()
        self._popped = 0  # ordinal of the next document to hand out
        self._closed = False

    def push(self, terms) -> None:
        if self._closed:
            raise RuntimeError("push() on a closed QueueSource")
        self._docs.append(np.asarray(terms))

    def push_collection(self, c) -> None:
        """Enqueue every document of a Collection, in document order."""
        for d in range(c.num_docs):
            self.push(c.doc(d))

    def close(self) -> None:
        """Mark the end of the stream: ``exhausted`` turns True once every
        pushed document has been polled."""
        self._closed = True

    @property
    def exhausted(self) -> bool:
        return self._closed and not self._docs

    def seek(self, offset: int) -> None:
        if offset != self._popped:
            raise ValueError(
                f"QueueSource cannot seek to {offset} (head is at "
                f"{self._popped}); in-memory sources do not survive restarts"
            )

    def poll(self, max_docs: int | None = None) -> list[tuple[int, np.ndarray]]:
        """Drain up to ``max_docs`` queued documents as
        ``(offset_after_doc, terms)`` pairs (possibly empty, never blocks)."""
        out = []
        while self._docs and (max_docs is None or len(out) < max_docs):
            terms = self._docs.popleft()
            self._popped += 1
            out.append((self._popped, terms))
        return out


class FileTailSource:
    """Tail an append-only feed file of one-line documents.

    Offsets are byte offsets into the file; ``poll`` parses every complete
    line between the current offset and EOF (bounded by
    ``max_bytes_per_poll`` per call) and leaves a trailing partial line —
    bytes after the last ``\\n`` — for the next poll, so a producer mid-
    ``write`` is never observed torn. A single document longer than
    ``max_bytes_per_poll`` grows the read window for that poll rather than
    stalling forever with no progress. A missing file is "no documents
    yet", not an error: the daemon may start before its producer.
    """

    def __init__(self, path: str, *, start_offset: int = 0,
                 max_bytes_per_poll: int = 4 << 20):
        self.path = path
        self._offset = int(start_offset)
        self.max_bytes_per_poll = int(max_bytes_per_poll)

    @property
    def offset(self) -> int:
        return self._offset

    @property
    def exhausted(self) -> bool:
        # a file feed has no in-band end marker; "done" is the ingestor's
        # idle timeout / max_docs call, not the source's
        return False

    def seek(self, offset: int) -> None:
        self._offset = int(offset)

    def poll(self, max_docs: int | None = None) -> list[tuple[int, np.ndarray]]:
        """Complete documents appended since the last poll, as
        ``(byte_offset_after_line, terms)``. Never blocks."""
        try:
            size = os.path.getsize(self.path)
        except OSError:
            return []
        if size <= self._offset:
            return []
        out = []
        with open(self.path, "rb") as f:
            f.seek(self._offset)
            read_size = self.max_bytes_per_poll
            chunk = f.read(read_size)
            # one document longer than the window must not stall the
            # tailer forever: a full chunk with no newline means the line
            # continues past it, so grow the window until the line's end
            # (or EOF — then it is a genuine partial still being written)
            while b"\n" not in chunk and len(chunk) == read_size:
                read_size *= 2
                f.seek(self._offset)
                chunk = f.read(read_size)
        consumed = 0
        while True:
            if max_docs is not None and len(out) >= max_docs:
                break
            nl = chunk.find(b"\n", consumed)
            if nl < 0:
                break  # trailing partial line: leave it for the next poll
            line = chunk[consumed:nl]
            consumed = nl + 1
            terms = (
                np.fromiter((int(t) for t in line.split()), dtype=np.int64)
                if line.strip() else np.zeros(0, dtype=np.int64)
            )
            out.append((self._offset + consumed, terms))
        self._offset += consumed
        return out
