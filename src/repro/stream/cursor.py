"""Durable stream cursor: exactly-once resume for the micro-segment tailer.

The cursor answers one question after a crash: *which prefix of the source
has already been committed as segments?* It is stored **inside the store
manifest** itself, under a top-level ``"stream"`` key::

    "stream": {"<source_id>": {"offset": 18734, "docs": 412, "seals": 7}}

and is only ever advanced through
:meth:`~repro.store.segments.Store.add_segment_from_rows`'s
``extra_mutate`` hook — i.e. inside the *same* flock'd, generation-
countered manifest commit that makes the sealed segment visible. Segment
append and cursor advance are therefore one atomic step: a SIGKILL either
lands before the commit (the pending segment directory is unreferenced
garbage, the cursor still points at the old offset, and the restarted
daemon re-reads and re-counts those documents) or after it (the segment is
live and the cursor has already moved past its documents). No document can
be double-committed or dropped — the same commit-under-lock discipline
:class:`repro.runtime.fault.SharedWorkTracker` uses for shard leases,
applied to the manifest the readers already watch.

Because ``Store._commit`` is a read-modify-write that preserves unrelated
manifest keys, the cursor survives compaction, transcoding and concurrent
batch appends untouched.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class CursorState:
    """Committed position of one stream source.

    ``offset`` is source-defined (byte offset for a file feed, document
    ordinal for a queue), ``docs`` counts documents committed so far and
    ``seals`` counts micro-segment commits — both feed freshness stats and
    the fencing check below.
    """

    offset: int = 0
    docs: int = 0
    seals: int = 0

    def as_dict(self) -> dict:
        return {"offset": int(self.offset), "docs": int(self.docs),
                "seals": int(self.seals)}


class StreamCursor:
    """Reader/mutator for one source's cursor in a store manifest."""

    def __init__(self, store, source_id: str):
        self.store = store
        self.source_id = str(source_id)

    def load(self) -> CursorState:
        """Committed state as of the latest manifest generation."""
        self.store.refresh()
        raw = self.store.manifest.get("stream", {}).get(self.source_id)
        if raw is None:
            return CursorState()
        return CursorState(offset=int(raw["offset"]), docs=int(raw["docs"]),
                           seals=int(raw.get("seals", 0)))

    def advance_mutation(self, prev: CursorState, new_offset: int,
                         docs_added: int):
        """Manifest mutation advancing ``prev`` → ``new_offset``.

        Pass the returned callable as ``extra_mutate`` to
        ``add_segment_from_rows(..., single_commit=True)``. It runs under
        the manifest lock and **fences**: if the on-disk cursor no longer
        matches ``prev`` (a second daemon committed for this source in the
        meantime), it raises and thereby aborts the whole commit before the
        segment becomes visible — the losing daemon's pending directory is
        left unreferenced, exactly as if it had crashed pre-commit.
        """
        source_id = self.source_id

        def mutate(m: dict) -> None:
            stream = m.setdefault("stream", {})
            on_disk = stream.get(source_id)
            disk_offset = int(on_disk["offset"]) if on_disk else 0
            disk_docs = int(on_disk["docs"]) if on_disk else 0
            disk_seals = int(on_disk.get("seals", 0)) if on_disk else 0
            if disk_offset != prev.offset:
                raise StreamCursorConflict(
                    f"stream cursor for {source_id!r} moved under us: "
                    f"expected offset {prev.offset}, manifest has "
                    f"{disk_offset} (another daemon is tailing this source?)"
                )
            stream[source_id] = CursorState(
                offset=int(new_offset),
                docs=disk_docs + int(docs_added),
                seals=disk_seals + 1,
            ).as_dict()

        return mutate


class StreamCursorConflict(RuntimeError):
    """Another writer advanced this source's cursor between our read and
    our commit; the seal was aborted and no segment was published."""
