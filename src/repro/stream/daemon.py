"""Micro-segment stream ingestor: bounded visibility lag over an LSM store.

:class:`StreamIngestor` tails a document source (see
:mod:`repro.stream.source`), buffers arriving documents in memory, and
**seals** the buffer into a micro-segment whenever either trigger fires:

* size — ``seal_docs`` documents are buffered, or
* age — the *oldest* buffered document has waited ``seal_age_ms``
  (defaults to half of ``max_visibility_lag_ms``, leaving the other half
  of the budget for the count + write + commit itself).

A seal is the exact batch pipeline in miniature: the buffered documents
become a :class:`~repro.data.corpus.Collection`, co-occurrences are
counted with a registered method into a budgeted
:class:`~repro.store.builder.SpillSink`, and the merged rows commit
through ``Store.add_segment_from_rows(..., single_commit=True)`` with the
stream cursor advanced in the **same** flock'd manifest commit (see
:mod:`repro.stream.cursor`). Counts are additive and exact for every
method, so where the micro-batch boundaries fall never changes the fully
compacted store — byte-for-byte — relative to a one-shot batch build;
streaming only changes *when* documents become queryable, and this daemon
bounds that.

Doc-to-queryable latency (arrival → commit visible) is recorded per
document into a mergeable ``stream/visibility_lag_s`` histogram;
``summary()`` reports its quantiles next to docs/seals throughput.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass

import numpy as np

import repro.obs as obs
from repro.core.cooc import count
from repro.store.builder import SpillSink
from repro.stream.cursor import CursorState, StreamCursor

# test hook: after this many seals the daemon parks forever (until killed),
# giving SIGKILL-mid-stream tests a deterministic crash point *between*
# commits — the cursor then proves exactly-once resume.
_STALL_ENV = "REPRO_TEST_STREAM_STALL_AFTER_SEALS"


@dataclass
class StreamConfig:
    """Tuning for one :class:`StreamIngestor`.

    ``max_visibility_lag_ms`` is the contract the daemon works to: every
    document should be queryable (committed to the manifest) within this
    long of arriving, provided a seal itself fits the budget. ``seal_docs``
    caps micro-segment size so a fast producer doesn't defer visibility
    behind one giant seal.
    """

    method: str = "list-scan"
    seal_docs: int = 2048
    max_visibility_lag_ms: float = 2_000.0
    seal_age_ms: float | None = None  # default: max_visibility_lag_ms / 2
    poll_interval_ms: float = 20.0
    memory_budget_pairs: int = 4 << 20
    max_docs: int | None = None       # stop after committing this many docs
    idle_timeout_s: float | None = None  # stop after this long with no input

    def __post_init__(self):
        if self.seal_docs < 1:
            raise ValueError("seal_docs must be >= 1")
        if self.max_visibility_lag_ms <= 0:
            raise ValueError("max_visibility_lag_ms must be > 0")
        if self.seal_age_ms is None:
            self.seal_age_ms = self.max_visibility_lag_ms / 2.0
        if self.seal_age_ms <= 0:
            raise ValueError("seal_age_ms must be > 0")


class StreamIngestor:
    """Tail ``source`` into ``store`` as micro-segments, resumably.

    ``run()`` drives the loop inline; ``start()``/``stop()`` wrap it in a
    daemon thread for embedding (e.g. ``cooc_serve --follow``). Restarting
    after any crash is safe: the constructor-loaded cursor says exactly
    which source prefix is already committed, and the fenced cursor
    mutation makes a duplicate commit impossible even with two daemons
    racing on one source.
    """

    def __init__(self, store, source, config: StreamConfig | None = None, *,
                 source_id: str, registry=None):
        self.store = store
        self.source = source
        self.config = config or StreamConfig()
        self.source_id = str(source_id)
        self.reg = registry if registry is not None else obs.get_registry()
        self.cursor = StreamCursor(store, self.source_id)
        self.lag_hist = obs.Histogram()     # doc arrival → queryable, seconds
        self.seal_hist = obs.Histogram()    # per-seal commit duration, seconds
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self._state = self.cursor.load()
        self._docs_run = 0                  # committed by *this* run
        self._seals_run = 0
        self._last_lags: list[float] = []   # lags of the most recent seal
        self.source.seek(self._state.offset)

    # ------------------------------------------------------------- lifecycle
    def start(self) -> "StreamIngestor":
        if self._thread is not None:
            raise RuntimeError("ingestor already started")
        self._thread = threading.Thread(
            target=self._run_guarded, name=f"stream-{self.source_id}",
            daemon=True,
        )
        self._thread.start()
        return self

    def _run_guarded(self) -> None:
        """Thread target: a failure (e.g. a StreamCursorConflict from a
        second daemon on the same source) must not vanish into a default
        thread traceback while the host keeps serving — it is recorded,
        flips ``healthy``, lands in ``summary()``, and re-raises from the
        next ``stop()``."""
        try:
            self.run()
        except BaseException as e:
            self._error = e
            self.reg.counter("stream/failures").inc(1)

    @property
    def healthy(self) -> bool:
        """False once a ``start()``-ed run has died on an exception."""
        return self._error is None

    @property
    def error(self) -> BaseException | None:
        return self._error

    def stop(self, timeout: float | None = 30.0, *,
             raise_on_error: bool = True) -> None:
        """Ask the loop to finish (it seals whatever is buffered first).
        If the threaded run died on an exception, re-raises it here —
        pass ``raise_on_error=False`` to inspect ``summary()`` /
        ``error`` instead."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if raise_on_error and self._error is not None:
            raise self._error

    # ------------------------------------------------------------- the loop
    def run(self) -> dict:
        cfg = self.config
        buf_terms: list[np.ndarray] = []
        buf_arrived: list[float] = []
        pending_offset = self._state.offset
        last_input = time.monotonic()
        with self.reg.span("stream/run", source=self.source_id):
            while True:
                # never buffer past one seal's worth: micro-segment size
                # stays deterministic even when the source has a backlog
                budget = cfg.seal_docs - len(buf_terms)
                if cfg.max_docs is not None:
                    budget = min(
                        budget,
                        cfg.max_docs - self._docs_run - len(buf_terms),
                    )
                polled = self.source.poll(budget) if budget > 0 else []
                now = time.monotonic()
                for off, terms in polled:
                    buf_terms.append(self._normalize(terms))
                    buf_arrived.append(now)
                    pending_offset = off
                if polled:
                    last_input = now
                done = (
                    self._stop.is_set()
                    or getattr(self.source, "exhausted", False)
                    or (cfg.max_docs is not None
                        and self._docs_run + len(buf_terms) >= cfg.max_docs)
                    or (cfg.idle_timeout_s is not None and not buf_terms
                        and now - last_input >= cfg.idle_timeout_s)
                )
                oldest_ms = (
                    (now - buf_arrived[0]) * 1e3 if buf_arrived else 0.0
                )
                if buf_terms and (
                    done or len(buf_terms) >= cfg.seal_docs
                    or oldest_ms >= cfg.seal_age_ms
                ):
                    self._seal(buf_terms, buf_arrived, pending_offset)
                    buf_terms, buf_arrived = [], []
                    self._maybe_stall()
                if done and not buf_terms:
                    break
                if not polled:
                    time.sleep(cfg.poll_interval_ms / 1e3)
        return self.summary()

    # ------------------------------------------------------------- internals
    def _normalize(self, terms) -> np.ndarray:
        """Apply the Collection invariant: sorted unique int32 term IDs in
        ``[0, vocab_size)``. Raises on out-of-range terms — a feed with a
        wrong vocabulary must fail loudly, not corrupt counts."""
        t = np.unique(np.asarray(terms, dtype=np.int64))
        if t.size and (t[0] < 0 or t[-1] >= self.store.vocab_size):
            raise ValueError(
                f"stream document has term IDs outside "
                f"[0, {self.store.vocab_size}): "
                f"min={int(t[0])} max={int(t[-1])}"
            )
        return t.astype(np.int32)

    def _seal(self, buf_terms, buf_arrived, new_offset: int) -> None:
        from repro.data.corpus import Collection

        cfg = self.config
        t0 = time.monotonic()
        ptr = np.zeros(len(buf_terms) + 1, dtype=np.int64)
        ptr[1:] = np.cumsum([t.size for t in buf_terms])
        terms = (
            np.concatenate(buf_terms) if buf_terms else
            np.zeros(0, dtype=np.int32)
        )
        c = Collection(ptr, terms, self.store.vocab_size)
        df = np.bincount(terms, minlength=self.store.vocab_size)
        with self.reg.span(
            "stream/seal", docs=c.num_docs, method=cfg.method,
            source=self.source_id,
        ) as sp:
            with SpillSink(
                self.store.vocab_size,
                memory_budget_pairs=cfg.memory_budget_pairs,
            ) as sink:
                count(cfg.method, c, sink)
                seg = self.store.add_segment_from_rows(
                    sink.merged_rows(),
                    df=df,
                    num_docs=c.num_docs,
                    source=f"stream:{self.source_id}",
                    single_commit=True,
                    extra_mutate=self.cursor.advance_mutation(
                        self._state, new_offset, c.num_docs
                    ),
                )
            sp.set(nnz=int(seg.nnz))
        t1 = time.monotonic()
        self._last_lags = [t1 - a for a in buf_arrived]
        for lag in self._last_lags:
            self.lag_hist.record(lag)
        self.seal_hist.record(t1 - t0)
        self._state = CursorState(
            offset=int(new_offset),
            docs=self._state.docs + c.num_docs,
            seals=self._state.seals + 1,
        )
        self._docs_run += c.num_docs
        self._seals_run += 1
        self.reg.counter("stream/docs").inc(c.num_docs)
        self.reg.counter("stream/seals").inc(1)
        self.reg.gauge("stream/cursor_offset").set(int(new_offset))

    def _maybe_stall(self) -> None:
        stall_after = int(os.environ.get(_STALL_ENV, "0") or "0")
        if stall_after and self._seals_run >= stall_after:
            while True:  # park until SIGKILLed by the test harness
                time.sleep(0.1)

    # ------------------------------------------------------------- reporting
    def summary(self) -> dict:
        """Cursor position plus visibility-lag and seal-cost quantiles (this
        process's seals only; cursor totals span all runs)."""
        out = {
            "source_id": self.source_id,
            "cursor": self._state.as_dict(),
            "docs_this_run": self._docs_run,
            "seals_this_run": self._seals_run,
            "max_visibility_lag_ms": self.config.max_visibility_lag_ms,
            "healthy": self.healthy,
        }
        if self._error is not None:
            out["error"] = f"{type(self._error).__name__}: {self._error}"
        if self.lag_hist.count:
            out["visibility_lag_ms"] = {
                "p50": self.lag_hist.percentile(50) * 1e3,
                "p99": self.lag_hist.percentile(99) * 1e3,
                "max": self.lag_hist.vmax * 1e3,
            }
        if self.seal_hist.count:
            out["seal_s"] = {
                "p50": self.seal_hist.percentile(50),
                "p99": self.seal_hist.percentile(99),
                "max": self.seal_hist.vmax,
            }
        return out
