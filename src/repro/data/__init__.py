"""Data substrate: corpora, preprocessing, indexes, host pipeline, samplers."""

from repro.data.corpus import Collection, synthetic_zipf_collection, collection_stats
from repro.data.index import InvertedIndex, build_inverted_index, incidence_dense, incidence_bitpacked
from repro.data.preprocess import preprocess_documents, remap_df_descending

__all__ = [
    "Collection",
    "synthetic_zipf_collection",
    "collection_stats",
    "InvertedIndex",
    "build_inverted_index",
    "incidence_dense",
    "incidence_bitpacked",
    "preprocess_documents",
    "remap_df_descending",
]
