"""Preprocessing — the step the paper calls "critical to good performance".

* ``preprocess_documents``: raw token-ID documents → deduplicated, sorted,
  densely re-numbered collection (the paper's §2 preprocessing).
* ``remap_df_descending``: beyond-paper — reassign term IDs by descending
  document frequency. The paper assigns IDs by first encounter; df-descending
  IDs concentrate the dense part of C = BᵀB in the top-left corner, which the
  FREQ-SPLIT hybrid (core/hybrid.py) exploits. Counting results are invariant
  to the renumbering (we keep the permutation to translate back).
"""

from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

from repro.data.corpus import Collection


def preprocess_documents(docs: Iterable[Sequence[int]], vocab_size: int | None = None) -> Collection:
    """Dedup + sort each document, build CSR. Token IDs must be >= 0."""
    uniq_docs = []
    max_id = -1
    for d in docs:
        arr = np.asarray(d, dtype=np.int64)
        if arr.size:
            u = np.unique(arr)
            max_id = max(max_id, int(u[-1]))
        else:
            u = arr
        uniq_docs.append(u.astype(np.int32))
    if vocab_size is None:
        vocab_size = max_id + 1
    ptr = np.zeros(len(uniq_docs) + 1, dtype=np.int64)
    ptr[1:] = np.cumsum([len(d) for d in uniq_docs])
    terms = (
        np.concatenate(uniq_docs).astype(np.int32)
        if uniq_docs
        else np.zeros(0, dtype=np.int32)
    )
    return Collection(ptr, terms, vocab_size)


def remap_df_descending(c: Collection) -> tuple[Collection, np.ndarray]:
    """Renumber term IDs by descending df (ties by old ID for determinism).

    Returns (new_collection, old_id_of_new_id) such that
    ``old_id_of_new_id[new_id] == old_id``.
    """
    df = np.bincount(c.terms, minlength=c.vocab_size)
    # stable sort on -df keeps old-ID order within ties
    order = np.argsort(-df, kind="stable").astype(np.int32)  # new_id -> old_id
    new_of_old = np.empty_like(order)
    new_of_old[order] = np.arange(c.vocab_size, dtype=np.int32)
    new_terms = new_of_old[c.terms]
    # re-sort within each document (renumbering breaks per-doc ascending order)
    out = np.empty_like(new_terms)
    for d in range(c.num_docs):
        lo, hi = c.doc_ptr[d], c.doc_ptr[d + 1]
        out[lo:hi] = np.sort(new_terms[lo:hi])
    return Collection(c.doc_ptr.copy(), out, c.vocab_size), order


def shard_documents(c: Collection, num_shards: int) -> list[Collection]:
    """Contiguous row-shards of B for distributed Gram accumulation.

    C = Σ_s B_sᵀ B_s — each shard's contribution is independent and additive,
    which is what makes the distributed accumulation fault-tolerant (a lost
    shard is simply recomputed and re-added; see runtime/fault.py).
    """
    bounds = np.linspace(0, c.num_docs, num_shards + 1).astype(np.int64)
    shards = []
    for s in range(num_shards):
        lo, hi = bounds[s], bounds[s + 1]
        plo, phi = c.doc_ptr[lo], c.doc_ptr[hi]
        ptr = (c.doc_ptr[lo:hi + 1] - plo).astype(np.int64)
        shards.append(Collection(ptr, c.terms[plo:phi].copy(), c.vocab_size))
    return shards
