"""Real neighbor sampler for GraphSAGE minibatch training.

CSR adjacency + uniform fixed-fanout sampling with replacement (the paper's
setting). Host-side numpy (the sampler is a data-pipeline stage; sampled
blocks are what ship to the device).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    indptr: np.ndarray   # int64[N+1]
    indices: np.ndarray  # int32[E]

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return int(self.indptr[-1])

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)


def csr_from_edge_index(edge_index: np.ndarray, num_nodes: int) -> CSRGraph:
    src, dst = edge_index
    order = np.argsort(dst, kind="stable")
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    counts = np.bincount(dst, minlength=num_nodes)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr, src[order].astype(np.int32))


def random_graph(num_nodes: int, avg_degree: int, seed: int = 0) -> CSRGraph:
    """Power-law-ish random graph for tests/benchmarks."""
    rng = np.random.default_rng(seed)
    n_edges = num_nodes * avg_degree
    # preferential-attachment-flavoured degree skew
    w = rng.zipf(1.5, size=num_nodes).astype(np.float64)
    w /= w.sum()
    src = rng.choice(num_nodes, size=n_edges, p=w).astype(np.int32)
    dst = rng.integers(0, num_nodes, size=n_edges).astype(np.int32)
    return csr_from_edge_index(np.stack([src, dst]), num_nodes)


def sample_neighbors(
    g: CSRGraph, seeds: np.ndarray, fanout: int, rng: np.random.Generator
) -> np.ndarray:
    """(len(seeds), fanout) uniform-with-replacement neighbor sample.
    Isolated nodes self-loop (standard GraphSAGE practice)."""
    lo = g.indptr[seeds]
    deg = g.indptr[seeds + 1] - lo
    r = rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(seeds), fanout))
    idx = lo[:, None] + r
    out = g.indices[np.minimum(idx, len(g.indices) - 1)]
    return np.where(deg[:, None] > 0, out, seeds[:, None].astype(np.int32))


def sample_blocks(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple,
    rng: np.random.Generator,
):
    """Multi-hop blocks: returns [seeds (B,), hop1 (B,f1), hop2 (B,f1,f2), ...]."""
    blocks = [seeds.astype(np.int32)]
    frontier = seeds.astype(np.int32)
    shape = (len(seeds),)
    for f in fanouts:
        nbrs = sample_neighbors(g, frontier.reshape(-1), f, rng)
        shape = shape + (f,)
        blocks.append(nbrs.reshape(shape))
        frontier = nbrs.reshape(-1)
    return blocks
