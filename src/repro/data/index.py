"""Indexes: the paper's two core structures.

* Forward index — the Collection itself (CSR doc → sorted unique term IDs).
* Inverted index — CSR term → ascending doc IDs (``build_inverted_index``).

Plus the TPU-side representations of the incidence matrix B ∈ {0,1}^{D×V}:

* ``incidence_dense``  — (D, V) 0/1 tile material for the MXU Gram kernel,
* ``incidence_bitpacked`` — (V, ceil(D/32)) uint32 bitmap material for the
  popcount intersection kernel (LIST-PAIRS adaptation).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.corpus import Collection


@dataclasses.dataclass(frozen=True)
class InvertedIndex:
    term_ptr: np.ndarray   # int64[V+1]
    docs: np.ndarray       # int32[nnz] — ascending doc IDs per term
    positions: np.ndarray  # int[nnz] — flat index into c.terms of each
                           # posting's occurrence (positions[k] locates the
                           # term of posting k inside its forward document);
                           # int32 whenever the posting count allows

    @property
    def vocab_size(self) -> int:
        return len(self.term_ptr) - 1

    def postings(self, t: int) -> np.ndarray:
        return self.docs[self.term_ptr[t]:self.term_ptr[t + 1]]

    def df(self) -> np.ndarray:
        return np.diff(self.term_ptr)


def build_inverted_index(c: Collection) -> InvertedIndex:
    """One pass over the forward index (the paper's "first pass").

    Stable counting-sort by term ID keeps doc IDs ascending inside each
    posting list (documents are visited in doc order). The sort permutation
    itself is kept as ``positions``: it maps each posting back to its flat
    offset in ``c.terms``, which is what lets LIST-SCAN gather the
    strict-upper suffix of every forward document without re-searching it.
    """
    df = np.bincount(c.terms, minlength=c.vocab_size).astype(np.int64)
    term_ptr = np.zeros(c.vocab_size + 1, dtype=np.int64)
    np.cumsum(df, out=term_ptr[1:])
    doc_ids = np.repeat(
        np.arange(c.num_docs, dtype=np.int32), np.diff(c.doc_ptr)
    )
    order = np.argsort(c.terms, kind="stable")
    positions = order.astype(np.int32) if len(c.terms) < 2**31 else order
    return InvertedIndex(term_ptr, doc_ids[order].astype(np.int32), positions)


def incidence_dense(
    c: Collection,
    doc_lo: int = 0,
    doc_hi: int | None = None,
    term_lo: int = 0,
    term_hi: int | None = None,
    dtype=np.float32,
) -> np.ndarray:
    """Materialize a (docs, terms) 0/1 tile of B. Host-side tile builder for
    streaming the Gram kernel; never materializes all of B for big corpora."""
    doc_hi = c.num_docs if doc_hi is None else doc_hi
    term_hi = c.vocab_size if term_hi is None else term_hi
    out = np.zeros((doc_hi - doc_lo, term_hi - term_lo), dtype=dtype)
    for i, d in enumerate(range(doc_lo, doc_hi)):
        ts = c.doc(d)
        ts = ts[(ts >= term_lo) & (ts < term_hi)]
        out[i, ts - term_lo] = 1
    return out


def incidence_bitpacked(
    c: Collection,
    term_lo: int = 0,
    term_hi: int | None = None,
) -> np.ndarray:
    """(terms, ceil(D/32)) uint32 bitmaps: bit d of word w = term appears in
    doc 32*w+d. 32 documents per word → 32× the HBM efficiency of a bf16
    incidence tile for pure intersection counting."""
    term_hi = c.vocab_size if term_hi is None else term_hi
    n_words = (c.num_docs + 31) // 32
    out = np.zeros((term_hi - term_lo, n_words), dtype=np.uint32)
    inv = build_inverted_index(c)
    for t in range(term_lo, term_hi):
        ds = inv.postings(t)
        np.bitwise_or.at(out[t - term_lo], ds // 32, (np.uint32(1) << (ds % 32).astype(np.uint32)))
    return out


def forward_padded(
    c: Collection, max_len: int | None = None, pad_id: int | None = None
) -> tuple[np.ndarray, np.ndarray]:
    """(D, L) padded forward docs + lengths — device-friendly forward index for
    the LIST-SCAN / MULTI-SCAN paths (pad_id defaults to vocab_size)."""
    lens = c.doc_lengths()
    L = int(lens.max()) if max_len is None else max_len
    pad = c.vocab_size if pad_id is None else pad_id
    out = np.full((c.num_docs, L), pad, dtype=np.int32)
    for d in range(c.num_docs):
        ts = c.doc(d)[:L]
        out[d, : len(ts)] = ts
    return out, lens.astype(np.int32)
