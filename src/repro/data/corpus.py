"""Document collections.

The paper's preprocessing (§2): per-document duplicate terms removed, term IDs
are 32-bit ordinals, per-document term IDs sorted ascending. A collection is
stored as a CSR forward index — ``doc_ptr`` + ``terms`` — which *is* the
paper's "forward documents" structure.

The synthetic generator draws Zipf-distributed terms so that the collection
reproduces the statistical shape of WT10G in Table 1 (heavy-tailed df, mean
document length ~230 unique terms, vocabulary growing sublinearly in D).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Collection:
    """A preprocessed document collection (CSR forward index).

    Invariants (enforced by ``preprocess_documents``):
      * per-document term IDs are strictly ascending (deduplicated + sorted),
      * term IDs are dense ordinals in ``[0, vocab_size)``.
    """

    doc_ptr: np.ndarray  # int64[D+1]
    terms: np.ndarray    # int32[nnz] — per-doc sorted unique term IDs
    vocab_size: int

    @property
    def num_docs(self) -> int:
        return len(self.doc_ptr) - 1

    @property
    def num_postings(self) -> int:
        return int(self.doc_ptr[-1])

    def doc(self, d: int) -> np.ndarray:
        return self.terms[self.doc_ptr[d]:self.doc_ptr[d + 1]]

    def doc_lengths(self) -> np.ndarray:
        return np.diff(self.doc_ptr)

    def head(self, n_docs: int) -> "Collection":
        """Prefix sub-collection — the paper emulates smaller collections by
        taking the first encountered documents (Table 1 columns)."""
        n_docs = min(n_docs, self.num_docs)
        ptr = self.doc_ptr[: n_docs + 1].copy()
        return Collection(ptr, self.terms[: ptr[-1]].copy(), self.vocab_size)


def _zipf_probs(vocab: int, s: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


def synthetic_zipf_collection(
    num_docs: int,
    *,
    vocab: int = 50_000,
    mean_len: float = 230.0,
    zipf_s: float = 1.07,
    min_len: int = 2,
    seed: int = 0,
) -> Collection:
    """Generate a Zipfian collection with WT10G-like shape (Table 1).

    Draw raw token counts per document from a lognormal (heavy upper tail like
    the paper's max-73.6K-term documents), then draw tokens i.i.d. Zipf and
    deduplicate — mirroring word-broken text with repetitions removed.
    """
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(vocab, zipf_s)
    # lognormal with heavy tail; clip to keep the quadratic pair blowup sane
    sigma = 0.9
    mu = np.log(mean_len) - 0.5 * sigma * sigma
    raw_lens = np.maximum(
        rng.lognormal(mean=mu, sigma=sigma, size=num_docs).astype(np.int64), min_len
    )

    docs = []
    # draw in chunks to bound memory
    for start in range(0, num_docs, 8192):
        stop = min(start + 8192, num_docs)
        lens = raw_lens[start:stop]
        flat = rng.choice(vocab, size=int(lens.sum()), p=probs)
        offs = np.concatenate([[0], np.cumsum(lens)])
        for i in range(len(lens)):
            uniq = np.unique(flat[offs[i]:offs[i + 1]])
            docs.append(uniq.astype(np.int32))

    ptr = np.zeros(num_docs + 1, dtype=np.int64)
    ptr[1:] = np.cumsum([len(d) for d in docs])
    terms = np.concatenate(docs) if docs else np.zeros(0, dtype=np.int32)
    return Collection(ptr, terms.astype(np.int32), vocab)


@dataclasses.dataclass(frozen=True)
class CollectionStats:
    """The collection statistics the planner's cost models consume (paper §3:
    asymptotics in documents, postings, df distribution, and vocabulary).

    ``df_rank_cum`` summarizes the df distribution compactly: entry k is the
    number of postings covered by the 2^k highest-df terms. That is all the
    FREQ-SPLIT cost model needs (head/tail postings split) without carrying
    the full df array around in a frozen plan.
    """

    num_docs: int
    num_postings: int
    vocab_size: int
    live_vocab: int          # terms with df > 0
    pair_occurrences: int    # Σ_d len_d·(len_d−1)/2
    max_doc_len: int
    df_rank_cum: tuple[int, ...]

    @property
    def avg_doc_len(self) -> float:
        return self.num_postings / self.num_docs if self.num_docs else 0.0

    @classmethod
    def from_collection(cls, c: "Collection") -> "CollectionStats":
        lens = c.doc_lengths()
        df = np.bincount(c.terms, minlength=c.vocab_size)
        df_desc = np.sort(df)[::-1]
        cum = np.cumsum(df_desc, dtype=np.int64)
        ranks = []
        r = 1
        while r < c.vocab_size:
            ranks.append(r)
            r *= 2
        ranks.append(c.vocab_size)
        return cls(
            num_docs=c.num_docs,
            num_postings=c.num_postings,
            vocab_size=c.vocab_size,
            live_vocab=int((df > 0).sum()),
            pair_occurrences=int(
                (lens.astype(np.int64) * (lens - 1) // 2).sum()
            ),
            max_doc_len=int(lens.max()) if len(lens) else 0,
            df_rank_cum=tuple(int(cum[r - 1]) for r in ranks),
        )

    def postings_in_top(self, h: int) -> int:
        """Postings covered by the ``h`` highest-df terms (log-interpolated
        from the rank samples)."""
        if h <= 0 or not self.df_rank_cum:
            return 0
        ranks = [min(1 << k, self.vocab_size) for k in range(len(self.df_rank_cum))]
        ranks[-1] = self.vocab_size
        if h >= self.vocab_size:
            return self.df_rank_cum[-1]
        for k in range(len(ranks)):
            if ranks[k] >= h:
                if ranks[k] == h or k == 0:
                    return self.df_rank_cum[k]
                lo_r, hi_r = ranks[k - 1], ranks[k]
                lo_c, hi_c = self.df_rank_cum[k - 1], self.df_rank_cum[k]
                frac = (h - lo_r) / (hi_r - lo_r)
                return int(lo_c + frac * (hi_c - lo_c))
        return self.df_rank_cum[-1]


def collection_stats(c: Collection) -> dict:
    """Table 1 statistics (exact pair count done by the core methods; here we
    report the closed-form per-document pair total = Σ len·(len−1)/2 which is
    the number of *pair occurrences*; distinct-pair counts come from the
    counting methods themselves)."""
    lens = c.doc_lengths()
    df = np.bincount(c.terms, minlength=c.vocab_size)
    return {
        "num_docs": c.num_docs,
        "avg_doc_len": float(lens.mean()) if len(lens) else 0.0,
        "min_doc_len": int(lens.min()) if len(lens) else 0,
        "max_doc_len": int(lens.max()) if len(lens) else 0,
        "std_doc_len": float(lens.std()) if len(lens) else 0.0,
        "num_postings": c.num_postings,
        "vocab_observed": int((df > 0).sum()),
        "pair_occurrences": int((lens.astype(np.int64) * (lens - 1) // 2).sum()),
    }
