"""Document collections.

The paper's preprocessing (§2): per-document duplicate terms removed, term IDs
are 32-bit ordinals, per-document term IDs sorted ascending. A collection is
stored as a CSR forward index — ``doc_ptr`` + ``terms`` — which *is* the
paper's "forward documents" structure.

The synthetic generator draws Zipf-distributed terms so that the collection
reproduces the statistical shape of WT10G in Table 1 (heavy-tailed df, mean
document length ~230 unique terms, vocabulary growing sublinearly in D).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Collection:
    """A preprocessed document collection (CSR forward index).

    Invariants (enforced by ``preprocess_documents``):
      * per-document term IDs are strictly ascending (deduplicated + sorted),
      * term IDs are dense ordinals in ``[0, vocab_size)``.
    """

    doc_ptr: np.ndarray  # int64[D+1]
    terms: np.ndarray    # int32[nnz] — per-doc sorted unique term IDs
    vocab_size: int

    @property
    def num_docs(self) -> int:
        return len(self.doc_ptr) - 1

    @property
    def num_postings(self) -> int:
        return int(self.doc_ptr[-1])

    def doc(self, d: int) -> np.ndarray:
        return self.terms[self.doc_ptr[d]:self.doc_ptr[d + 1]]

    def doc_lengths(self) -> np.ndarray:
        return np.diff(self.doc_ptr)

    def head(self, n_docs: int) -> "Collection":
        """Prefix sub-collection — the paper emulates smaller collections by
        taking the first encountered documents (Table 1 columns)."""
        n_docs = min(n_docs, self.num_docs)
        ptr = self.doc_ptr[: n_docs + 1].copy()
        return Collection(ptr, self.terms[: ptr[-1]].copy(), self.vocab_size)


def _zipf_probs(vocab: int, s: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


def synthetic_zipf_collection(
    num_docs: int,
    *,
    vocab: int = 50_000,
    mean_len: float = 230.0,
    zipf_s: float = 1.07,
    min_len: int = 2,
    seed: int = 0,
) -> Collection:
    """Generate a Zipfian collection with WT10G-like shape (Table 1).

    Draw raw token counts per document from a lognormal (heavy upper tail like
    the paper's max-73.6K-term documents), then draw tokens i.i.d. Zipf and
    deduplicate — mirroring word-broken text with repetitions removed.
    """
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(vocab, zipf_s)
    # lognormal with heavy tail; clip to keep the quadratic pair blowup sane
    sigma = 0.9
    mu = np.log(mean_len) - 0.5 * sigma * sigma
    raw_lens = np.maximum(
        rng.lognormal(mean=mu, sigma=sigma, size=num_docs).astype(np.int64), min_len
    )

    docs = []
    # draw in chunks to bound memory
    for start in range(0, num_docs, 8192):
        stop = min(start + 8192, num_docs)
        lens = raw_lens[start:stop]
        flat = rng.choice(vocab, size=int(lens.sum()), p=probs)
        offs = np.concatenate([[0], np.cumsum(lens)])
        for i in range(len(lens)):
            uniq = np.unique(flat[offs[i]:offs[i + 1]])
            docs.append(uniq.astype(np.int32))

    ptr = np.zeros(num_docs + 1, dtype=np.int64)
    ptr[1:] = np.cumsum([len(d) for d in docs])
    terms = np.concatenate(docs) if docs else np.zeros(0, dtype=np.int32)
    return Collection(ptr, terms.astype(np.int32), vocab)


def collection_stats(c: Collection) -> dict:
    """Table 1 statistics (exact pair count done by the core methods; here we
    report the closed-form per-document pair total = Σ len·(len−1)/2 which is
    the number of *pair occurrences*; distinct-pair counts come from the
    counting methods themselves)."""
    lens = c.doc_lengths()
    df = np.bincount(c.terms, minlength=c.vocab_size)
    return {
        "num_docs": c.num_docs,
        "avg_doc_len": float(lens.mean()) if len(lens) else 0.0,
        "min_doc_len": int(lens.min()) if len(lens) else 0,
        "max_doc_len": int(lens.max()) if len(lens) else 0,
        "std_doc_len": float(lens.std()) if len(lens) else 0.0,
        "num_postings": c.num_postings,
        "vocab_observed": int((df > 0).sum()),
        "pair_occurrences": int((lens.astype(np.int64) * (lens - 1) // 2).sum()),
    }
