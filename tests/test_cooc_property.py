"""Hypothesis property tests: system invariants over random corpora.

Strategy generates raw documents WITH duplicates and unsorted tokens so the
preprocessing path (dedup + sort, paper §2) is exercised too.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cooc import dense_counts
from repro.core.oracle import brute_force_counts
from repro.core.types import DenseSink
from repro.core.hybrid import count_freq_split
from repro.data.preprocess import preprocess_documents, remap_df_descending, shard_documents

VOCAB = 40

documents = st.lists(
    st.lists(st.integers(0, VOCAB - 1), min_size=0, max_size=25),
    min_size=1,
    max_size=30,
)


@st.composite
def corpora(draw):
    docs = draw(documents)
    return preprocess_documents(docs, vocab_size=VOCAB)


@settings(max_examples=40, deadline=None)
@given(corpora())
def test_all_methods_agree_with_oracle(c):
    oracle = brute_force_counts(c)
    for method in ["naive", "list-pairs", "list-blocks", "list-scan", "multi-scan"]:
        got = dense_counts(method, c)
        assert np.array_equal(got, oracle), method


@settings(max_examples=25, deadline=None)
@given(corpora())
def test_tpu_adaptations_agree_with_oracle(c):
    oracle = brute_force_counts(c)
    for method in [
        "list-pairs-bitpacked",
        "list-blocks-gram",
        "list-scan-segment",
        "multi-scan-matmul",
    ]:
        got = dense_counts(method, c, use_kernel=False)
        assert np.array_equal(got, oracle), method


@settings(max_examples=25, deadline=None)
@given(corpora(), st.integers(0, VOCAB))
def test_freq_split_any_head(c, head):
    cd, _ = remap_df_descending(c)
    sink = DenseSink(cd.vocab_size)
    count_freq_split(cd, sink, head=head, use_kernel=False)
    assert np.array_equal(sink.mat, brute_force_counts(cd))


@settings(max_examples=30, deadline=None)
@given(corpora())
def test_count_invariants(c):
    oracle = brute_force_counts(c)
    df = np.bincount(c.terms, minlength=VOCAB)
    # strict upper triangle only
    assert np.array_equal(oracle, np.triu(oracle, k=1))
    # bounded by min df
    i, j = np.nonzero(oracle)
    assert np.all(oracle[i, j] <= np.minimum(df[i], df[j]))
    # total pair mass == sum over docs of len*(len-1)/2
    lens = c.doc_lengths().astype(np.int64)
    assert oracle.sum() == int((lens * (lens - 1) // 2).sum())


@settings(max_examples=25, deadline=None)
@given(corpora(), st.integers(1, 5))
def test_shard_additivity(c, n_shards):
    """C = Σ_s B_sᵀ B_s — the property that makes the distributed (and
    fault-tolerant) accumulation correct."""
    total = brute_force_counts(c)
    acc = np.zeros_like(total)
    for s in shard_documents(c, n_shards):
        acc += brute_force_counts(s)
    assert np.array_equal(acc, total)


@settings(max_examples=25, deadline=None)
@given(corpora())
def test_renumbering_invariance(c):
    """Counts are permutation-equivariant under term renumbering."""
    cd, old_of_new = remap_df_descending(c)
    a = brute_force_counts(c)
    b = brute_force_counts(cd)
    # map b back through the permutation: b[i,j] counts pair (old i, old j)
    V = c.vocab_size
    back = np.zeros_like(a)
    i, j = np.nonzero(b)
    oi, oj = old_of_new[i], old_of_new[j]
    lo, hi = np.minimum(oi, oj), np.maximum(oi, oj)
    np.add.at(back, (lo, hi), b[i, j])
    assert np.array_equal(back, a)


upper_csr_segments = st.integers(1, 24).flatmap(
    lambda V: st.tuples(
        st.just(V),
        st.lists(  # strict-upper pairs (i < j) with positive counts
            st.tuples(
                st.integers(0, max(V - 2, 0)),
                st.integers(1, max(V - 1, 1)),
                st.integers(1, 500),
            ),
            min_size=0,
            max_size=80,
        ),
        st.integers(1, 40),  # sym build chunk size, in pairs
    )
)


@settings(max_examples=40, deadline=None)
@given(upper_csr_segments)
def test_symmetric_build_matches_lexsort_reference(case):
    """The streamed two-pass symmetric-adjacency build is byte-identical to
    the old in-memory doubled-COO + lexsort build on random upper-CSR
    segments — empty rows, empty segments, and single-row segments
    included — at any chunk size."""
    import os
    import tempfile

    from conftest import lexsort_sym_reference
    from repro.store.csr_store import write_segment

    V, raw_pairs, chunk = case
    dense = np.zeros((V, V), dtype=np.int64)
    for i, j, cnt in raw_pairs:
        if i < j < V:
            dense[i, j] += cnt
    rows = [
        (i, np.nonzero(dense[i])[0], dense[i][np.nonzero(dense[i])[0]])
        for i in range(V)
        if dense[i].any()
    ]
    seg_dir = os.path.join(tempfile.mkdtemp(prefix="sym_prop_"), "seg")
    write_segment(seg_dir, iter(rows), V, sym_chunk_pairs=chunk)
    row_ptr = np.fromfile(os.path.join(seg_dir, "row_ptr.bin"), dtype=np.int64)
    cols = np.fromfile(os.path.join(seg_dir, "cols.bin"), dtype=np.int32)
    counts = np.fromfile(os.path.join(seg_dir, "counts.bin"), dtype=np.int64)
    want_ptr, want_cols, want_counts = lexsort_sym_reference(
        row_ptr, cols, counts, V
    )
    got_ptr = np.fromfile(
        os.path.join(seg_dir, "sym_row_ptr.bin"), dtype=np.int64
    )
    got_cols = np.fromfile(os.path.join(seg_dir, "sym_cols.bin"), dtype=np.int32)
    got_counts = np.fromfile(
        os.path.join(seg_dir, "sym_counts.bin"), dtype=np.int64
    )
    assert np.array_equal(got_ptr, want_ptr)
    assert np.array_equal(got_cols, want_cols)
    assert np.array_equal(got_counts, want_counts)


@settings(max_examples=30, deadline=None)
@given(corpora(), st.integers(1, 12))
def test_vectorized_list_scan_property(c, rows_per_batch):
    """Batched-histogram LIST-SCAN == per-doc-loop baseline on random
    corpora at random batch sizes (dense and sparse accumulation regimes)."""
    from repro.core.list_scan import count_list_scan, count_list_scan_loop

    a, b = DenseSink(c.vocab_size), DenseSink(c.vocab_size)
    count_list_scan(c, a, rows_per_batch=rows_per_batch)
    count_list_scan_loop(c, b)
    assert np.array_equal(a.mat, b.mat)
