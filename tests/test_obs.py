"""The telemetry subsystem (src/repro/obs/): metric primitives, span
nesting, exporters, and the instrumented pipelines.

The load-bearing assertions:

* histogram **merge correctness** — percentiles of N merged per-worker
  histograms agree with percentiles of the pooled samples to within one log
  bucket's relative width (the property that makes fleet-level p99 honest);
* span **nesting and attribute propagation** across a PlanExecutor
  crash-and-resume (the resumed run re-counts only the un-checkpointed
  shards, and its spans say so);
* the executor's stage spans **tile** the root ``ingest/execute`` span
  (count + segment_write + refresh cover >= 90% of the root's wall time on
  a store-output run — the ISSUE 6 acceptance criterion);
* the disabled path records nothing and hands out shared null objects.
"""

import dataclasses
import json
import math

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import (
    SUBDIV,
    _MIN_IDX,
    Histogram,
    bucket_index,
    bucket_mid,
    merge_snapshots,
)

# one log bucket's relative width (the merge-percentile error bound), with
# a little headroom for numpy's interpolating percentile definition
BUCKET_FACTOR = 2.0 ** (1.5 / SUBDIV)


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------


def test_bucket_index_clamps_and_orders():
    assert bucket_index(0.0) == _MIN_IDX
    assert bucket_index(-1.0) == _MIN_IDX
    assert bucket_index(1e-300) == _MIN_IDX
    assert bucket_index(1e300) == bucket_index(1e299)  # clamped at the top
    # monotone in value, and the midpoint lands inside the bucket
    for v in (1e-6, 0.001, 0.5, 1.0, 7.0, 1234.5):
        i = bucket_index(v)
        assert bucket_index(v * 4) > i
        assert 2 ** (i / SUBDIV) <= bucket_mid(i) <= 2 ** ((i + 1) / SUBDIV)


def test_counter_and_gauge_state():
    reg = obs.Registry(enabled=True)
    reg.counter("a.b").inc()
    reg.counter("a.b").inc(41)
    reg.gauge("g").set(0.25)
    snap = reg.snapshot()
    assert snap["counters"]["a.b"] == 42
    assert snap["gauges"]["g"] == 0.25


def test_histogram_merge_matches_pooled_percentiles():
    """Percentiles of merged per-worker histograms == percentiles of the
    pooled samples, to within one bucket's relative width — the property
    the serving parent relies on when it turns worker snapshots into fleet
    p50/p95/p99."""
    rng = np.random.default_rng(7)
    # three "workers" with deliberately different latency regimes
    worker_samples = [
        rng.lognormal(mean=-6.0, sigma=0.5, size=1500),   # fast worker
        rng.lognormal(mean=-4.5, sigma=0.8, size=1000),   # slow worker
        rng.lognormal(mean=-5.5, sigma=1.2, size=500),    # noisy worker
    ]
    hists = []
    for samples in worker_samples:
        h = Histogram()
        for v in samples:
            h.record(float(v))
        hists.append(h)

    merged = Histogram()
    for h in hists:
        merged.merge(h)
    pooled = np.concatenate(worker_samples)
    assert merged.count == len(pooled)
    assert merged.total == pytest.approx(pooled.sum())
    assert merged.vmin == pooled.min() and merged.vmax == pooled.max()
    for q in (10, 50, 90, 95, 99):
        got = merged.percentile(q)
        want = float(np.percentile(pooled, q))
        assert want / BUCKET_FACTOR <= got <= want * BUCKET_FACTOR, (
            f"p{q}: merged {got} vs pooled {want}"
        )
    # merging must be equivalent to recording everything in one histogram
    one = Histogram()
    for v in pooled:
        one.record(float(v))
    assert one.buckets == merged.buckets
    assert one.percentile(99) == merged.percentile(99)


def test_histogram_percentile_clamps_to_observed_range():
    h = Histogram()
    h.record(0.003)
    # a single sample: every quantile is that sample, not a bucket midpoint
    assert h.percentile(50) == 0.003
    assert h.percentile(99) == 0.003
    assert Histogram().percentile(99) == 0.0  # empty -> 0, not NaN


def test_histogram_state_survives_json_roundtrip():
    h = Histogram()
    for v in (0.001, 0.004, 0.002, 1.5):
        h.record(v)
    back = Histogram.from_state(json.loads(json.dumps(h.state())))
    assert back.count == h.count
    assert back.buckets == h.buckets  # keys re-int'ed after stringification
    assert back.percentile(95) == h.percentile(95)
    assert back.mean == h.mean


def test_merge_snapshots_counters_add_histograms_merge():
    a, b = obs.Registry(enabled=True), obs.Registry(enabled=True)
    a.counter("n").inc(3)
    b.counter("n").inc(4)
    b.counter("only_b").inc(1)
    a.gauge("g").set(1.0)
    b.gauge("g").set(2.0)
    for v in (0.001, 0.002):
        a.histogram("lat").record(v)
    b.histogram("lat").record(0.004)
    merged = merge_snapshots([a.snapshot(), None, b.snapshot()])
    assert merged["counters"] == {"n": 7, "only_b": 1}
    assert merged["gauges"]["g"] == 2.0  # last write wins
    h = Histogram.from_state(merged["histograms"]["lat"])
    assert h.count == 3
    assert h.vmax == 0.004


# ---------------------------------------------------------------------------
# registry + spans
# ---------------------------------------------------------------------------


def test_disabled_registry_is_nullobject_noop():
    reg = obs.Registry(enabled=False)
    assert reg.span("x") is obs.NULL_SPAN
    assert reg.counter("c") is obs.NULL_METRIC
    assert reg.gauge("g") is obs.NULL_METRIC
    assert reg.histogram("h") is obs.NULL_METRIC
    with reg.span("x", a=1) as sp:
        sp.set(b=2)
        reg.counter("c").inc(5)
        reg.histogram("h").record(0.1)
    assert reg.span_events() == []
    assert reg.snapshot() == {
        "counters": {}, "gauges": {}, "histograms": {}, "dropped_events": 0,
    }


def test_module_default_registry_starts_disabled():
    # the process-global default must be off (BENCH overhead contract);
    # tests that enable it go through obs.scoped() which restores the old one
    assert obs.get_registry().enabled is False


def test_span_nesting_depth_and_attrs():
    reg = obs.Registry(enabled=True)
    with reg.span("a", k=1):
        with reg.span("a/b") as sp:
            sp.set(rows=7)
        with reg.span("a/c"):
            pass
    events = reg.span_events()  # completion order: a/b, a/c, a
    assert [e["name"] for e in events] == ["a/b", "a/c", "a"]
    assert [e["depth"] for e in events] == [1, 1, 0]
    assert events[0]["args"] == {"rows": 7}
    assert events[2]["args"] == {"k": 1}
    root = events[2]
    for child in events[:2]:  # children nest inside the root's interval
        assert child["ts_us"] >= root["ts_us"]
        assert child["ts_us"] + child["dur_us"] <= (
            root["ts_us"] + root["dur_us"] + 1.0
        )


def test_span_event_cap_counts_drops():
    reg = obs.Registry(enabled=True, max_events=2)
    for _ in range(5):
        with reg.span("s"):
            pass
    assert len(reg.span_events()) == 2
    assert reg.dropped_events == 3
    assert reg.snapshot()["dropped_events"] == 3


def test_scoped_installs_and_restores():
    before = obs.get_registry()
    with obs.scoped() as reg:
        assert obs.get_registry() is reg
        assert reg.enabled
    assert obs.get_registry() is before


def test_stage_totals_sums_by_name():
    reg = obs.Registry(enabled=True)
    for _ in range(3):
        with reg.span("ingest/count"):
            pass
    with reg.span("query/execute"):
        pass
    totals = reg.stage_totals("ingest/")
    assert set(totals) == {"ingest/count"}
    assert totals["ingest/count"] > 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_chrome_trace_roundtrip(tmp_path):
    reg = obs.Registry(enabled=True)
    with reg.span("ingest/count", shard=0):
        reg.counter("ingest.docs_counted").inc(10)
    path = str(tmp_path / "trace.json")
    assert reg.write_trace(path) == path
    trace = obs.load_trace(path)
    assert {e["ph"] for e in trace["traceEvents"]} <= {"X", "M"}
    assert obs.span_names(trace) == {"ingest/count"}
    x = [e for e in trace["traceEvents"] if e["ph"] == "X"][0]
    assert x["cat"] == "ingest"
    assert x["args"]["shard"] == 0 and x["args"]["depth"] == 0
    meta = [e for e in trace["traceEvents"] if e["ph"] == "M"][0]
    assert meta["args"]["counters"] == {"ingest.docs_counted": 10}


def test_load_trace_rejects_non_trace(tmp_path):
    p = tmp_path / "not_a_trace.json"
    p.write_text('{"hello": 1}')
    with pytest.raises(ValueError, match="traceEvents"):
        obs.load_trace(str(p))


def test_prometheus_text_format():
    reg = obs.Registry(enabled=True)
    reg.counter("ingest.spills").inc(3)
    reg.gauge("serving/batch_window_occupancy").set(0.5)
    for v in (0.001, 0.002, 0.004):
        reg.histogram("serving/queue_wait_s").record(v)
    text = reg.prometheus_text()
    assert "# TYPE repro_ingest_spills counter" in text
    assert "repro_ingest_spills 3" in text
    assert "repro_serving_batch_window_occupancy 0.5" in text
    assert 'repro_serving_queue_wait_s{quantile="0.99"}' in text
    assert "repro_serving_queue_wait_s_count 3" in text
    # names must be exposition-safe: no dots or slashes survive
    for line in text.splitlines():
        if line and not line.startswith("#"):
            assert "/" not in line.split(" ")[0]
            assert "." not in line.split("{")[0].split(" ")[0]


# ---------------------------------------------------------------------------
# instrumented pipelines
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def coll():
    from repro.data.corpus import synthetic_zipf_collection

    return synthetic_zipf_collection(120, vocab=200, mean_len=14, seed=11)


def test_executor_stage_spans_tile_root(tmp_path, coll):
    """A store-output spill run emits all five stage spans, and the
    top-level stages (count + segment_write + refresh) account for >= 90%
    of the root ``ingest/execute`` wall time (the acceptance criterion —
    `cooc_run --trace-out` checks the same property end-to-end)."""
    from repro.core.plan import CountJob, Planner

    job = CountJob(
        collection=coll, output="store", method="list-scan",
        out_path=str(tmp_path / "store"), dense_vocab_cap=1,
        num_shards=3, memory_budget_pairs=256,
    )
    with obs.scoped() as reg:
        res = Planner().plan(job).execute(out_dir=str(tmp_path / "run"))
    assert res.summary["exact"] is True
    totals = reg.stage_totals("ingest/")
    assert {
        "ingest/execute", "ingest/count", "ingest/spill",
        "ingest/bucket_merge", "ingest/segment_write", "ingest/refresh",
    } <= set(totals)
    covered = (
        totals["ingest/count"]
        + totals["ingest/segment_write"]
        + totals["ingest/refresh"]
    )
    assert covered >= 0.9 * totals["ingest/execute"], totals
    # counters rode along with the spans
    snap = reg.snapshot()
    assert snap["counters"]["ingest.shards_done"] == 3
    assert snap["counters"]["ingest.docs_counted"] == coll.num_docs
    assert snap["counters"]["ingest.rows_written"] > 0
    assert snap["counters"]["ingest.spills"] >= 3  # budget forced spills


def test_executor_span_attrs_across_resume(tmp_path, coll, monkeypatch):
    """Crash after the first checkpoint, resume, and read the story off the
    span log: the first run counted only some shards, the resumed run's
    root span says resume=True and its count spans cover exactly the shards
    the checkpoint didn't."""
    from repro.core.plan import CountJob, Planner
    from repro.core.specs import REGISTRY

    job = CountJob(
        collection=coll, output="stats", method="list-scan",
        dense_vocab_cap=1, num_shards=6, memory_budget_pairs=128,
    )
    plan = Planner().plan(job)
    out = str(tmp_path / "run")

    real = REGISTRY["list-scan"]
    calls = {"n": 0}

    def failing(c, sink, **kw):
        calls["n"] += 1
        if calls["n"] > 3:
            raise RuntimeError("injected crash")
        return real.fn(c, sink, **kw)

    monkeypatch.setitem(REGISTRY, "list-scan", dataclasses.replace(real, fn=failing))
    with obs.scoped() as reg1:
        with pytest.raises(RuntimeError, match="injected crash"):
            plan.execute(out_dir=out, ckpt_every=2)
    counted1 = {
        e["args"]["shard"]
        for e in reg1.span_events()
        if e["name"] == "ingest/count" and "shard" in e["args"]
    }
    monkeypatch.setitem(REGISTRY, "list-scan", real)

    with obs.scoped() as reg2:
        res = plan.execute(out_dir=out, ckpt_every=2, resume=True)
    from repro.core.oracle import brute_force_counts

    oracle = brute_force_counts(coll)
    assert res.summary["total_count"] == int(oracle.sum())

    events2 = reg2.span_events()
    root = [e for e in events2 if e["name"] == "ingest/execute"]
    assert len(root) == 1
    assert root[0]["args"]["resume"] is True
    assert root[0]["args"]["shards"] == 6
    counted2 = {
        e["args"]["shard"]
        for e in events2
        if e["name"] == "ingest/count" and "shard" in e["args"]
    }
    # the checkpoint held 2 completed shards; the resumed run counts the
    # other 4 (including the shard the injected crash interrupted)
    assert len(counted2) == 4
    assert counted2 | counted1 == set(range(6))
    assert reg2.snapshot()["counters"]["ingest.shards_done"] == 4
    # every count span carries its method + doc attribution
    for e in events2:
        if e["name"] == "ingest/count":
            assert e["args"]["method"] == "list-scan"
            assert e["args"]["docs"] > 0


def test_query_engine_spans_and_cache_counters(tmp_path, coll):
    from repro.core.cooc import count_to_store
    from repro.store import QueryEngine, TopKRequest

    store, _ = count_to_store(
        "list-scan", coll, str(tmp_path / "store"), memory_budget_pairs=512
    )
    with obs.scoped() as reg:
        engine = QueryEngine(store)
        terms = np.arange(8)
        engine.execute([TopKRequest(terms, k=5, score="count")])
        engine.execute([TopKRequest(terms, k=5, score="count")])  # cache hits
    events = [e for e in reg.span_events() if e["name"] == "query/execute"]
    assert len(events) == 2
    assert all(e["args"]["requests"] == 1 for e in events)
    snap = reg.snapshot()
    assert snap["counters"]["query.requests"] == 2
    assert snap["counters"]["query.topk_queries"] == 16
    assert snap["counters"]["query.cache_misses"] >= 8
    assert snap["counters"]["query.cache_hits"] >= 8  # second pass was warm


def test_query_engine_private_registry_overrides_global():
    # serving workers hand the engine their own registry; the global one
    # (disabled here) must not see anything
    private = obs.Registry(enabled=True)

    class _Fake:
        pass

    from repro.store.query import QueryEngine

    engine = QueryEngine.__new__(QueryEngine)
    engine._registry = private
    assert engine.registry is private
    engine._registry = None
    assert engine.registry is obs.get_registry()
