"""Fault-tolerant serving: worker supervision (typed ``WorkerDied`` with no
60s hangs, respawn with the queue intact), admission control
(``ServerOverloaded`` shedding, deadline propagation), client retries, and
the shutdown/sentinel regressions — all driven through the env-gated
failpoints of ``repro.runtime.faultinject``."""

import os
import pickle
import signal
import threading
import time

import numpy as np
import pytest

from repro.core.cooc import count_to_store
from repro.data.corpus import synthetic_zipf_collection
from repro.runtime import faultinject
from repro.store import (
    CoocServer,
    ServerOverloaded,
    TopKRequest,
    WorkerDied,
)
from repro.store.requests import envelope_times, make_envelope
from repro.store.serving import _STOP, _is_stop, backoff_delay


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(150, vocab=128, mean_len=12, seed=7)


@pytest.fixture(scope="module")
def store_path(coll, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("resilience") / "store")
    count_to_store("list-scan", coll, path)
    return path


@pytest.fixture
def faults(monkeypatch):
    """Arm a REPRO_FAULTS schedule for the workers this test spawns; the
    monkeypatch teardown disarms it before the next test."""

    def arm(spec: str) -> None:
        monkeypatch.setenv(faultinject.ENV_VAR, spec)

    return arm


# --------------------------------------------------------------- failpoints
def test_fault_registry_parsing_and_scoping():
    fr = faultinject.FaultRegistry(
        "kill-worker=1:3; stall-queue=*:0.5:2 ;drop-response=4"
    )
    assert fr and fr.active("kill-worker")
    assert not fr.active("nope")
    # worker scope: armed for wid 1 only
    assert not fr.kill_worker(worker=0, batches_done=99)
    assert not fr.kill_worker(worker=1, batches_done=2)
    assert fr.kill_worker(worker=1, batches_done=3)
    # * scope + stall budget of 2
    assert fr.stall_queue(worker=7) == 0.5
    assert fr.stall_queue(worker=7) == 0.5
    assert fr.stall_queue(worker=7) == 0.0
    # unscoped drop budget of 4, per-worker hit counters
    assert sum(fr.drop_response(worker=0) for _ in range(10)) == 4
    assert sum(fr.drop_response(worker=1) for _ in range(10)) == 4


def test_fault_registry_drop_skip():
    fr = faultinject.FaultRegistry("drop-response=0:2:1")
    # skip 1, drop 2, pass the rest
    assert [fr.drop_response(worker=0) for _ in range(5)] == [
        False, True, True, False, False,
    ]


def test_fault_registry_disarmed(monkeypatch):
    monkeypatch.delenv(faultinject.ENV_VAR, raising=False)
    fr = faultinject.from_env()
    assert not fr
    assert not fr.active("kill-worker")
    assert fr.stall_queue(worker=0) == 0.0


def test_backoff_delay_jitter_bounds():
    for attempt in range(6):
        lo = backoff_delay(attempt, base_ms=40, rng=lambda: 0.0)
        hi = backoff_delay(attempt, base_ms=40, rng=lambda: 1.0)
        assert lo == pytest.approx(0.5 * hi)
        assert hi == pytest.approx(min(40 * 2 ** attempt, 2000) / 1e3)
    # the cap keeps a long retry storm bounded
    assert backoff_delay(30, base_ms=40, rng=lambda: 1.0) == 2.0


# ------------------------------------------------------------ wire envelope
def test_envelope_deadline_roundtrip():
    env = make_envelope(3, 7, 0, 2, TopKRequest([1]), t_submit=5.0, deadline=9.0)
    assert envelope_times(env) == (5.0, 9.0)
    # legacy short envelopes: no deadline, no submit stamp
    assert envelope_times((3, 7, 0, 2, TopKRequest([1]))) == (None, None)


# ------------------------------------------------------- sentinel satellite
def test_stop_sentinel_is_not_none_and_survives_pickle():
    """mp queues pickle items: the sentinel must be detectable after a
    round-trip, and a stray ``None`` (the old sentinel) must not stop a
    worker."""
    assert not _is_stop(None)
    assert _is_stop(_STOP)
    assert _is_stop(pickle.loads(pickle.dumps(_STOP)))


def test_stray_none_on_queue_does_not_stop_worker(store_path):
    with CoocServer(store_path, workers=1, batch_window_ms=0.5) as server:
        client = server.client()
        ids, _ = client.topk([3], k=4)
        # the respawn-race artefact: a bare None lands on the request queue
        server._request_qs[0].put(None)
        time.sleep(0.2)
        ids2, _ = client.topk([3], k=4, timeout=15.0)  # worker still alive
        np.testing.assert_array_equal(ids, ids2)
    assert server.stats()["workers_lost"] == 0


# ---------------------------------------------------------- worker death
def test_worker_died_mid_execute_is_typed_and_fast(store_path, faults):
    """A SIGKILL'd worker's in-flight request fails back as WorkerDied in
    supervisor time, not at the 60s client timeout."""
    faults("kill-worker=0")  # die at the first claimed batch
    with CoocServer(store_path, workers=1, batch_window_ms=0.5,
                    max_respawns=0) as server:
        client = server.client()
        t0 = time.monotonic()
        with pytest.raises(WorkerDied):
            client.topk([3], k=4)  # default timeout=60: must not be reached
        elapsed = time.monotonic() - t0
        assert elapsed < 20, f"WorkerDied took {elapsed:.1f}s (hang?)"
        time.sleep(0.2)  # let the supervisor finish marking the slot dead
        # respawn budget 0: the fleet is gone, submits fail fast and typed
        with pytest.raises(WorkerDied):
            client.topk([3], k=4)
    assert server.stats()["resilience"]["worker_died_failures"] >= 1


def test_worker_died_respawn_and_retry_succeed(store_path, faults):
    """kill-worker fires on every incarnation of worker 0, so the slot dies
    after every other batch; with a respawn budget and client retries every
    request still completes — the queue survives the respawn."""
    faults("kill-worker=0:2")
    with CoocServer(store_path, workers=2, routing=True,
                    batch_window_ms=0.5, max_respawns=2) as server:
        client = server.client()
        direct = None
        for _ in range(20):
            ids, scores = client.execute(
                [TopKRequest(np.arange(16), k=4)], timeout=30.0, retries=4,
            )[0]
            if direct is None:
                direct = (ids.copy(), scores.copy())
            np.testing.assert_array_equal(ids, direct[0])
    stats = server.stats()
    assert stats["resilience"]["respawns"] >= 1
    # every kill stranded at least its claimed batch
    assert stats["resilience"]["worker_died_failures"] >= 1


def test_worker_died_mid_stream_iterator_raises_promptly(store_path, faults):
    """The hard case: a stream whose first chunk arrived and whose tail was
    lost (drop-response), then the worker dies on its next batch. The
    supervisor fails the still-claimed stream tag, so the iterator raises
    WorkerDied on the next ``next()`` instead of stalling — and the
    client's buffers are drained via ``_forget``."""
    # batch 1 = the stream: claim flows, chunk 0 passes, chunks 1-2 dropped;
    # batch 2 = the probe request: claimed, then the worker dies
    faults("kill-worker=0:1;drop-response=0:2:1")
    with CoocServer(store_path, workers=1, batch_window_ms=0.5,
                    max_respawns=0) as server:
        client = server.client()
        it = client.topk_stream([3], k=96, chunk=32, timeout=30.0)
        ids0, scores0 = next(it)  # chunk 0 made it through
        assert ids0.shape == (1, 32)
        # the probe's batch triggers the kill; its own failure is typed too
        t0 = time.monotonic()
        with pytest.raises(WorkerDied):
            client.topk([5], k=4, timeout=60.0)
        with pytest.raises(WorkerDied):
            next(it)  # supervisor failed the claimed stream tag
        assert time.monotonic() - t0 < 20
        # _forget ran: nothing keeps buffering for the dead request ids
        assert not client._msgs
    assert server.stats()["resilience"]["worker_died_failures"] >= 2


# ------------------------------------------------------- admission control
def test_overload_sheds_typed_and_counts(store_path, faults):
    """A stalled worker with a bounded queue sheds excess load as
    ServerOverloaded at submit — typed, counted, never a silent drop."""
    faults("stall-queue=1.0:5")
    with CoocServer(store_path, workers=1, batch_window_ms=0.0,
                    max_inflight=2, max_respawns=0) as server:
        shed = []
        served = []

        def hammer():
            c = server.client()
            for _ in range(8):
                try:
                    c.topk([3], k=4, timeout=30.0)
                    served.append(1)
                except ServerOverloaded:
                    shed.append(1)

        threads = [threading.Thread(target=hammer) for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = server.stats()
        assert shed, "bounded queue never shed under a 1s stall"
        assert served, "shedding must not starve everything"
        assert stats["resilience"]["shed"] == len(shed)
        assert stats["resilience"]["max_inflight"] == 2


def test_overloaded_retry_then_succeed_under_stall(store_path, faults):
    """Satellite: a shed request retried with jittered backoff lands once
    the stalled worker drains — the caller sees one successful execute."""
    faults("stall-queue=1.0:1")
    with CoocServer(store_path, workers=1, batch_window_ms=0.0,
                    max_inflight=2, max_respawns=0) as server:
        # one request in service (pinned behind the stall), two more filling
        # the bounded queue — each from its own client thread
        def fill(c):  # a filler may race another into a shed: keep pushing
            for _ in range(100):
                try:
                    c.topk([1], k=4, timeout=30.0)
                    return
                except ServerOverloaded:
                    time.sleep(0.05)

        fillers = []
        for _ in range(3):
            th = threading.Thread(target=fill, args=(server.client(),))
            th.start()
            fillers.append(th)
        client = server.client()
        deadline = time.monotonic() + 10
        saw_shed = False
        while time.monotonic() < deadline:  # wait for the queue to be full
            try:
                client.execute([TopKRequest([2], k=4)], timeout=30.0)
            except ServerOverloaded:
                saw_shed = True
                break
            time.sleep(0.02)
        assert saw_shed, "bounded queue never filled behind the stall"
        # same request, now with retries: a backed-off attempt lands after
        # the ~1s stall drains the queue
        (ids, scores), = client.execute(
            [TopKRequest([2], k=4)], timeout=30.0,
            retries=10, retry_backoff_ms=100.0,
        )
        assert ids.shape == (1, 4)
        for th in fillers:
            th.join()
        assert server.stats()["resilience"]["shed"] >= 1


def test_deadline_expired_skip_is_counted(store_path, faults):
    """Requests whose client gave up before a worker dequeued them are
    answered with a typed expiry (client-side: TimeoutError), not executed
    — and counted as serving/deadline_expired."""
    faults("stall-queue=0.8:1")
    with CoocServer(store_path, workers=1, batch_window_ms=0.0,
                    max_respawns=0) as server:
        timeouts = []

        def call():
            c = server.client()
            try:
                c.topk([3], k=4, timeout=0.2)
            except TimeoutError:
                timeouts.append(1)

        threads = [threading.Thread(target=call) for _ in range(5)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert timeouts  # the stall outlived every client deadline
        time.sleep(1.2)  # let the stalled worker drain the expired backlog
    final = server.stats()
    assert final["resilience"]["deadline_expired"] >= 1


# ----------------------------------------------------------- stop satellite
def test_stop_returns_fast_when_workers_die_with_backlog(store_path, faults):
    """Satellite regression: a worker that dies before its final snapshot
    while its queue pipe still holds data used to pin ``stop()`` against
    the full 120s timeout (the dead-worker check only ran when the stats
    pipe went quiet, and periodic snapshots kept it noisy). stop() must
    now return in supervisor time."""
    faults("stall-queue=30:1")  # pin both workers so a backlog builds
    with CoocServer(store_path, workers=2, routing=True,
                    batch_window_ms=0.5, stats_interval_s=0.05,
                    max_respawns=0) as server:

        def call():  # backlog nobody will serve; typed failure or timeout
            c = server.client()
            try:
                c.topk(np.arange(8), k=4, timeout=25.0)
            except (WorkerDied, TimeoutError):
                pass

        for _ in range(6):
            th = threading.Thread(target=call)
            th.daemon = True
            th.start()
        time.sleep(1.0)  # workers are stalled with envelopes behind them
        for p in server._procs:
            os.kill(p.pid, signal.SIGKILL)
        t0 = time.monotonic()
        stats = server.stop(timeout=120.0)
        elapsed = time.monotonic() - t0
    assert elapsed < 15, f"stop() took {elapsed:.1f}s with dead workers"
    assert stats["workers_lost"] >= 1
