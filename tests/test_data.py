"""Data-substrate invariants: corpus generation, preprocessing, indexes."""

import numpy as np
import pytest

from repro.data.corpus import Collection, collection_stats, synthetic_zipf_collection
from repro.data.index import (
    build_inverted_index,
    forward_padded,
    incidence_bitpacked,
    incidence_dense,
)
from repro.data.preprocess import preprocess_documents, remap_df_descending, shard_documents


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(200, vocab=500, mean_len=25, seed=7)


def test_preprocess_dedup_sort():
    c = preprocess_documents([[5, 3, 3, 1], [2, 2], [], [9, 0, 9]])
    assert c.num_docs == 4
    assert np.array_equal(c.doc(0), [1, 3, 5])
    assert np.array_equal(c.doc(1), [2])
    assert len(c.doc(2)) == 0
    assert np.array_equal(c.doc(3), [0, 9])
    assert c.vocab_size == 10


def test_collection_invariants(coll):
    for d in range(coll.num_docs):
        ts = coll.doc(d)
        assert np.all(np.diff(ts) > 0), "per-doc terms must be strictly ascending"
        assert ts.dtype == np.int32
    assert coll.doc_ptr[0] == 0 and coll.doc_ptr[-1] == len(coll.terms)


def test_head_prefix(coll):
    h = coll.head(50)
    assert h.num_docs == 50
    for d in range(50):
        assert np.array_equal(h.doc(d), coll.doc(d))


def test_stats_shape(coll):
    s = collection_stats(coll)
    assert s["num_docs"] == 200
    assert s["min_doc_len"] >= 1
    assert s["num_postings"] == coll.num_postings
    assert s["pair_occurrences"] > 0


def test_inverted_index_roundtrip(coll):
    inv = build_inverted_index(coll)
    assert inv.term_ptr[-1] == coll.num_postings
    # postings ascending, and doc d contains t iff d in postings(t)
    df = inv.df()
    for t in np.nonzero(df)[0][:50]:
        post = inv.postings(t)
        assert np.all(np.diff(post) > 0)
        for d in post[:5]:
            assert t in coll.doc(int(d))


def test_incidence_dense_matches_index(coll):
    B = incidence_dense(coll, 0, 40, 0, coll.vocab_size)
    for d in range(40):
        assert np.array_equal(np.nonzero(B[d])[0], coll.doc(d))


def test_incidence_bitpacked_popcounts(coll):
    inv = build_inverted_index(coll)
    bits = incidence_bitpacked(coll)
    df = inv.df()
    popcounts = np.unpackbits(bits.view(np.uint8), bitorder="little").reshape(
        coll.vocab_size, -1
    ).sum(axis=1)
    assert np.array_equal(popcounts, df)


def test_forward_padded(coll):
    fwd, lens = forward_padded(coll)
    assert np.array_equal(lens, coll.doc_lengths())
    for d in range(20):
        assert np.array_equal(fwd[d, : lens[d]], coll.doc(d))
        assert np.all(fwd[d, lens[d]:] == coll.vocab_size)


def test_df_descending_remap(coll):
    c2, old_of_new = remap_df_descending(coll)
    df2 = np.bincount(c2.terms, minlength=c2.vocab_size)
    assert np.all(np.diff(df2) <= 0), "df must be non-increasing in new IDs"
    # permutation must preserve the multiset of documents
    for d in range(20):
        orig = set(coll.doc(d).tolist())
        back = set(old_of_new[c2.doc(d)].tolist())
        assert orig == back


def test_shard_documents_partition(coll):
    shards = shard_documents(coll, 7)
    assert sum(s.num_docs for s in shards) == coll.num_docs
    assert sum(s.num_postings for s in shards) == coll.num_postings
    recon = np.concatenate([s.terms for s in shards])
    assert np.array_equal(recon, coll.terms)
