import os
import sys

# Tests must see exactly ONE device (the dry-run sets its own 512-device flag
# in a separate process). Keep threads bounded for CI stability.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def lexsort_sym_reference(row_ptr, cols, counts, V):
    """The pre-refactor in-memory symmetric-adjacency build (doubled COO +
    lexsort) — the byte-identity oracle for csr_store._write_symmetric's
    external-memory two-pass build."""
    import numpy as np

    rows = np.repeat(
        np.arange(V, dtype=np.int32), np.diff(row_ptr).astype(np.int64)
    )
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    v2 = np.concatenate([counts, counts])
    order = np.lexsort((c2, r2))
    sym_ptr = np.zeros(V + 1, dtype=np.int64)
    np.cumsum(np.bincount(r2, minlength=V), out=sym_ptr[1:])
    return sym_ptr, c2[order].astype(np.int32), v2[order]
