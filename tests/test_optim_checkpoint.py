"""Optimizer correctness, schedules, compression, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint, save_checkpoint
from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    dequantize_int8,
    ef_compress_update,
    global_norm,
    quantize_int8,
    sgd,
    warmup_cosine,
)

RNG = np.random.default_rng(0)


def _quadratic_params():
    return {"w": jnp.asarray(RNG.normal(size=(8, 4)).astype(np.float32)),
            "b": jnp.zeros((4,), jnp.float32)}


def _loss(params, x):
    y = x @ params["w"] + params["b"]
    return jnp.mean(jnp.square(y - 1.0))


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.05),
    lambda: adamw(0.05),
    lambda: adamw(0.05, moment_dtype=jnp.bfloat16),
    # adafactor's update is sign-like in magnitude → needs a decaying step
    lambda: adafactor(lambda t: 0.5 / jnp.sqrt(t.astype(jnp.float32))),
])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = _quadratic_params()
    state = opt.init(params)
    x = jnp.asarray(RNG.normal(size=(32, 8)).astype(np.float32))
    l0 = float(_loss(params, x))
    for _ in range(60):
        grads = jax.grad(_loss)(params, x)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(_loss(params, x)) < 0.2 * l0


def test_adafactor_memory_is_factored():
    opt = adafactor(0.1)
    params = {"w": jnp.zeros((128, 64))}
    state = opt.init(params)
    assert state["stats"]["w"]["r"].shape == (128,)
    assert state["stats"]["w"]["c"].shape == (64,)


def test_warmup_cosine():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(jnp.int32(55))) < 1.0


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.ones((4,)) * 0.01}
    same, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01)


def test_int8_quantization_roundtrip_error():
    x = jnp.asarray(RNG.normal(size=(1000,)).astype(np.float32))
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    rel = np.abs(np.asarray(deq) - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 0.01  # int8 block quant ≈ 0.4% max error


def test_error_feedback_accumulates():
    """EF: the sum of decompressed grads converges to the sum of true grads."""
    g = jnp.asarray(RNG.normal(size=(512,)).astype(np.float32)) * 1e-3
    err = jnp.zeros_like(g)
    total_sent = np.zeros(512, np.float32)
    for i in range(20):
        sent, err = ef_compress_update(g, err)
        total_sent += np.asarray(sent)
    drift = np.abs(total_sent - 20 * np.asarray(g)).max()
    # residual error is bounded by one quantization step, NOT growing with t
    assert drift <= np.abs(np.asarray(err)).max() + 1e-6


# ---------------------------------------------------------------- checkpoint
def _tree():
    return {
        "params": {"w": jnp.asarray(RNG.normal(size=(6, 3)).astype(np.float32))},
        "opt": {"m": jnp.ones((6, 3), jnp.bfloat16), "count": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save_checkpoint(d, 42, tree, extra={"loss": 1.5})
    assert latest_step(d) == 42
    restored, extra = restore_checkpoint(d, 42, jax.eval_shape(lambda: tree))
    assert extra["loss"] == 1.5
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree, restored,
    )


def test_checkpoint_atomicity(tmp_path):
    """A half-written .tmp directory must be invisible to latest_step."""
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert latest_step(d) == 1


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    bad = {"params": {"w": jnp.zeros((2, 2))}}
    with pytest.raises(ValueError):
        restore_checkpoint(d, 1, jax.eval_shape(lambda: bad))


def test_checkpoint_manager_async_and_gc(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep_last=2)
    for step in [1, 2, 3, 4]:
        mgr.save_async(step, _tree())
    mgr.wait()
    steps = sorted(
        int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step_")
    )
    assert steps == [3, 4]


def test_checkpoint_restart_continues_training(tmp_path):
    """Kill-and-restart: restored state must continue producing identical
    updates (the fault-tolerance contract)."""
    d = str(tmp_path)
    opt = adamw(0.05)
    params = _quadratic_params()
    state = opt.init(params)
    x = jnp.asarray(RNG.normal(size=(16, 8)).astype(np.float32))

    def step(params, state):
        grads = jax.grad(_loss)(params, x)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state

    for _ in range(3):
        params, state = step(params, state)
    save_checkpoint(d, 3, {"p": params, "s": state})
    p_cont, s_cont = step(params, state)  # the "would-have-been" step 4

    restored, _ = restore_checkpoint(
        d, 3, jax.eval_shape(lambda: {"p": params, "s": state})
    )
    p_rest, s_rest = step(restored["p"], restored["s"])
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-7),
        p_cont, p_rest,
    )
