"""Compressed columnar storage engine (ISSUE 7): block codecs, compressed
columns, bloom filters, and the v2 segment format — gated on *byte identity*
with the raw v1 arrays and the brute-force oracle, never on allclose."""

import json
import os
import shutil

import numpy as np
import pytest

from repro.core.cooc import count
from repro.core.oracle import brute_force_counts
from repro.data.corpus import synthetic_zipf_collection
from repro.data.preprocess import shard_documents
from repro.store import (
    BloomFilter,
    CompressedColumn,
    CompressedSegment,
    CSRSegment,
    QueryEngine,
    SpillSink,
    Store,
    compress_segment,
    open_segment,
    segment_bytes,
    write_column,
)
from repro.store import codec


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(120, vocab=200, mean_len=15, seed=11)


@pytest.fixture(scope="module")
def oracle(coll):
    return brute_force_counts(coll)


def _build_segment(coll, out_dir: str, *, version: int):
    sink = SpillSink(coll.vocab_size, memory_budget_pairs=256)
    count("list-scan", coll, sink)
    return sink.finalize_segment(out_dir, version=version)


@pytest.fixture(scope="module")
def seg_pair(coll, tmp_path_factory):
    """The same pairs as a v1 (raw) and a v2 (compressed) segment."""
    base = tmp_path_factory.mktemp("segs")
    v1 = _build_segment(coll, str(base / "v1"), version=1)
    v2 = _build_segment(coll, str(base / "v2"), version=2)
    assert isinstance(v1, CSRSegment) and isinstance(v2, CompressedSegment)
    return v1, v2


# ----------------------------------------------------------------- codecs
_EXTREMES = np.array(
    [0, 1, -1, 127, 128, -128, 2**31 - 1, -(2**31),
     2**63 - 1, -(2**63), 42],
    dtype=np.int64,
)


def test_zigzag_roundtrip_extremes():
    u = codec.zigzag_encode(_EXTREMES)
    assert u.dtype == np.uint64
    np.testing.assert_array_equal(codec.zigzag_decode(u), _EXTREMES)
    # small magnitudes map to small codes (the property varint relies on)
    assert codec.zigzag_encode(np.array([0, -1, 1, -2], dtype=np.int64)).tolist() \
        == [0, 1, 2, 3]


def test_varint_roundtrip_extremes():
    u = codec.zigzag_encode(_EXTREMES)
    b = codec.varint_encode(u)
    assert b.dtype == np.uint8
    np.testing.assert_array_equal(codec.varint_decode(b), u)
    # empty input round-trips too
    empty = np.zeros(0, dtype=np.uint64)
    assert codec.varint_decode(codec.varint_encode(empty)).size == 0


@pytest.mark.parametrize("n", [1, 63, 64, 65, 1000])
def test_varint_roundtrip_random(n):
    rng = np.random.default_rng(n)
    # log-uniform widths so every byte length 1..10 is exercised
    u = (rng.integers(0, 2**63, size=n).astype(np.uint64)
         >> rng.integers(0, 63, size=n).astype(np.uint64))
    np.testing.assert_array_equal(
        codec.varint_decode(codec.varint_encode(u)), u
    )


@pytest.mark.parametrize("vals", [
    np.zeros(100, dtype=np.uint64),                      # width 0
    np.full(7, 2**64 - 1, dtype=np.uint64),              # width 64
    np.arange(1000, dtype=np.uint64),
    np.array([5], dtype=np.uint64),
])
def test_bitpack_roundtrip(vals):
    b = codec.bitpack_encode(vals)
    np.testing.assert_array_equal(codec.bitpack_decode(b, len(vals)), vals)


def test_bitpack_is_frame_of_reference():
    # a tight cluster far from zero packs to ~0 bits per value
    vals = np.arange(10**12, 10**12 + 1024, dtype=np.uint64)
    assert len(codec.bitpack_encode(vals)) < 9 + 1024 * 2


# ------------------------------------------------------ compressed columns
@pytest.mark.parametrize("mode,cdc", [
    ("raw", "varint"), ("delta", "varint"), ("delta", "bitpack"),
])
@pytest.mark.parametrize("n", [0, 1, 1023, 1024, 1025, 5000])
def test_column_roundtrip_and_slices(tmp_path, mode, cdc, n):
    rng = np.random.default_rng(n + len(mode))
    if mode == "delta":  # delta columns are for sorted data
        values = np.sort(rng.integers(0, 10**9, size=n))
    else:
        values = rng.integers(0, 10**6, size=n)
    path = str(tmp_path / f"{mode}_{cdc}_{n}.z")
    write_column(path, values, mode=mode, codec=cdc, block=64)
    col = CompressedColumn(path)
    assert len(col) == n
    np.testing.assert_array_equal(col.decode_all(), values)
    for lo, hi in [(0, n), (0, 0), (n, n), (0, min(1, n)),
                   (min(63, n), min(65, n)), (n // 2, n)]:
        np.testing.assert_array_equal(col.slice(lo, hi), values[lo:hi])
    if n:
        assert col.at(0) == values[0] and col.at(n - 1) == values[n - 1]


def test_column_find(tmp_path):
    values = np.arange(0, 4000, 3, dtype=np.int64)  # sorted, stride 3
    path = str(tmp_path / "find.z")
    write_column(path, values, mode="delta", codec="bitpack", block=64)
    col = CompressedColumn(path)
    rng = np.random.default_rng(3)
    for i in rng.integers(0, len(values), size=50):
        assert col.find(int(values[i])) == i
    for miss in (-1, 1, 4, values[-1] + 1, 10**9):
        assert col.find(miss) == -1


def test_column_dtype_preserved(tmp_path):
    values = np.arange(100, dtype=np.int32)
    path = str(tmp_path / "i32.z")
    write_column(path, values, mode="delta", codec="varint", block=16)
    col = CompressedColumn(path)
    assert col.decode_all().dtype == np.int32
    assert col.slice(10, 20).dtype == np.int32


def test_block_cache_counts_hits(tmp_path):
    from repro import obs

    values = np.arange(1000, dtype=np.int64)
    path = str(tmp_path / "cached.z")
    write_column(path, values, block=64)
    reg = obs.Registry(enabled=True)
    cache = codec.BlockCache(max_blocks=4, registry=reg)
    col = CompressedColumn(path, cache=cache, tag="t", registry=reg)
    col.slice(0, 64)
    col.slice(0, 64)                       # same block again -> cache hit
    snap = reg.snapshot()["counters"]
    assert snap["storage.block_cache_hits"] >= 1
    assert snap["storage.blocks_decoded"] >= 1


# ------------------------------------------------------------------ bloom
def test_bloom_no_false_negatives_and_fpr():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 2**62, size=20_000).astype(np.uint64)
    filt = BloomFilter.build(keys)
    assert filt.contains(keys).all()       # zero false negatives, always
    probes = rng.integers(2**62, 2**63, size=50_000).astype(np.uint64)
    fpr = filt.contains(probes).mean()
    assert fpr < 0.05, f"blocked bloom FPR {fpr:.4f} out of spec"


def test_bloom_save_load_roundtrip(tmp_path):
    keys = np.arange(1000, dtype=np.uint64) * 2654435761
    filt = BloomFilter.build(keys)
    path = str(tmp_path / "bloom.bin")
    filt.save(path)
    loaded = BloomFilter.load(path)
    assert loaded.contains(keys).all()
    probes = np.arange(10_000, dtype=np.uint64) * 7 + 3
    np.testing.assert_array_equal(loaded.contains(probes), filt.contains(probes))


# ------------------------------------------------- v2 segment: identity
def test_v2_matches_v1_and_oracle(coll, oracle, seg_pair):
    v1, v2 = seg_pair
    np.testing.assert_array_equal(v2.dense(), oracle)
    np.testing.assert_array_equal(v2.df, v1.df)
    assert v2.nnz == v1.nnz and v2.total_count == v1.total_count
    sym = oracle + oracle.T
    for t in range(coll.vocab_size):
        for a, b in zip(v1.row(t), v2.row(t)):
            assert a.tobytes() == b.tobytes() and a.dtype == b.dtype
        for a, b in zip(v1.neighbours(t), v2.neighbours(t)):
            assert a.tobytes() == b.tobytes() and a.dtype == b.dtype
        ids, cnts = v2.neighbours(t)
        np.testing.assert_array_equal(cnts, sym[t][sym[t] > 0])


def test_v2_pair_counts_bloom_gated(coll, oracle, seg_pair):
    from repro import obs

    _, v2 = seg_pair
    sym = oracle + oracle.T
    rng = np.random.default_rng(13)
    pairs = rng.integers(0, coll.vocab_size, size=(500, 2))
    with obs.scoped() as reg:
        got = v2.pair_counts(pairs)
    np.testing.assert_array_equal(got, sym[pairs[:, 0], pairs[:, 1]])
    for i, j in [(0, 0), (1, 2), (199, 3)]:
        assert v2.pair_count(i, j) == sym[i, j]
    snap = reg.snapshot()["counters"]
    # a handful of pairs (diagonal / duplicates) resolve before the probe
    assert snap["storage.bloom_checks"] >= 450
    assert snap["storage.bloom_negative"] > 0   # most random pairs are absent


def test_v2_iter_rows_and_pair_file(tmp_path, seg_pair):
    v1, v2 = seg_pair
    for (t1, s1, c1), (t2, s2, c2) in zip(v1.iter_rows(), v2.iter_rows()):
        assert t1 == t2
        assert s1.tobytes() == s2.tobytes()
        assert c1.tobytes() == c2.tobytes()
    p1, p2 = str(tmp_path / "a.pairs"), str(tmp_path / "b.pairs")
    v1.to_pair_file(p1)
    v2.to_pair_file(p2)
    with open(p1, "rb") as f1, open(p2, "rb") as f2:
        assert f1.read() == f2.read()


def test_v2_compresses_at_least_2x(seg_pair):
    v1, v2 = seg_pair
    assert segment_bytes(v2.path) * 2 <= segment_bytes(v1.path)


def test_compress_segment_in_place_upgrade(coll, oracle, tmp_path):
    src = _build_segment(coll, str(tmp_path / "v1"), version=1)
    dup = str(tmp_path / "dup")
    shutil.copytree(src.path, dup)
    compress_segment(dup)
    seg = open_segment(dup)
    assert isinstance(seg, CompressedSegment)
    np.testing.assert_array_equal(seg.dense(), oracle)
    assert not any(f.endswith(".bin") for f in os.listdir(dup)
                   if f != "bloom.bin"), "raw arrays not removed"
    with pytest.raises(ValueError, match="needs a v1 segment"):
        compress_segment(dup)               # already v2


# ------------------------------------------------ version/magic handling
def test_open_segment_unknown_version_is_clear(coll, tmp_path):
    seg = _build_segment(coll, str(tmp_path / "seg"), version=1)
    meta_path = os.path.join(seg.path, "meta.json")
    meta = json.load(open(meta_path))
    meta["format_version"] = 99
    json.dump(meta, open(meta_path, "w"))
    with pytest.raises(ValueError, match="format_version 99"):
        open_segment(seg.path)
    meta["format_version"] = 1
    meta["magic"] = "not-a-segment"
    json.dump(meta, open(meta_path, "w"))
    with pytest.raises(ValueError, match="magic"):
        open_segment(seg.path)


def test_open_segment_premagic_v1_still_loads(coll, oracle, tmp_path):
    # segments written before the header existed carry no magic field
    seg = _build_segment(coll, str(tmp_path / "seg"), version=1)
    meta_path = os.path.join(seg.path, "meta.json")
    meta = json.load(open(meta_path))
    del meta["magic"]
    json.dump(meta, open(meta_path, "w"))
    loaded = open_segment(seg.path)
    assert isinstance(loaded, CSRSegment)
    np.testing.assert_array_equal(loaded.dense(), oracle)


def test_write_segment_rejects_unknown_version(coll, tmp_path):
    with pytest.raises(ValueError, match="unknown segment version"):
        _build_segment(coll, str(tmp_path / "seg"), version=3)


# -------------------------------------------------------- store integration
def test_store_v2_end_to_end(coll, oracle, tmp_path):
    store = Store.create(str(tmp_path / "s"), coll.vocab_size,
                         segment_version=2)
    for shard in shard_documents(coll, 2):
        store.append_collection(shard, method="list-scan")
    assert all(isinstance(s, CompressedSegment) for s in store.segments)
    np.testing.assert_array_equal(store.dense(), oracle)
    eng = QueryEngine(store)
    rng = np.random.default_rng(5)
    pairs = rng.integers(0, coll.vocab_size, size=(200, 2))
    sym = oracle + oracle.T
    np.testing.assert_array_equal(eng.pair_counts(pairs),
                                  sym[pairs[:, 0], pairs[:, 1]])
    # compaction keeps the format and the answers
    store.compact()
    assert len(store.segment_names) == 1
    assert isinstance(store.segments[0], CompressedSegment)
    np.testing.assert_array_equal(store.dense(), oracle)


def test_store_mixed_v1_v2_segments(coll, oracle, tmp_path):
    """v1 and v2 segments coexist in one store: the manifest's
    segment_version only steers new writes, reads dispatch per segment."""
    store = Store.create(str(tmp_path / "s"), coll.vocab_size,
                         segment_version=1)
    shards = shard_documents(coll, 2)
    store.append_collection(shards[0], method="list-scan")
    store._commit(lambda m: m.update(segment_version=2))
    store.append_collection(shards[1], method="list-scan")
    kinds = {type(s) for s in store.segments}
    assert kinds == {CSRSegment, CompressedSegment}
    np.testing.assert_array_equal(store.dense(), oracle)
    # compacting the mixed pair merges into the current (v2) format
    store.compact()
    assert isinstance(store.segments[0], CompressedSegment)
    np.testing.assert_array_equal(store.dense(), oracle)


def test_v1_engine_results_identical_to_v2(coll, oracle, tmp_path):
    """The ISSUE acceptance gate at store level: every query path returns
    byte-identical results on a v1 and a v2 build of the same corpus."""
    engines = []
    for ver in (1, 2):
        st = Store.create(str(tmp_path / f"v{ver}"), coll.vocab_size,
                          segment_version=ver)
        for shard in shard_documents(coll, 3):
            st.append_collection(shard, method="list-scan")
        engines.append(QueryEngine(st))
    e1, e2 = engines
    rng = np.random.default_rng(17)
    terms = rng.integers(0, coll.vocab_size, size=64)
    for score in ("count", "pmi", "dice"):
        a, b = e1.topk(terms, k=8, score=score), e2.topk(terms, k=8, score=score)
        assert a[0].tobytes() == b[0].tobytes()
        assert a[1].tobytes() == b[1].tobytes()
    pairs = rng.integers(0, coll.vocab_size, size=(300, 2))
    assert e1.pair_counts(pairs).tobytes() == e2.pair_counts(pairs).tobytes()
