"""Store subsystem: spill-and-merge build, CSR segments, incremental
append, shard ingest, and query exactness — everything checked against the
naive / brute-force dense oracle (integer equality, no allclose)."""

import os

import numpy as np
import pytest

from repro.core.cooc import count, count_to_store, dense_counts
from repro.core.oracle import brute_force_counts
from repro.core.types import DenseSink, FileSink, read_pair_file
from repro.data.corpus import synthetic_zipf_collection
from repro.data.preprocess import shard_documents
from repro.store import (
    CSRSegment,
    QueryEngine,
    SpillSink,
    Store,
    segment_from_pair_file,
)


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(120, vocab=200, mean_len=15, seed=11)


@pytest.fixture(scope="module")
def oracle(coll):
    return brute_force_counts(coll)


# ------------------------------------------------------------------ builder
@pytest.mark.parametrize("method", ["naive", "list-scan", "list-blocks"])
@pytest.mark.parametrize("budget", [64, 4096, 1 << 22])
def test_spill_sink_matches_dense(coll, oracle, method, budget, tmp_path):
    """Any counting method through a SpillSink (any budget, incl. ones that
    force many spills) equals the dense accumulation."""
    sink = SpillSink(coll.vocab_size, memory_budget_pairs=budget)
    count(method, coll, sink)
    if budget == 64:
        assert sink.stats["spills"] > 1
    seg = sink.finalize_segment(str(tmp_path / "seg"))
    assert np.array_equal(seg.dense(), oracle)
    assert seg.nnz == int((oracle > 0).sum())
    assert seg.total_count == int(oracle.sum())


def test_spill_sink_emit_col(coll, oracle, tmp_path):
    """freq-split's column-order tail path spills correctly too."""
    from repro.data.preprocess import remap_df_descending

    cd, _ = remap_df_descending(coll)
    sink = SpillSink(cd.vocab_size, memory_budget_pairs=128)
    count("freq-split", cd, sink, head=32, use_kernel=False)
    seg = sink.finalize_segment(str(tmp_path / "seg"))
    assert np.array_equal(seg.dense(), brute_force_counts(cd))


# ------------------------------------------------------------- CSR segment
def test_segment_lookups(coll, oracle, tmp_path):
    sink = SpillSink(coll.vocab_size, memory_budget_pairs=256)
    count("list-scan", coll, sink)
    seg = sink.finalize_segment(str(tmp_path / "seg"))

    sym = oracle + oracle.T
    rng = np.random.default_rng(0)
    for t in [0, 7, coll.vocab_size - 1]:
        secs, cnts = seg.row(t)
        nz = np.nonzero(oracle[t])[0]
        assert np.array_equal(secs, nz)
        assert np.array_equal(cnts, oracle[t][nz])
        ids, ncnts = seg.neighbours(t)
        nz = np.nonzero(sym[t])[0]
        assert np.array_equal(ids, nz)
        assert np.array_equal(ncnts, sym[t][nz])

    pairs = rng.integers(0, coll.vocab_size, size=(300, 2))
    lo, hi = np.minimum(pairs[:, 0], pairs[:, 1]), np.maximum(pairs[:, 0], pairs[:, 1])
    want = np.where(lo == hi, 0, oracle[lo, hi])
    assert np.array_equal(seg.pair_counts(pairs), want)
    assert seg.pair_count(5, 5) == 0
    # reopen from disk (a serving process)
    seg2 = CSRSegment(seg.path)
    assert np.array_equal(seg2.dense(), oracle)


def test_pair_file_roundtrip(coll, oracle, tmp_path):
    """FileSink output -> SpillSink runs -> merged CSR store -> back to the
    paper's pair format; counts match the naive oracle end to end."""
    pf = str(tmp_path / "pairs.bin")
    sink = FileSink(pf)
    count("list-scan", coll, sink)
    sink.close()

    seg = segment_from_pair_file(pf, str(tmp_path / "seg"), coll.vocab_size)
    assert np.array_equal(seg.dense(), dense_counts("naive", coll))

    pf2 = str(tmp_path / "pairs2.bin")
    seg.to_pair_file(pf2)
    mat = np.zeros_like(oracle)
    for p, secs, cnts in read_pair_file(pf2):
        mat[p, secs.astype(np.int64)] += cnts.astype(np.int64)
    assert np.array_equal(mat, oracle)


def test_segment_emit_to_dense_sink(coll, oracle, tmp_path):
    sink = SpillSink(coll.vocab_size)
    count("list-scan", coll, sink)
    seg = sink.finalize_segment(str(tmp_path / "seg"))
    dense = DenseSink(coll.vocab_size)
    seg.emit_to(dense)
    assert np.array_equal(dense.mat, oracle)


def test_empty_collection(tmp_path):
    from repro.data.preprocess import preprocess_documents

    c = preprocess_documents([[1], []], vocab_size=8)  # no pairs at all
    sink = SpillSink(c.vocab_size)
    count("list-scan", c, sink)
    seg = sink.finalize_segment(str(tmp_path / "seg"))
    assert seg.nnz == 0
    assert seg.pair_count(0, 1) == 0
    ids, cnts = seg.neighbours(1)
    assert len(ids) == 0 and len(cnts) == 0


# ------------------------------------------------------- store / manifest
def test_incremental_append_exact(coll, oracle, tmp_path):
    store = Store.create(str(tmp_path / "store"), coll.vocab_size)
    for shard in shard_documents(coll, 3):
        store.append_collection(shard, method="naive", memory_budget_pairs=128)
    assert len(store.segment_names) == 3
    assert np.array_equal(store.dense(), oracle)
    assert store.num_docs == coll.num_docs
    assert np.array_equal(
        store.df(), np.bincount(coll.terms, minlength=coll.vocab_size)
    )


def test_compaction_preserves_counts(coll, oracle, tmp_path):
    store = Store.create(str(tmp_path / "store"), coll.vocab_size)
    for shard in shard_documents(coll, 4):
        store.append_collection(shard, method="list-scan", memory_budget_pairs=256)
    df, nd = store.df().copy(), store.num_docs
    old_dirs = [os.path.join(store.path, n) for n in store.segment_names]
    store.compact()
    assert len(store.segment_names) == 1
    assert np.array_equal(store.dense(), oracle)
    assert np.array_equal(store.df(), df) and store.num_docs == nd
    assert not any(os.path.exists(p) for p in old_dirs)  # GC'd


def test_multi_shard_ingest(coll, oracle, tmp_path):
    """Per-shard stores (the distributed runner's per-worker outputs) merge
    exactly into one serving store."""
    dest = Store.create(str(tmp_path / "dest"), coll.vocab_size)
    for i, shard in enumerate(shard_documents(coll, 2)):
        shard_store = Store.create(str(tmp_path / f"shard{i}"), coll.vocab_size)
        shard_store.append_collection(shard, method="list-blocks")
        dest.ingest_store(shard_store)
    assert np.array_equal(dest.dense(), oracle)
    assert dest.num_docs == coll.num_docs


def test_store_reopen(coll, oracle, tmp_path):
    path = str(tmp_path / "store")
    store = Store.create(path, coll.vocab_size)
    store.append_collection(coll, method="list-scan")
    del store
    store = Store.open(path)
    assert np.array_equal(store.dense(), oracle)


def test_count_to_store_create_then_append(coll, tmp_path):
    path = str(tmp_path / "store")
    half = shard_documents(coll, 2)
    store, _ = count_to_store("list-scan", half[0], path, memory_budget_pairs=512)
    store2, _ = count_to_store("list-scan", half[1], path, memory_budget_pairs=512)
    assert len(store2.segment_names) == 2
    assert np.array_equal(store2.dense(), brute_force_counts(coll))


# ------------------------------------------------------------ query engine
def test_query_engine_pair_counts(coll, oracle, tmp_path):
    store, _ = count_to_store("list-scan", coll, str(tmp_path / "s"))
    eng = QueryEngine(store)
    rng = np.random.default_rng(2)
    pairs = rng.integers(0, coll.vocab_size, size=(400, 2))
    lo, hi = np.minimum(pairs[:, 0], pairs[:, 1]), np.maximum(pairs[:, 0], pairs[:, 1])
    want = np.where(lo == hi, 0, oracle[lo, hi])
    assert np.array_equal(eng.pair_counts(pairs), want)


def test_query_engine_topk_count_exact(coll, oracle, tmp_path):
    store, _ = count_to_store("list-scan", coll, str(tmp_path / "s"))
    eng = QueryEngine(store)
    sym = oracle + oracle.T
    terms = np.arange(0, coll.vocab_size, 7)
    k = 6
    ids, scores = eng.topk(terms, k=k, score="count")
    assert ids.shape == (len(terms), k)
    for b, t in enumerate(terms):
        want = np.sort(sym[t])[::-1][:k]
        got = np.where(ids[b] >= 0, scores[b], 0).astype(np.int64)
        assert np.array_equal(np.sort(got)[::-1], want)
        for i, s in zip(ids[b], scores[b]):
            if i >= 0:
                assert sym[t][i] == s


@pytest.mark.parametrize("score", ["pmi", "dice"])
def test_query_engine_topk_scored(coll, oracle, tmp_path, score):
    store, _ = count_to_store("list-scan", coll, str(tmp_path / "s"))
    eng = QueryEngine(store)
    sym = (oracle + oracle.T).astype(np.float64)
    df = np.bincount(coll.terms, minlength=coll.vocab_size).astype(np.float64)
    D = coll.num_docs
    terms = np.array([0, 3, 11, 42])
    k = 5
    ids, scores = eng.topk(terms, k=k, score=score)
    for b, t in enumerate(terms):
        with np.errstate(divide="ignore", invalid="ignore"):
            if score == "pmi":
                ref = np.log(sym[t] * D / (df[t] * df))
            else:
                ref = 2.0 * sym[t] / (df[t] + df)
        ref[sym[t] == 0] = -np.inf
        want = np.sort(ref)[::-1][:k]
        got = np.sort(np.asarray(scores[b], dtype=np.float64))[::-1]
        finite = np.isfinite(want)
        assert np.allclose(got[finite], want[finite], rtol=1e-5)
        for i, s in zip(ids[b], scores[b]):
            if i >= 0 and np.isfinite(s):
                assert np.isclose(float(s), ref[i], rtol=1e-5)


def test_query_engine_k_exceeds_degree(coll, tmp_path):
    store, _ = count_to_store("list-scan", coll, str(tmp_path / "s"))
    eng = QueryEngine(store)
    ids, scores = eng.topk([0], k=10 * coll.vocab_size, score="count")
    assert ids.shape[1] == 10 * coll.vocab_size
    assert (ids[0] == -1).any()  # padded out past the true degree


def test_query_engine_cache_and_invalidation(coll, tmp_path):
    store, _ = count_to_store("list-scan", coll, str(tmp_path / "s"))
    eng = QueryEngine(store, cache_rows=4)
    eng.topk([1, 2, 1, 2], k=3)
    assert eng.stats["cache_hits"] >= 2
    before = eng.pair_counts(np.array([[1, 2]]))[0]
    # append the same docs again: every count doubles, engine must notice
    store.append_collection(coll, method="list-scan")
    after = eng.pair_counts(np.array([[1, 2]]))[0]
    assert after == 2 * before
    ids, scores = eng.topk([1], k=3)
    sym = 2 * (brute_force_counts(coll) + brute_force_counts(coll).T)
    assert scores[0][0] == np.sort(sym[1])[::-1][0]


def test_query_engine_invalidation_under_mutation_in_flight(coll, tmp_path):
    """Satellite: queries interleaved with append + compact() — the row
    cache invalidates at each manifest bump and answers stay exact."""
    store, _ = count_to_store("list-scan", coll, str(tmp_path / "s"))
    eng = QueryEngine(store, cache_rows=8)
    eng.topk([1, 2, 3], k=4)                     # warm the cache
    pc0 = eng.pair_counts(np.array([[1, 2]]))[0]
    store.append_collection(coll, method="list-scan")
    mid_ids, mid_scores = eng.topk([1, 2, 3], k=4)   # in-flight: post-append
    assert eng.pair_counts(np.array([[1, 2]]))[0] == 2 * pc0
    store.compact()                              # same counts, new segment
    after_ids, after_scores = eng.topk([1, 2, 3], k=4)
    np.testing.assert_array_equal(mid_ids, after_ids)
    np.testing.assert_array_equal(mid_scores, after_scores)
    assert eng.pair_counts(np.array([[1, 2]]))[0] == 2 * pc0
    # cache was rebuilt against the compacted segment, not served stale
    assert len(store.segment_names) == 1


def test_store_refresh_sees_sibling_process_commits(coll, tmp_path):
    """Store.refresh(): a second Store object on the same directory (the
    serving-worker topology) picks up append/compact commits and bumps its
    version so engines invalidate."""
    path = str(tmp_path / "s")
    store, _ = count_to_store("list-scan", coll, path)
    sibling = Store.open(path)                   # what a worker holds
    eng = QueryEngine(sibling, cache_rows=8)
    before = eng.pair_counts(np.array([[1, 2]]))[0]
    assert sibling.refresh() is False            # nothing changed yet
    store.append_collection(coll, method="list-scan")
    assert sibling.refresh() is True
    assert eng.pair_counts(np.array([[1, 2]]))[0] == 2 * before
    store.compact()
    assert sibling.refresh() is True
    assert eng.pair_counts(np.array([[1, 2]]))[0] == 2 * before
    ids, _ = eng.topk([1], k=3)                  # reads the compacted segment
    ref = QueryEngine(Store.open(path))
    np.testing.assert_array_equal(ids, ref.topk([1], k=3)[0])


# ------------------------------------------------------------------ serving
def test_cooc_serve_driver_smoke():
    from repro.launch.cooc_serve import serve

    stats = serve(docs=200, vocab=256, queries=64, batch=16, topk=5)
    assert stats["topk_qps"] > 0 and stats["pair_qps"] > 0
    assert stats["num_docs"] == 200


# ------------------------------------------------- radix-partitioned spills
def test_spill_runs_are_bucketed(coll, tmp_path):
    """A spill writes one sorted run per nonempty primary-range bucket;
    finalization merges per bucket, and the result still equals the dense
    oracle (covered above) while run files carry bucket ids."""
    sink = SpillSink(coll.vocab_size, memory_budget_pairs=64,
                     spill_dir=str(tmp_path / "spill"))
    count("list-scan", coll, sink)
    sink.flush()
    assert sink.stats["spills"] > 1
    assert sink.stats["bucket_runs"] == len(sink.runs) > 0
    for bucket, path in sink.runs:
        name = os.path.basename(path)
        assert name.endswith(f"_b{bucket:04d}.bin"), name
        # every run's primaries stay inside its bucket's primary range
        lo = bucket << sink._pshift
        hi = (bucket + 1) << sink._pshift
        for primary, _, _ in read_pair_file(path):
            assert lo <= primary < hi, (primary, bucket)
    sink.close()


def test_sum_by_key_byte_identical_to_two_sort_reference():
    """Single-sort + diff-boundary aggregation is byte-identical (values
    AND dtypes) to the old argsort + np.unique double-sort on random input."""
    from repro.store.builder import sum_by_key

    def two_sort_reference(keys, cnts):
        order = np.argsort(keys, kind="stable")
        keys, cnts = keys[order], np.asarray(cnts, dtype=np.int64)[order]
        uniq, start = np.unique(keys, return_index=True)
        return uniq, np.add.reduceat(cnts, start)

    rng = np.random.default_rng(3)
    for n in [1, 2, 17, 1000, 20000]:
        keys = rng.integers(0, max(1, n // 2), size=n).astype(np.int64)
        cnts = rng.integers(1, 1000, size=n).astype(np.uint32)  # narrow in
        got_k, got_c = sum_by_key(keys, cnts)
        want_k, want_c = two_sort_reference(keys, cnts)
        assert got_k.dtype == want_k.dtype and got_c.dtype == want_c.dtype
        assert np.array_equal(got_k, want_k)
        assert np.array_equal(got_c, want_c)
    # empty input stays typed and empty
    got_k, got_c = sum_by_key(np.array([], dtype=np.int64), np.array([]))
    assert got_k.dtype == np.int64 and got_c.dtype == np.int64
    assert len(got_k) == 0 and len(got_c) == 0


def test_spill_overflow_u32_survives_radix_rewrite(tmp_path):
    """Regression: pre-aggregated counts >= 2^32 must still raise
    OverflowError (the run format stores u32 counts) through the
    radix-partitioned spill path — including the oversize-emission path."""
    sink = SpillSink(100, memory_budget_pairs=8)
    sink.emit_row(1, np.array([2, 3]), np.array([1 << 32, 5], dtype=np.int64))
    with pytest.raises(OverflowError, match="u32"):
        sink.flush()
    sink.close()
    # oversize emission (bigger than the whole buffer) goes straight to disk
    sink = SpillSink(1000, memory_budget_pairs=4)
    big = np.arange(1, 41, dtype=np.int64)
    with pytest.raises(OverflowError, match="u32"):
        sink.emit_row(0, big, np.full(40, 1 << 33, dtype=np.int64))
    sink.close()


def test_emit_does_not_mutate_caller_arrays(coll, tmp_path):
    """The copy-free emit path packs keys into the sink's own buffers —
    the caller's secondaries/counts must come back untouched."""
    sink = SpillSink(64, memory_budget_pairs=128)
    secs = np.array([3, 9, 11], dtype=np.int64)
    cnts = np.array([1, 2, 3], dtype=np.int64)
    sink.emit_row(1, secs, cnts)
    prims = np.array([2, 5], dtype=np.int32)
    ccnts = np.array([7, 8], dtype=np.uint32)
    sink.emit_col(60, prims, ccnts)
    assert np.array_equal(secs, [3, 9, 11]) and np.array_equal(cnts, [1, 2, 3])
    assert np.array_equal(prims, [2, 5]) and np.array_equal(ccnts, [7, 8])
    seg = sink.finalize_segment(str(tmp_path / "seg"))
    assert seg.pair_count(1, 9) == 2 and seg.pair_count(5, 60) == 8


# --------------------------------------- external-memory symmetric build
def _read_sym(seg_dir):
    return (
        np.fromfile(os.path.join(seg_dir, "sym_row_ptr.bin"), dtype=np.int64),
        np.fromfile(os.path.join(seg_dir, "sym_cols.bin"), dtype=np.int32),
        np.fromfile(os.path.join(seg_dir, "sym_counts.bin"), dtype=np.int64),
    )


def _read_upper(seg_dir):
    return (
        np.fromfile(os.path.join(seg_dir, "row_ptr.bin"), dtype=np.int64),
        np.fromfile(os.path.join(seg_dir, "cols.bin"), dtype=np.int32),
        np.fromfile(os.path.join(seg_dir, "counts.bin"), dtype=np.int64),
    )


def test_symmetric_build_is_external_memory(tmp_path):
    """Acceptance: a segment whose nnz exceeds the configured chunk by >=10x
    builds its symmetric adjacency without materializing O(nnz) arrays —
    the build reports per-chunk temporaries bounded by the chunk size — and
    the result is byte-identical to the in-memory lexsort reference."""
    from conftest import lexsort_sym_reference
    from repro.store.csr_store import _write_symmetric, write_segment

    V = 120
    rows = [
        (i, np.arange(i + 1, V, dtype=np.int64),
         np.full(V - i - 1, i + 1, dtype=np.int64))
        for i in range(V - 1)
    ]
    seg_dir = str(tmp_path / "seg")
    write_segment(seg_dir, iter(rows), V)
    row_ptr, cols, counts = _read_upper(seg_dir)
    nnz = int(row_ptr[-1])
    chunk = nnz // 16
    assert nnz >= 10 * chunk
    stats = _write_symmetric(seg_dir, row_ptr, V, nnz, chunk_pairs=chunk)
    assert stats["chunks"] >= 10
    assert stats["peak_temp_elems"] <= chunk  # O(V + chunk), never O(nnz)
    want = lexsort_sym_reference(row_ptr, cols, counts, V)
    got = _read_sym(seg_dir)
    for g, w in zip(got, want):
        assert g.dtype == w.dtype and np.array_equal(g, w)


def test_symmetric_build_identical_on_random_segments(tmp_path):
    """Streamed two-pass build == in-memory lexsort build on random upper
    CSR segments, including empty rows, empty segments, and single-row
    segments, at adversarial chunk sizes."""
    from conftest import lexsort_sym_reference
    from repro.store.csr_store import write_segment

    rng = np.random.default_rng(7)
    cases = []
    for trial in range(25):
        V = int(rng.integers(1, 50))
        density = float(rng.random()) * 0.5
        dense = np.triu(
            (rng.random((V, V)) < density) * rng.integers(1, 90, (V, V)), 1
        )
        cases.append((V, dense, int(rng.integers(1, 60))))
    cases.append((1, np.zeros((1, 1), dtype=np.int64), 1))      # empty segment
    one = np.zeros((4, 4), dtype=np.int64)
    one[1, 3] = 5
    cases.append((4, one, 1))                                   # single row
    for idx, (V, dense, chunk) in enumerate(cases):
        rows = [
            (i, np.nonzero(dense[i])[0], dense[i][np.nonzero(dense[i])[0]])
            for i in range(V)
            if dense[i].any()
        ]
        seg_dir = str(tmp_path / f"seg{idx}")
        write_segment(seg_dir, iter(rows), V, sym_chunk_pairs=chunk)
        row_ptr, cols, counts = _read_upper(seg_dir)
        want = lexsort_sym_reference(row_ptr, cols, counts, V)
        got = _read_sym(seg_dir)
        for g, w in zip(got, want):
            assert np.array_equal(g, w), idx


# ------------------------------------------- manifest generation / compaction
def test_refresh_detects_same_stat_rewrite(coll, tmp_path):
    """Satellite regression (ISSUE 7): a manifest rewrite that lands on the
    same inode, byte length, and (coarse) mtime is invisible to a pure stat
    signature — the generation counter at the head of the file must catch
    it. Forced here by rewriting store.json in place, padded to the same
    length, with the old mtime restored."""
    path = str(tmp_path / "s")
    store, _ = count_to_store("list-scan", coll, path)
    sibling = Store.open(path)
    meta_path = os.path.join(path, "store.json")
    st_before = os.stat(meta_path)
    import json as _json

    with open(meta_path) as f:
        manifest = _json.load(f)
    old_len = st_before.st_size
    manifest["generation"] = int(manifest["generation"]) + 1
    manifest["segments"] = []            # semantically different manifest
    blob = _json.dumps(manifest, indent=2)
    blob += " " * (old_len - len(blob))  # same byte length
    assert len(blob) == old_len
    with open(meta_path, "r+") as f:     # in place: same inode
        f.write(blob)
    os.utime(meta_path, ns=(st_before.st_atime_ns, st_before.st_mtime_ns))
    st_after = os.stat(meta_path)
    assert (st_after.st_ino, st_after.st_mtime_ns, st_after.st_size) == (
        st_before.st_ino, st_before.st_mtime_ns, st_before.st_size
    ), "rewrite failed to preserve the stat signature"
    assert sibling.refresh() is True, "generation probe missed the rewrite"
    assert sibling.segment_names == []


def test_generation_monotone_across_commits(coll, tmp_path):
    path = str(tmp_path / "s")
    store, _ = count_to_store("list-scan", coll, path)
    g0 = store.manifest["generation"]
    store.append_collection(coll, method="list-scan")
    g1 = store.manifest["generation"]
    store.compact()
    g2 = store.manifest["generation"]
    assert g0 < g1 < g2


def test_plan_compaction_size_tiers(coll, tmp_path):
    """Size-tiered planning merges peers: three similar small segments
    qualify, the one big segment is left alone."""
    from repro.data.preprocess import shard_documents

    path = str(tmp_path / "s")
    store = Store.create(path, coll.vocab_size)
    store.append_collection(coll, method="list-scan")   # big
    for shard in shard_documents(coll, 6)[:3]:          # three small peers
        store.append_collection(shard, method="list-scan")
    plan = store.plan_compaction(min_segments=2, tier_ratio=4.0)
    assert len(plan) == 3
    assert store.segment_names[0] not in plan           # big one excluded
    assert store.plan_compaction(min_segments=5) == []


def test_compact_background_joins_and_preserves_appends(coll, oracle, tmp_path):
    """compact_background merges in a worker process while this process
    keeps appending; the concurrent append survives the commit race."""
    from repro.data.preprocess import shard_documents

    path = str(tmp_path / "s")
    store = Store.create(path, coll.vocab_size)
    shards = shard_documents(coll, 3)
    for shard in shards[:2]:
        store.append_collection(shard, method="list-scan")
    names = list(store.segment_names)
    handle = store.compact_background(names=names)
    assert handle is not None
    store.append_collection(shards[2], method="list-scan")  # concurrent write
    res = handle.join(timeout=120)
    assert sorted(res["merged"]) == sorted(names)
    store.refresh()
    assert len(store.segment_names) == 2    # merged + concurrent append
    np.testing.assert_array_equal(store.dense(), oracle)


def test_compact_while_reader_holds_segments(coll, oracle, tmp_path):
    """Satellite (ISSUE 7): a reader holding opened segments survives the
    compactor unlinking them — eager mmaps keep the data alive — and a
    refresh mid-stream swaps to the merged segment with identical bytes."""
    from repro.data.preprocess import shard_documents

    path = str(tmp_path / "s")
    store = Store.create(path, coll.vocab_size, segment_version=2)
    for shard in shard_documents(coll, 3):
        store.append_collection(shard, method="list-scan")
    reader = Store.open(path)
    eng = QueryEngine(reader)
    rng = np.random.default_rng(23)
    terms = rng.integers(0, coll.vocab_size, size=32)
    before = eng.topk(terms, k=8, score="pmi")
    _ = reader.segments                      # opened (mmapped) pre-compact
    store.compact()                          # unlinks the three source dirs
    after_unlinked = eng.topk(terms, k=8, score="pmi")   # old mmaps still live
    assert before[0].tobytes() == after_unlinked[0].tobytes()
    assert before[1].tobytes() == after_unlinked[1].tobytes()
    assert reader.refresh() is True
    after = eng.topk(terms, k=8, score="pmi")
    assert before[0].tobytes() == after[0].tobytes()
    assert before[1].tobytes() == after[1].tobytes()
    np.testing.assert_array_equal(reader.dense(), oracle)


def test_add_segment_single_commit(tmp_path):
    """single_commit writes the segment into a hidden pending directory and
    publishes it with ONE manifest commit: name allocation, rename, and
    append land together, and no pending dir survives."""
    import glob

    path = str(tmp_path / "s")
    store = Store.create(path, 10)
    rows = [(0, np.array([3, 7], dtype=np.int64), np.array([2, 5], dtype=np.int64))]
    gen0 = store.manifest["generation"]
    seg = store.add_segment_from_rows(iter(rows), num_docs=1, single_commit=True)
    assert store.segment_names == [os.path.basename(seg.path)]
    assert store.manifest["next_seg_id"] == 1
    assert store.manifest["generation"] == gen0 + 1   # exactly one commit
    assert glob.glob(os.path.join(path, ".pending-*")) == []
    assert store.pair_count(0, 3) == 2
    assert store.pair_count(0, 7) == 5
    # a reader that refreshes never observes a reserved-but-absent name
    reader = Store.open(path)
    for name in reader.segment_names:
        assert os.path.isdir(os.path.join(path, name))


def test_concurrent_appenders_never_drop_segments(tmp_path):
    """PR-7 manifest stress: two processes appending segments in a tight
    loop — one through the default reserve-then-append commit pair, one
    through single_commit — never drop a generation, lose an append, or
    collide on a segment id."""
    import subprocess
    import sys

    import repro

    path = str(tmp_path / "s")
    store = Store.create(path, 50)
    script = (
        "import sys\n"
        "import numpy as np\n"
        "from repro.store import Store\n"
        "store_dir, who, mode = sys.argv[1], int(sys.argv[2]), sys.argv[3]\n"
        "store = Store.open(store_dir)\n"
        "for k in range(6):\n"
        "    rows = [(who, np.array([10 + k], dtype=np.int64),\n"
        "             np.array([1], dtype=np.int64))]\n"
        "    store.add_segment_from_rows(\n"
        "        iter(rows), num_docs=1, source=f'stress-{who}-{k}',\n"
        "        single_commit=(mode == 'single'))\n"
    )
    src_root = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", script, path, str(who), mode], env=env
        )
        for who, mode in ((0, "two-commit"), (1, "single"))
    ]
    for p in procs:
        assert p.wait(timeout=180) == 0

    store = Store.open(path)
    names = store.segment_names
    assert len(names) == 12 and len(set(names)) == 12   # nothing lost
    ids = sorted(int(n.split("-")[1]) for n in names)
    assert store.manifest["next_seg_id"] == max(ids) + 1
    for name in names:                       # every committed dir exists
        assert os.path.isdir(os.path.join(path, name))
    # counts additive across all 12 appends: each writer hit 6 distinct pairs
    for who in (0, 1):
        for k in range(6):
            assert store.pair_count(who, 10 + k) == 1


# ------------------------------------- shared-handle thread safety / pending
def test_refresh_during_commit_never_drops_mutation(tmp_path):
    """One Store handle shared across threads (stream ingestor sealing
    while a compaction daemon polls refresh()): a refresh() landing
    between _commit's mutate and _save must not replace the manifest and
    silently drop the mutation. The handle mutex makes every commit's
    mark durable."""
    import threading

    store = Store.create(str(tmp_path / "s"), 50)
    stop = threading.Event()

    def refresher():
        while not stop.is_set():
            store.refresh()

    t = threading.Thread(target=refresher, daemon=True)
    t.start()
    try:
        for i in range(200):
            store._commit(lambda m, i=i: m.setdefault("ticks", []).append(i))
    finally:
        stop.set()
        t.join(timeout=10)
    store.refresh()
    assert store.manifest["ticks"] == list(range(200))


def test_stale_pending_dirs_swept_on_open(tmp_path):
    """A .pending-* dir from a SIGKILLed single_commit (dead pid) is
    garbage-collected by Store.open; a live writer's dir is left alone."""
    path = str(tmp_path / "s")
    Store.create(path, 50)
    dead = os.path.join(path, ".pending-999999999-abc")  # no such pid
    live = os.path.join(path, f".pending-{os.getpid()}-abc")
    os.makedirs(dead)
    os.makedirs(live)
    Store.open(path)
    assert not os.path.exists(dead)
    assert os.path.exists(live)


def test_aborted_single_commit_leaves_no_pending_dir(tmp_path):
    """An extra_mutate abort removes the pending segment dir immediately —
    repeated aborts (e.g. stream-cursor fence losses) must not accumulate
    orphan directories."""
    store = Store.create(str(tmp_path / "s"), 50)

    def boom(m):
        raise RuntimeError("fenced")

    for _ in range(3):
        with pytest.raises(RuntimeError, match="fenced"):
            store.add_segment_from_rows(
                iter([(0, np.array([1], np.int32), np.array([1], np.int64))]),
                num_docs=1,
                single_commit=True,
                extra_mutate=boom,
            )
    leftovers = [n for n in os.listdir(str(tmp_path / "s"))
                 if n.startswith(".pending-")]
    assert leftovers == []
