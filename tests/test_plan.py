"""The typed counting-plan API (core/specs.py + core/plan.py).

Golden auto-selection across small/medium/large collections, cost-model
monotonicity, CountJob validation, byte-identity of the count() compat shim
with the seed API, exactness of every sink policy against the dense oracle,
and the executor's checkpoint/resume on the spill path."""

import os

import numpy as np
import pytest

from repro.core.cooc import METHODS, count, count_to_store, dense_counts
from repro.core.oracle import brute_force_counts
from repro.core.plan import CountJob, Plan, PlanExecutor, Planner, execute_job
from repro.core.specs import REGISTRY, get_spec
from repro.core.types import DenseSink, FileSink, StatsSink
from repro.data.corpus import CollectionStats, synthetic_zipf_collection
from repro.data.preprocess import remap_df_descending


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(80, vocab=150, mean_len=14, seed=11)


@pytest.fixture(scope="module")
def oracle(coll):
    return brute_force_counts(coll)


# ---------------------------------------------------------------------------
# MethodSpec registry
# ---------------------------------------------------------------------------


def test_registry_covers_legacy_methods():
    assert set(METHODS) == set(REGISTRY)
    for name, spec in REGISTRY.items():
        assert spec.name == name
        assert METHODS[name] is spec.fn
        assert spec.kind in ("paper", "tpu", "hybrid")


def test_spec_param_validation():
    spec = get_spec("naive")
    assert spec.resolve_kwargs() == {"flush_pairs": 2_000_000}
    assert spec.resolve_kwargs({"flush_pairs": 7}) == {"flush_pairs": 7}
    with pytest.raises(TypeError):
        spec.validate_kwargs({"bogus": 1})
    with pytest.raises(TypeError):
        spec.validate_kwargs({"flush_pairs": "many"})
    with pytest.raises(TypeError):
        spec.validate_kwargs({"flush_pairs": True})  # bool is not an int here
    with pytest.raises(ValueError):
        spec.validate_kwargs({"flush_pairs": 0})
    # allow_none params accept their None default explicitly
    assert get_spec("list-blocks").resolve_kwargs({"block_size": None}) == {
        "block_size": None
    }


def test_count_shim_validates_and_matches_seed(coll, oracle):
    """count() must behave exactly like the seed entry point."""
    with pytest.raises(KeyError):
        count("no-such-method", coll)
    with pytest.raises(TypeError):
        count("list-scan", coll, StatsSink(), bogus=3)
    # identical results to calling the registered function directly
    for method in ["naive", "list-scan", "multi-scan"]:
        direct = DenseSink(coll.vocab_size)
        REGISTRY[method].fn(coll, direct)
        assert np.array_equal(dense_counts(method, coll), direct.mat)
        assert np.array_equal(direct.mat, oracle)


def test_count_shim_pair_file_byte_identical(tmp_path, coll):
    """FileSink output through the shim is byte-identical to the direct
    seed-style invocation."""
    p_shim = str(tmp_path / "shim.bin")
    p_direct = str(tmp_path / "direct.bin")
    with FileSink(p_shim) as sink:
        count("list-scan", coll, sink)
    direct = FileSink(p_direct)  # seed style: manual close
    REGISTRY["list-scan"].fn(coll, direct)
    direct.close()
    with open(p_shim, "rb") as a, open(p_direct, "rb") as b:
        assert a.read() == b.read()


# ---------------------------------------------------------------------------
# cost models + auto selection
# ---------------------------------------------------------------------------


def _stats(num_docs, vocab, mean_len, seed=5):
    c = synthetic_zipf_collection(num_docs, vocab=vocab, mean_len=mean_len, seed=seed)
    return CollectionStats.from_collection(c)


def _auto_pick(stats):
    ranked = sorted(
        (spec.cost(stats, spec.defaults()), name)
        for name, spec in REGISTRY.items()
        if spec.kind == "paper"
    )
    return ranked[0][1]


def test_auto_selection_golden_small_medium_large():
    """The paper's narrative: LIST-PAIRS wins at small scale, the
    block/scan family asymptotically — at least 3 distinct methods across
    the sweep (acceptance criterion)."""
    small = _stats(400, 64, 60)
    medium = _stats(1_500, 30_000, 40)
    large = _stats(40_000, 16_000, 50)
    picks = {
        "small": _auto_pick(small),
        "medium": _auto_pick(medium),
        "large": _auto_pick(large),
    }
    assert picks["small"] == "list-pairs"
    assert picks["medium"] == "list-blocks"
    assert picks["large"] == "list-scan"
    assert len(set(picks.values())) >= 3


def test_auto_selection_via_planner(coll):
    """End-to-end: Planner.rank on real CountJobs picks the golden methods."""
    small = synthetic_zipf_collection(400, vocab=64, mean_len=60, seed=5)
    medium = synthetic_zipf_collection(1_500, vocab=30_000, mean_len=40, seed=5)
    planner = Planner()
    picks = set()
    for c in (small, medium):
        plan = planner.plan(CountJob(collection=c, output="stats", method="auto"))
        picks.add(plan.method)
        assert plan.ranking[0][0] == plan.method
        # ranking is sorted best-first
        costs = [cost for _, cost in plan.ranking]
        assert costs == sorted(costs)
    assert picks == {"list-pairs", "list-blocks"}


def test_auto_never_picks_naive_or_tpu():
    """NAÏVE 'is indeed very slow' (abstract) — it must never win; TPU
    adaptations are explicit opt-ins."""
    for d, v, l in [(400, 64, 60), (1_500, 30_000, 40), (40_000, 16_000, 50)]:
        stats = _stats(d, v, l)
        assert _auto_pick(stats) != "naive"
    job = CountJob(
        collection=synthetic_zipf_collection(50, vocab=100, mean_len=10, seed=0),
        output="stats",
    )
    names = {s.name for s in Planner().candidates(job)}
    assert not any(REGISTRY[n].kind == "tpu" for n in names)
    assert "freq-split" not in names  # needs df-descending IDs


def test_freq_split_eligible_and_wins_when_df_descending():
    c = synthetic_zipf_collection(400, vocab=2_000, mean_len=40, seed=5)
    cd, _ = remap_df_descending(c)
    job = CountJob(collection=cd, output="stats", df_descending=True)
    names = {s.name for s in Planner().candidates(job)}
    assert "freq-split" in names
    # on a df-descending large collection the hybrid's model beats list-scan
    stats = _stats(40_000, 16_000, 50)
    fs = REGISTRY["freq-split"]
    ls = REGISTRY["list-scan"]
    assert fs.cost(stats, fs.defaults()) < ls.cost(stats, ls.defaults())


def test_cost_model_monotonic_in_docs():
    """More documents never gets cheaper (vocab fixed) — for every method."""
    full = synthetic_zipf_collection(4_000, vocab=8_000, mean_len=40, seed=7)
    prev: dict[str, float] = {}
    for n in (500, 1_000, 2_000, 4_000):
        stats = CollectionStats.from_collection(full.head(n))
        for name, spec in REGISTRY.items():
            cost = spec.cost(stats, spec.defaults())
            assert cost > 0
            if name in prev:
                assert cost >= prev[name], (name, n)
            prev[name] = cost


def test_collection_stats_df_distribution():
    c = synthetic_zipf_collection(300, vocab=4_000, mean_len=30, seed=3)
    s = CollectionStats.from_collection(c)
    df = np.bincount(c.terms, minlength=c.vocab_size)
    assert s.num_postings == c.num_postings
    assert s.live_vocab == int((df > 0).sum())
    assert s.df_rank_cum[-1] == c.num_postings
    # postings_in_top interpolates monotonically up to the full mass
    tops = [s.postings_in_top(h) for h in (0, 1, 10, 100, 1_000, 4_000, 10_000)]
    assert tops == sorted(tops)
    assert tops[0] == 0 and tops[-1] == c.num_postings
    assert s.postings_in_top(1) == int(df.max())


# ---------------------------------------------------------------------------
# CountJob validation
# ---------------------------------------------------------------------------


def test_count_job_validation(coll):
    good = CountJob(collection=coll, output="stats")
    assert good.method == "auto"
    with pytest.raises(ValueError):
        CountJob(collection="nope", output="stats")
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="matrix")
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="pairs-file")  # out_path missing
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="store")
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="stats", num_shards=0)
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="stats", memory_budget_pairs=0)
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="stats", method="no-such-method")
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="stats", method="freq-split")  # needs df order
    with pytest.raises(ValueError):
        CountJob(
            collection=coll, output="stats", method="naive",
            method_kwargs={"bogus": 1},
        )
    with pytest.raises(ValueError):
        CountJob(collection=coll, output="stats", method_kwargs={"head": 8})  # auto


# ---------------------------------------------------------------------------
# execution: every sink policy bit-exact vs the dense oracle
# ---------------------------------------------------------------------------


def test_plan_dense_output_exact(coll, oracle):
    res = execute_job(CountJob(collection=coll, output="dense", method="auto"))
    assert res.summary["exact"] is True
    assert np.array_equal(res.counts, oracle)
    assert res.summary["distinct_pairs"] == int((oracle > 0).sum())
    assert res.summary["total_count"] == int(oracle.sum())


def test_plan_spill_policy_exact(coll, oracle):
    """Forcing the spill policy (tiny dense cap, several shards, tiny memory
    budget → many runs) must still merge bit-exactly."""
    job = CountJob(
        collection=coll, output="stats", method="list-scan",
        dense_vocab_cap=1, num_shards=4, memory_budget_pairs=64,
    )
    plan = Planner().plan(job)
    assert plan.sink_policy == "spill"
    res = plan.execute()
    assert res.summary["exact"] is True
    assert res.summary["distinct_pairs"] == int((oracle > 0).sum())
    assert res.summary["total_count"] == int(oracle.sum())


def test_plan_pairs_file_spill_matches_dense(tmp_path, coll):
    """pairs.bin written through the spill merge is byte-identical to the
    dense-merge file."""
    p_dense = str(tmp_path / "dense.bin")
    p_spill = str(tmp_path / "spill.bin")
    execute_job(
        CountJob(collection=coll, output="pairs-file", method="list-scan",
                 out_path=p_dense)
    )
    execute_job(
        CountJob(collection=coll, output="pairs-file", method="list-scan",
                 out_path=p_spill, dense_vocab_cap=1, num_shards=3,
                 memory_budget_pairs=128)
    )
    with open(p_dense, "rb") as a, open(p_spill, "rb") as b:
        assert a.read() == b.read()


def test_plan_store_output(tmp_path, coll, oracle):
    res = execute_job(
        CountJob(collection=coll, output="store", method="auto",
                 out_path=str(tmp_path / "store"), dense_vocab_cap=1,
                 num_shards=2)
    )
    assert res.store is not None and res.segment is not None
    assert np.array_equal(res.store.dense(), oracle)
    assert res.summary["distinct_pairs"] == int((oracle > 0).sum())


def test_plan_stats_inexact_optout(coll, oracle):
    """exact=False is the only way to get the old upper-bound behavior, and
    it is labelled as such."""
    job = CountJob(
        collection=coll, output="stats", method="list-scan", exact=False,
        dense_vocab_cap=1, num_shards=3,
    )
    plan = Planner().plan(job)
    assert plan.sink_policy == "stats" and plan.exact is False
    res = plan.execute()
    assert res.summary["exact"] is False
    assert "distinct_pairs" not in res.summary  # no exact claim
    assert res.summary["distinct_pairs_upper_bound"] >= int((oracle > 0).sum())
    assert res.summary["total_count"] == int(oracle.sum())  # additive → exact


def test_every_paper_method_exact_through_spill_plan(coll, oracle):
    """Cross product: each paper method through the spill executor stays
    bit-exact (the plan layer must not perturb any method's output)."""
    for method in ("naive", "list-pairs", "list-blocks", "list-scan", "multi-scan"):
        res = execute_job(
            CountJob(collection=coll, output="stats", method=method,
                     dense_vocab_cap=1, num_shards=2, memory_budget_pairs=256)
        )
        assert res.summary["distinct_pairs"] == int((oracle > 0).sum()), method
        assert res.summary["total_count"] == int(oracle.sum()), method


def test_executor_resume_spill(tmp_path, coll, oracle):
    """Kill-resume on the spill path: completed shards' run files are reused,
    remaining shards recounted, totals unchanged."""
    out = str(tmp_path / "run")
    job = CountJob(
        collection=coll, output="stats", method="list-scan",
        dense_vocab_cap=1, num_shards=6, memory_budget_pairs=128,
    )
    plan = Planner().plan(job)
    res = plan.execute(out_dir=out, ckpt_every=2)
    assert res.summary["total_count"] == int(oracle.sum())
    # simulate a restart after completion: resume must not double-count
    res2 = plan.execute(out_dir=out, ckpt_every=2, resume=True)
    assert res2.summary["total_count"] == int(oracle.sum())
    assert res2.summary["distinct_pairs"] == int((oracle > 0).sum())


def test_executor_fresh_run_ignores_stale_spill_dirs(tmp_path, coll, oracle):
    """Re-running (without resume) into an out_dir that a previous run with
    MORE shards populated must not fold the stale runs into the merge."""
    out = str(tmp_path / "run")
    mk = lambda shards: CountJob(
        collection=coll, output="stats", method="list-scan",
        dense_vocab_cap=1, num_shards=shards, memory_budget_pairs=128,
    )
    res8 = execute_job(mk(8), out_dir=out)
    res3 = execute_job(mk(3), out_dir=out)  # fewer shards, same out_dir
    assert res8.summary["total_count"] == int(oracle.sum())
    assert res3.summary["total_count"] == int(oracle.sum())
    assert res3.summary["distinct_pairs"] == int((oracle > 0).sum())


def test_append_collection_auto_rejects_kwargs(tmp_path, coll):
    from repro.store import Store

    store = Store.create(str(tmp_path / "s"), coll.vocab_size)
    with pytest.raises(ValueError):
        store.append_collection(coll, method="auto", head=512)


def test_count_to_store_auto(tmp_path, coll, oracle):
    store, seg = count_to_store("auto", coll, str(tmp_path / "s"))
    assert seg.meta["source"].startswith("plan:")
    assert np.array_equal(store.dense(), oracle)


# ---------------------------------------------------------------------------
# sinks as context managers
# ---------------------------------------------------------------------------


def test_file_sink_context_manager(tmp_path, coll):
    path = str(tmp_path / "pairs.bin")
    with FileSink(path) as sink:
        count("list-scan", coll, sink)
        assert not sink.f.closed
    assert sink.f.closed


def test_spill_sink_context_manager_cleans_up(coll):
    from repro.store.builder import SpillSink

    with SpillSink(coll.vocab_size, memory_budget_pairs=64) as sink:
        count("list-scan", coll, sink)
        spill_dir = sink.spill_dir
        assert sink.runs  # tiny budget → must have spilled
    assert not os.path.isdir(spill_dir)  # closed (and owned dir removed)

    # on error paths too
    with pytest.raises(RuntimeError):
        with SpillSink(coll.vocab_size, memory_budget_pairs=64) as sink:
            spill_dir = sink.spill_dir
            raise RuntimeError("boom")
    assert not os.path.isdir(spill_dir)
