"""Expert-parallel MoE (shard_map) ≡ single-device reference — subprocess
with 8 placeholder devices."""

import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.models.layers import init_moe, moe_ffn, moe_ffn_ep

    E, k, d, eff, B, S = 8, 2, 32, 16, 4, 16
    key = jax.random.PRNGKey(0)
    params = init_moe(key, d, eff, E, 1, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))

    # single-device reference (big capacity → no drops → paths comparable)
    y_ref, aux_ref = moe_ffn(params, x, n_experts=E, top_k=k,
                             capacity_factor=float(E), expert_kind="swiglu")

    from repro.runtime.sharding import set_mesh_compat as set_mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    fn = lambda p, xx: moe_ffn_ep(p, xx, n_experts=E, top_k=k,
                                  capacity_factor=float(E), expert_kind="swiglu")
    with set_mesh(mesh):
        y_ep, aux_ep = jax.jit(fn)(params, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), atol=2e-5, rtol=2e-5)
    # aux is averaged PER DATA SHARD in the EP path (standard Switch/GShard
    # practice) vs global-batch in the reference → small semantic difference
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=0.15)

    # gradients must also agree (shard_map psum transpose correctness).
    # Both losses touch aux with coefficient 0 so its cotangent is an
    # instantiated zero — old shard_map releases reject symbolic Zero
    # cotangents in transpose; the gradients are unchanged.
    def loss_ref(p):
        y, aux = moe_ffn(p, x, n_experts=E, top_k=k,
                         capacity_factor=float(E), expert_kind="swiglu")
        return jnp.sum(y ** 2) + 0.0 * aux
    def loss_ep(p):
        y, aux = fn(p, x)
        return jnp.sum(y ** 2) + 0.0 * aux
    g_ref = jax.grad(loss_ref)(params)
    with set_mesh(mesh):
        g_ep = jax.jit(jax.grad(loss_ep))(params)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=3e-4, rtol=3e-3
        ),
        g_ep, g_ref,
    )
    # B=1 (replicated-batch) path: decode shapes with batch < mesh extent
    x1 = x[:1]
    with set_mesh(mesh):
        y1, _ = jax.jit(fn)(params, x1)
    y1_ref, _ = moe_ffn(params, x1, n_experts=E, top_k=k,
                        capacity_factor=float(E), expert_kind="swiglu")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y1_ref), atol=2e-5, rtol=2e-5)
    print("OK")
    """
)


def test_moe_ep_matches_reference():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True,
        cwd=__file__.rsplit("/", 2)[0], timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout
