"""Multi-client serving layer: coalescing correctness (unit level, no
processes) and the real thing — spawned shared-mmap workers serving
concurrent client threads with results identical to a direct QueryEngine,
plus hot-term routing, streaming top-k, and cross-process store mutation."""

import queue
import threading

import numpy as np
import pytest

from repro.core.cooc import count_to_store
from repro.core.oracle import brute_force_counts
from repro.data.corpus import synthetic_zipf_collection
from repro.store import (
    CoocServer,
    NeighboursRequest,
    PairCountsRequest,
    QueryEngine,
    ServingConfig,
    Store,
    TopKRequest,
)
from repro.store.serving import _STAT_KEYS, _serve_batch


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(150, vocab=128, mean_len=12, seed=7)


@pytest.fixture(scope="module")
def store_path(coll, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serving") / "store")
    count_to_store("list-scan", coll, path)
    return path


# ----------------------------------------------------------- config
def test_serving_config_validation():
    with pytest.raises(ValueError, match="workers"):
        ServingConfig(workers=0)
    with pytest.raises(ValueError, match="max_batch"):
        ServingConfig(max_batch=0)
    with pytest.raises(ValueError, match="batch_window_ms"):
        ServingConfig(batch_window_ms=-1.0)
    with pytest.raises(ValueError, match="stats_interval_s"):
        ServingConfig(stats_interval_s=-0.5)


# ------------------------------------------------- batch coalescing (unit)
def test_serve_batch_coalesces_and_splits(store_path, coll):
    """One micro-batch of typed request envelopes: per-(k, score) topk
    groups and all pair lookups each become a single launch, and every
    client gets exactly its slice back."""
    engine = QueryEngine(Store.open(store_path))
    out = queue.Queue()
    stats = {k: 0 for k in _STAT_KEYS}
    batch = [
        (0, 0, 0, 1, TopKRequest(np.array([1, 2]), k=4)),
        (1, 0, 0, 1, TopKRequest(np.array([3]), k=4)),    # coalesces with above
        (0, 1, 0, 1, TopKRequest(np.array([5]), k=2, score="pmi")),
        (1, 1, 0, 1, PairCountsRequest(np.array([[1, 2], [3, 4]]))),
        (0, 2, 0, 1, PairCountsRequest(np.array([[5, 6]]))),
        (1, 2, 0, 1, TopKRequest(np.array([999]), k=4)),  # out-of-vocab -> error
    ]
    _serve_batch(engine, batch, out, worker_id=0, stats=stats)
    assert stats["topk_launches"] == 2          # (4, count) + (2, pmi)
    assert stats["pair_launches"] == 1
    assert stats["topk_queries"] == 4 and stats["pair_queries"] == 3
    assert stats["requests"] == 6 and stats["batches"] == 1

    got = {}
    while not out.empty():
        cid, rid, part, parts, seq, last, ok, payload, meta = out.get()
        assert (part, parts, seq, last) == (0, 1, 0, True)
        got[(cid, rid)] = (ok, payload, meta)
    assert len(got) == 6
    err_kind, err_msg = got[(1, 2)][1]
    assert got[(1, 2)][0] is False and err_kind == "value_error"
    assert "out-of-vocab" in err_msg

    ref = QueryEngine(engine.store)
    ids, scores = ref.topk(np.array([1, 2, 3]), k=4)
    ok, (ids01, s01), meta = got[(0, 0)]
    assert ok and meta["coalesced_requests"] == 2
    np.testing.assert_array_equal(ids01, ids[:2])
    ok, (ids10, _), _ = got[(1, 0)]
    np.testing.assert_array_equal(ids10, ids[2:])
    np.testing.assert_array_equal(
        got[(1, 1)][1], ref.pair_counts(np.array([[1, 2], [3, 4]]))
    )
    np.testing.assert_array_equal(
        got[(0, 2)][1], ref.pair_counts(np.array([[5, 6]]))
    )


def test_serve_batch_streams_and_neighbours(store_path):
    engine = QueryEngine(Store.open(store_path))
    out = queue.Queue()
    stats = {k: 0 for k in _STAT_KEYS}
    batch = [
        (0, 0, 0, 1, TopKRequest(np.array([1]), k=10, chunk=4)),
        (0, 1, 0, 1, NeighboursRequest(2)),
    ]
    _serve_batch(engine, batch, out, worker_id=0, stats=stats)
    assert stats["stream_chunks"] == 3 and stats["neighbours_queries"] == 1
    msgs = []
    while not out.empty():
        msgs.append(out.get())
    chunks = sorted(
        [m for m in msgs if m[1] == 0], key=lambda m: m[4]
    )  # by seq
    assert [m[5] for m in chunks] == [False, False, True]  # last flags
    ids = np.concatenate([m[7][0] for m in chunks], axis=1)
    ref_ids, _ = QueryEngine(engine.store).topk([1], k=10)
    np.testing.assert_array_equal(ids, ref_ids)
    (nmsg,) = [m for m in msgs if m[1] == 1]
    np.testing.assert_array_equal(
        nmsg[7][0], QueryEngine(engine.store).neighbours(2)[0]
    )


def test_serve_batch_survives_unexpected_error(store_path):
    """A non-ValueError mid-batch (e.g. a segment racing a parent compact)
    must produce error responses for the unanswered requests, not kill the
    worker with clients blocked until timeout."""
    engine = QueryEngine(Store.open(store_path))
    out = queue.Queue()
    stats = {k: 0 for k in _STAT_KEYS}

    def boom(pairs):
        raise OSError("segment vanished")

    engine.store.pair_counts = boom
    batch = [
        (0, 0, 0, 1, TopKRequest(np.array([1]), k=3)),
        (0, 1, 0, 1, PairCountsRequest(np.array([[1, 2]]))),
    ]
    _serve_batch(engine, batch, out, worker_id=0, stats=stats)
    msgs = {}
    while not out.empty():
        cid, rid, part, parts, seq, last, ok, payload, meta = out.get()
        msgs[rid] = (ok, payload)
    assert msgs[0][0] is True                     # the earlier group answered
    ok, (kind, message) = msgs[1]
    assert ok is False and kind == "serving_error"
    assert "segment vanished" in message


# --------------------------------------------------- end-to-end (processes)
def test_server_multi_client_matches_engine(store_path, coll):
    """>1 client served against shared mmap segments with batched execution:
    every served result equals the direct QueryEngine answer."""
    oracle = brute_force_counts(coll)
    sym = oracle + oracle.T
    ref = QueryEngine(Store.open(store_path))
    n_clients, reqs_per_client = 3, 6
    errors, metas = [], []

    with CoocServer(store_path, workers=2, batch_window_ms=5.0) as server:
        def client_loop(idx):
            try:
                client = server.client()
                rng = np.random.default_rng(100 + idx)
                for _ in range(reqs_per_client):
                    terms = rng.integers(0, coll.vocab_size, size=8)
                    ids, scores = client.topk(terms, k=5)
                    rids, rscores = ref.topk(terms, k=5)
                    np.testing.assert_array_equal(ids, rids)
                    np.testing.assert_array_equal(scores, rscores)
                    for b, t in enumerate(terms):  # and against the oracle
                        for i, s in zip(ids[b], scores[b]):
                            if i >= 0:
                                assert sym[t][i] == s
                    metas.append(client.last_meta)
                    pairs = rng.integers(0, coll.vocab_size, size=(6, 2))
                    np.testing.assert_array_equal(
                        client.pair_counts(pairs), ref.pair_counts(pairs)
                    )
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)

        threads = [
            threading.Thread(target=client_loop, args=(i,))
            for i in range(n_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
    assert not errors, errors

    stats = server.stats()
    assert stats["workers"] == 2 and stats["routing"] is False
    assert stats["requests"] == n_clients * reqs_per_client * 2
    assert stats["topk_queries"] == n_clients * reqs_per_client * 8
    assert stats["pair_queries"] == n_clients * reqs_per_client * 6
    assert stats["batches"] >= 1
    assert stats["cache_hits"] + stats["cache_misses"] > 0
    assert 0.0 <= stats["cache_hit_rate"] <= 1.0
    assert len(stats["per_worker"]) == 2
    assert metas and all("worker" in m for m in metas)


def test_server_routed_matches_engine_and_partitions_caches(store_path, coll):
    """Hot-term routing: results stay byte-identical to the direct engine
    (split requests reassemble exactly), every worker's cache holds only
    terms it owns, and on a Zipf-skewed workload the aggregate hit rate
    beats the unrouted baseline with the same undersized LRU."""
    ref = QueryEngine(Store.open(store_path))
    V = coll.vocab_size
    # Zipf-skewed draws: the hot head is much larger than cache_rows=8
    rng = np.random.default_rng(3)
    probs = (1.0 / np.arange(1, V + 1)) ** 1.0
    probs /= probs.sum()
    workload = [rng.choice(V, size=16, p=probs) for _ in range(60)]
    pair_load = [rng.choice(V, size=(4, 2), p=None) for _ in range(10)]

    hit_rates = {}
    for routing in (False, True):
        with CoocServer(
            store_path, workers=2, batch_window_ms=1.0,
            cache_rows=8, routing=routing,
        ) as server:
            client = server.client()
            for terms in workload:
                ids, scores = client.topk(terms, k=5, score="pmi")
                rids, rscores = ref.topk(terms, k=5, score="pmi")
                np.testing.assert_array_equal(ids, rids)
                np.testing.assert_array_equal(scores, rscores)
            for pairs in pair_load:
                np.testing.assert_array_equal(
                    client.pair_counts(pairs), ref.pair_counts(pairs)
                )
            nids, ncnts = client.neighbours(1)
            np.testing.assert_array_equal(nids, ref.neighbours(1)[0])
        hit_rates[routing] = server.stats()["cache_hit_rate"]
        assert server.stats()["routing"] is routing
    assert hit_rates[True] > hit_rates[False], hit_rates


def test_server_streaming_topk(store_path):
    ref = QueryEngine(Store.open(store_path))
    with CoocServer(store_path, workers=2, batch_window_ms=1.0,
                    routing=True) as server:
        client = server.client()
        chunks = list(client.topk_stream([1, 2, 3], k=23, chunk=8))
        mono_ids, mono_scores = ref.topk([1, 2, 3], k=23)
        assert [c[0].shape[1] for c in chunks] == [8, 8, 7]
        np.testing.assert_array_equal(
            np.concatenate([c[0] for c in chunks], axis=1), mono_ids)
        np.testing.assert_array_equal(
            np.concatenate([c[1] for c in chunks], axis=1), mono_scores)
        # interleave: a monolithic request while a stream is half-consumed
        stream = client.topk_stream([5], k=9, chunk=3)
        first = next(stream)
        ids, _ = client.topk([7], k=4)
        np.testing.assert_array_equal(ids, ref.topk([7], k=4)[0])
        rest = list(stream)
        sids = np.concatenate([first[0]] + [c[0] for c in rest], axis=1)
        np.testing.assert_array_equal(sids, ref.topk([5], k=9)[0])


def test_server_sees_parent_store_mutation(coll, tmp_path):
    """Satellite: cache invalidation under mutation, through serving
    workers — a parent-process append/compact becomes visible to in-flight
    serving traffic via Store.refresh() between micro-batches."""
    path = str(tmp_path / "mut_store")
    store, _ = count_to_store("list-scan", coll, path)
    with CoocServer(path, workers=2, batch_window_ms=1.0) as server:
        client = server.client()
        before = client.pair_counts(np.array([[1, 2]]))[0]
        tids, tscores = client.topk([1], k=4)
        store.append_collection(coll, method="list-scan")  # counts double
        after = client.pair_counts(np.array([[1, 2]]))[0]
        assert after == 2 * before
        store.compact()                                    # counts unchanged
        assert client.pair_counts(np.array([[1, 2]]))[0] == after
        ids, scores = client.topk([1], k=4)
        ref = QueryEngine(Store.open(path))
        np.testing.assert_array_equal(ids, ref.topk([1], k=4)[0])
        np.testing.assert_array_equal(scores, ref.topk([1], k=4)[1])
        assert np.all(scores[tscores >= 0] >= tscores[tscores >= 0])
    assert sum(w["store_refreshes"] for w in server.stats()["per_worker"]) >= 1


def test_server_error_propagation_and_restart_guard(store_path):
    with CoocServer(store_path, workers=1, batch_window_ms=0.0) as server:
        client = server.client()
        with pytest.raises(ValueError, match="out-of-vocab"):
            client.topk([10_000], k=3)
        with pytest.raises(ValueError, match="out-of-vocab"):
            client.pair_counts(np.array([[0, -2]]))
        with pytest.raises(ValueError, match="out-of-vocab"):
            client.neighbours(10_000)
        # healthy after an error response
        ids, _ = client.topk([1], k=3)
        assert ids.shape == (1, 3)
        with pytest.raises(RuntimeError, match="already started"):
            server.start()


def test_client_rejects_invalid_requests_before_submit(store_path):
    """Satellite: an unknown score (or bad k/dtype) fails at request
    construction on the client — no envelope ever reaches a worker."""
    with CoocServer(store_path, workers=1, batch_window_ms=0.0) as server:
        client = server.client()
        with pytest.raises(ValueError, match="unknown score"):
            client.topk([1], k=3, score="bogus")
        with pytest.raises(ValueError, match="k must be"):
            client.topk([1], k=0)
        with pytest.raises(ValueError, match="integer term ids"):
            client.topk(np.array([1.5]), k=3)
        ids, _ = client.topk([1], k=3)  # server healthy, nothing poisoned
        assert ids.shape == (1, 3)
    # the invalid requests never became envelopes: exactly one served
    assert server.stats()["requests"] == 1


def test_client_buffers_bounded_after_errors_and_dropped_streams(store_path):
    """A failed routed request or an abandoned stream must not leave the
    client buffering its late-arriving sibling messages forever."""
    import time as _time

    with CoocServer(store_path, workers=2, batch_window_ms=1.0,
                    routing=True) as server:
        client = server.client()
        # split across both workers; the OOV slice fails, the other succeeds
        terms = np.concatenate([np.arange(16), [10_000]])
        with pytest.raises(ValueError, match="out-of-vocab"):
            client.topk(terms, k=3)
        # abandon a stream after the first chunk
        stream = client.topk_stream(np.arange(8), k=30, chunk=4)
        next(stream)
        stream.close()
        # drop a stream before the first next(): __del__ must clean up
        never_started = client.topk_stream(np.arange(4), k=20, chunk=4)
        del never_started
        # multi-request batch where the first request fails: the submitted
        # sibling must be abandoned, not buffered forever
        with pytest.raises(ValueError, match="out-of-vocab"):
            client.execute([
                TopKRequest([10_000], k=3),
                PairCountsRequest(np.array([[1, 2]])),
            ])
        # keep serving; the dead-rid messages drain instead of accumulating
        deadline = _time.monotonic() + 30
        while (client._msgs or client._discard) and _time.monotonic() < deadline:
            np.testing.assert_array_equal(
                client.pair_counts(np.array([[1, 2]])),
                QueryEngine(Store.open(store_path)).pair_counts(np.array([[1, 2]])),
            )
            _time.sleep(0.02)
        assert not client._msgs and not client._discard
        assert not client._positions
        ids, _ = client.topk(np.arange(8), k=3)
        assert ids.shape == (8, 3)


# --------------------------------------------------- telemetry (satellites)
def test_server_stats_include_server_side_timing(store_path):
    """Satellite: percentiles must exist on the server side of the queue —
    queue-wait, execute, and total request latency come from worker
    histograms merged across processes, not client wall clocks."""
    with CoocServer(store_path, workers=2, batch_window_ms=1.0) as server:
        client = server.client()
        for _ in range(10):
            client.topk([1, 2, 3], k=5)
    stats = server.stats()
    timing = stats["server_timing"]
    assert set(timing) == {"queue_wait_ms", "execute_ms", "request_latency_ms"}
    # every served request was measured, and latency >= its queue-wait share
    assert timing["queue_wait_ms"]["count"] == stats["requests"] == 10
    assert timing["request_latency_ms"]["count"] == 10
    assert timing["execute_ms"]["count"] == stats["batches"]
    for d in timing.values():
        assert d["p50"] <= d["p95"] <= d["p99"]
        assert d["mean"] > 0
    assert timing["request_latency_ms"]["p50"] >= timing["queue_wait_ms"]["p50"]
    # the merged raw metrics snapshot travels too (for prometheus export)
    hists = stats["metrics"]["histograms"]
    assert "serving/queue_wait_s" in hists and "serving/execute_s" in hists
    assert stats["workers_lost"] == 0


def test_server_live_stats_with_periodic_snapshots(store_path):
    """stats() on a *running* server merges the freshest periodic snapshot
    from each worker (stats_interval_s), without stopping anything."""
    import time as _time

    with CoocServer(
        store_path, workers=2, batch_window_ms=1.0, stats_interval_s=0.05
    ) as server:
        client = server.client()
        for _ in range(8):
            client.topk([1, 2], k=4)
        deadline = _time.monotonic() + 30
        live = server.stats()
        while live["requests"] < 8 and _time.monotonic() < deadline:
            _time.sleep(0.05)
            live = server.stats()
        assert live["live"] is True
        assert live["requests"] == 8
        assert live["server_timing"]["queue_wait_ms"]["count"] == 8
        ids, _ = client.topk([3], k=4)  # still serving
        assert ids.shape == (1, 4)
    final = server.stats()
    assert final["live"] is False and final["requests"] == 9


def test_server_counts_lost_workers_not_silent(store_path):
    """Satellite: a worker that dies without a final snapshot must be
    *counted*, not silently dropped from the stats — its last periodic
    snapshot stands in for its traffic. Routed mode: each worker owns its
    own request queue, so killing one never wedges the survivor.
    ``max_respawns=0`` pins supervision off so the death stays a loss —
    with a respawn budget the replacement would report a final snapshot
    and the slot would not count as lost."""
    import os as _os
    import signal as _signal
    import time as _time

    with CoocServer(
        store_path, workers=2, batch_window_ms=1.0,
        routing=True, stats_interval_s=0.05, max_respawns=0,
    ) as server:
        client = server.client()
        for _ in range(10):
            client.topk(np.arange(16), k=4)  # splits across both workers
        # let both workers publish a periodic snapshot covering the traffic
        deadline = _time.monotonic() + 30
        while (
            server.stats()["requests"] < 20 and _time.monotonic() < deadline
        ):
            _time.sleep(0.05)
        assert server.stats()["requests"] == 20
        victim = server._procs[0]
        _os.kill(victim.pid, _signal.SIGKILL)
        victim.join(timeout=30)
    stats = server.stats()
    assert stats["workers_lost"] == 1
    # the victim's periodic snapshot stood in: no requests went missing
    assert stats["requests"] == 20
    assert stats["server_timing"]["queue_wait_ms"]["count"] == 20
    assert len(stats["per_worker"]) == 2


def test_server_rejects_bad_args(store_path, tmp_path):
    with pytest.raises(FileNotFoundError):
        CoocServer(str(tmp_path / "nope"))
    with pytest.raises(ValueError, match="unknown kernel"):
        CoocServer(store_path, kernel="cuda")


# ------------------------------------------- compaction under live serving
def test_server_serves_through_background_compaction(coll, tmp_path):
    """Satellite (ISSUE 7): workers keep answering, byte-identically, while
    a background process compacts the v2 segments out from under their
    mmaps, and pick the merged segment up via their between-batch refresh.
    The server stats surface the codec counters the workers accumulated."""
    from repro.data.preprocess import shard_documents

    path = str(tmp_path / "store")
    store = Store.create(path, coll.vocab_size, segment_version=2)
    for shard in shard_documents(coll, 3):
        store.append_collection(shard, method="list-scan")

    server = CoocServer(path, workers=2, batch_window_ms=1.0).start()
    try:
        client = server.client()
        rng = np.random.default_rng(29)
        terms = rng.integers(0, coll.vocab_size, size=24)
        before = client.topk(terms, k=6, score="pmi")
        handle = store.compact_background(names=store.segment_names)
        assert handle is not None
        while handle.alive():                     # serve through the merge
            client.topk(rng.integers(0, coll.vocab_size, size=24), k=6)
        res = handle.join(timeout=120)
        assert len(res["merged"]) == 3
        after = client.topk(terms, k=6, score="pmi")
        assert before[0].tobytes() == after[0].tobytes()
        assert before[1].tobytes() == after[1].tobytes()
        pairs = rng.integers(0, coll.vocab_size, size=(64, 2))
        want = QueryEngine(Store.open(path)).pair_counts(pairs)
        np.testing.assert_array_equal(client.pair_counts(pairs), want)
    finally:
        stats = server.stop()
    assert stats["workers_lost"] == 0
    assert stats["storage"]["blocks_decoded"] > 0
    assert 0.0 <= stats["storage"]["block_cache_hit_rate"] <= 1.0
    store.refresh()
    assert len(store.segment_names) == 1
