"""Multi-client serving layer: coalescing correctness (unit level, no
processes) and the real thing — spawned shared-mmap workers serving
concurrent client threads with results identical to a direct QueryEngine."""

import queue
import threading

import numpy as np
import pytest

from repro.core.cooc import count_to_store
from repro.core.oracle import brute_force_counts
from repro.data.corpus import synthetic_zipf_collection
from repro.store import CoocServer, QueryEngine, ServingConfig, Store
from repro.store.serving import _serve_batch


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(150, vocab=128, mean_len=12, seed=7)


@pytest.fixture(scope="module")
def store_path(coll, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("serving") / "store")
    count_to_store("list-scan", coll, path)
    return path


# ----------------------------------------------------------- config
def test_serving_config_validation():
    with pytest.raises(ValueError, match="workers"):
        ServingConfig(workers=0)
    with pytest.raises(ValueError, match="max_batch"):
        ServingConfig(max_batch=0)
    with pytest.raises(ValueError, match="batch_window_ms"):
        ServingConfig(batch_window_ms=-1.0)


# ------------------------------------------------- batch coalescing (unit)
def test_serve_batch_coalesces_and_splits(store_path, coll):
    """One micro-batch with mixed requests: per-(k, score) topk groups and
    all pair lookups each become a single launch, and every client gets
    exactly its slice back."""
    engine = QueryEngine(Store.open(store_path))
    out = queue.Queue()
    stats = {k: 0 for k in (
        "requests", "batches", "max_batch_requests",
        "topk_queries", "topk_launches", "pair_queries", "pair_launches",
    )}
    batch = [
        ("topk", 0, 0, np.array([1, 2]), 4, "count"),
        ("topk", 1, 0, np.array([3]), 4, "count"),      # coalesces with above
        ("topk", 0, 1, np.array([5]), 2, "pmi"),        # different group
        ("pairs", 1, 1, np.array([[1, 2], [3, 4]])),
        ("pairs", 0, 2, np.array([[5, 6]])),
        ("topk", 1, 2, np.array([999]), 4, "count"),    # out-of-vocab -> error
    ]
    _serve_batch(engine, batch, out, worker_id=0, stats=stats)
    assert stats["topk_launches"] == 2          # (4, count) + (2, pmi)
    assert stats["pair_launches"] == 1
    assert stats["topk_queries"] == 4 and stats["pair_queries"] == 3
    assert stats["requests"] == 6 and stats["batches"] == 1

    got = {}
    while not out.empty():
        cid, rid, ok, payload, meta = out.get()
        got[(cid, rid)] = (ok, payload, meta)
    assert len(got) == 6
    err_kind, err_msg = got[(1, 2)][1]
    assert got[(1, 2)][0] is False and err_kind == "value_error"
    assert "out-of-vocab" in err_msg

    ref = QueryEngine(engine.store)
    ids, scores = ref.topk(np.array([1, 2, 3]), k=4)
    ok, (ids01, s01), meta = got[(0, 0)]
    assert ok and meta["coalesced_requests"] == 2
    np.testing.assert_array_equal(ids01, ids[:2])
    ok, (ids10, _), _ = got[(1, 0)]
    np.testing.assert_array_equal(ids10, ids[2:])
    np.testing.assert_array_equal(
        got[(1, 1)][1], ref.pair_counts(np.array([[1, 2], [3, 4]]))
    )
    np.testing.assert_array_equal(
        got[(0, 2)][1], ref.pair_counts(np.array([[5, 6]]))
    )


# --------------------------------------------------- end-to-end (processes)
def test_server_multi_client_matches_engine(store_path, coll):
    """>1 client served against shared mmap segments with batched execution:
    every served result equals the direct QueryEngine answer."""
    oracle = brute_force_counts(coll)
    sym = oracle + oracle.T
    ref = QueryEngine(Store.open(store_path))
    n_clients, reqs_per_client = 3, 6
    errors, metas = [], []

    with CoocServer(store_path, workers=2, batch_window_ms=5.0) as server:
        def client_loop(idx):
            try:
                client = server.client()
                rng = np.random.default_rng(100 + idx)
                for _ in range(reqs_per_client):
                    terms = rng.integers(0, coll.vocab_size, size=8)
                    ids, scores = client.topk(terms, k=5)
                    rids, rscores = ref.topk(terms, k=5)
                    np.testing.assert_array_equal(ids, rids)
                    np.testing.assert_array_equal(scores, rscores)
                    for b, t in enumerate(terms):  # and against the oracle
                        for i, s in zip(ids[b], scores[b]):
                            if i >= 0:
                                assert sym[t][i] == s
                    metas.append(client.last_meta)
                    pairs = rng.integers(0, coll.vocab_size, size=(6, 2))
                    np.testing.assert_array_equal(
                        client.pair_counts(pairs), ref.pair_counts(pairs)
                    )
            except Exception as e:  # pragma: no cover - surfaced below
                errors.append(e)

        threads = [
            threading.Thread(target=client_loop, args=(i,))
            for i in range(n_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=180)
    assert not errors, errors

    stats = server.stats
    assert stats["workers"] == 2
    assert stats["requests"] == n_clients * reqs_per_client * 2
    assert stats["topk_queries"] == n_clients * reqs_per_client * 8
    assert stats["pair_queries"] == n_clients * reqs_per_client * 6
    assert stats["batches"] >= 1
    assert stats["cache_hits"] + stats["cache_misses"] > 0
    assert len(stats["per_worker"]) == 2
    assert metas and all("worker" in m for m in metas)


def test_server_error_propagation_and_restart_guard(store_path):
    with CoocServer(store_path, workers=1, batch_window_ms=0.0) as server:
        client = server.client()
        with pytest.raises(ValueError, match="out-of-vocab"):
            client.topk([10_000], k=3)
        with pytest.raises(ValueError, match="out-of-vocab"):
            client.pair_counts(np.array([[0, -2]]))
        # healthy after an error response
        ids, _ = client.topk([1], k=3)
        assert ids.shape == (1, 3)
        with pytest.raises(RuntimeError, match="already started"):
            server.start()


def test_server_rejects_bad_args(store_path, tmp_path):
    with pytest.raises(FileNotFoundError):
        CoocServer(str(tmp_path / "nope"))
    with pytest.raises(ValueError, match="unknown kernel"):
        CoocServer(store_path, kernel="cuda")
