"""Launch-layer integration: sharding rules lower+compile on a small mesh
(subprocess, 8 placeholder devices), end-to-end cooc driver with resume,
roofline HLO parser, serve driver."""

import json
import subprocess
import sys
import textwrap

import numpy as np

SMALL_MESH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_spec
    from repro.launch.train import make_lm_train_step, pick_optimizer, opt_state_specs
    from repro.models import transformer as T
    from repro.runtime.sharding import lm_param_specs
    from repro.launch.specs import _attach, _sds

    from repro.runtime.sharding import set_mesh_compat as set_mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    for arch in ["olmoe-1b-7b", "minicpm3-4b"]:
        cfg = dataclasses.replace(get_spec(arch).smoke(), remat=True)
        shapes_tree = T.param_shapes(cfg)
        specs = lm_param_specs(shapes_tree, mesh)
        params = jax.eval_shape(lambda c=cfg: T.init_params(jax.random.PRNGKey(0), c))
        params_sds = _attach(params, specs, mesh)
        opt, opt_name = pick_optimizer(cfg.num_params())
        ostate = _attach(jax.eval_shape(opt.init, params_sds),
                         opt_state_specs(opt_name, specs, shapes_tree), mesh)
        tokens = _sds((8, 32), jnp.int32, mesh, P(("data",), None))
        step = make_lm_train_step(cfg, opt)
        with set_mesh(mesh):
            compiled = jax.jit(step, donate_argnums=0).lower(
                (params_sds, ostate), {"tokens": tokens}
            ).compile()
        cost = compiled.cost_analysis()
        assert (cost[0] if isinstance(cost, (list, tuple)) else cost)["flops"] > 0
        print(arch, "lowered+compiled on 2x4 mesh OK")
    print("DONE")
    """
)


def test_lm_sharding_rules_compile_small_mesh():
    res = subprocess.run(
        [sys.executable, "-c", SMALL_MESH_SCRIPT],
        capture_output=True, text=True,
        cwd=__file__.rsplit("/", 2)[0], timeout=900,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "DONE" in res.stdout


def test_cooc_run_end_to_end_and_resume(tmp_path):
    from repro.core.oracle import brute_force_counts
    from repro.data.corpus import synthetic_zipf_collection
    from repro.data.preprocess import remap_df_descending
    from repro.launch.cooc_run import run

    out = str(tmp_path / "run1")
    res = run(num_docs=200, vocab=300, method="freq-split", num_shards=5,
              out_dir=out, ckpt_every=2)
    # exactness of the merged result
    c = synthetic_zipf_collection(200, vocab=300, mean_len=60, seed=0)
    cd, _ = remap_df_descending(c)
    oracle = brute_force_counts(cd)
    assert res["exact"] is True
    assert res["distinct_pairs"] == int((oracle > 0).sum())
    assert res["total_count"] == int(oracle.sum())
    # resume from the checkpoint: counts must not double
    res2 = run(num_docs=200, vocab=300, method="freq-split", num_shards=5,
               out_dir=out, ckpt_every=2, resume=True)
    assert res2["exact"] is True
    assert res2["total_count"] == res["total_count"]


def test_cooc_run_large_vocab_exact(tmp_path):
    """vocab > dense_vocab_cap used to fall back to a lossy StatsSink
    aggregate ('upper bound across shards'); the plan executor must now merge
    exactly via spilled runs — identical to a dense run of the same corpus."""
    from repro.launch.cooc_run import run

    dense = run(num_docs=150, vocab=400, method="auto", num_shards=4,
                out_dir=str(tmp_path / "dense"), dense_vocab_cap=4096)
    spill = run(num_docs=150, vocab=400, method="auto", num_shards=4,
                out_dir=str(tmp_path / "spill"), dense_vocab_cap=64)
    assert dense["exact"] is True and spill["exact"] is True
    assert spill["distinct_pairs"] == dense["distinct_pairs"]
    assert spill["total_count"] == dense["total_count"]
    # the paper-format output files are byte-identical across merge policies
    with open(tmp_path / "dense" / "pairs.bin", "rb") as a, \
         open(tmp_path / "spill" / "pairs.bin", "rb") as b:
        assert a.read() == b.read()


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes

    hlo = """
      %ag = bf16[16,512]{1,0} all-gather(bf16[16,32]{1,0} %x), dimensions={1}
      %ar = f32[1024]{0} all-reduce(f32[1024]{0} %y), to_apply=%sum
      %t = (f32[256]{0}, f32[256]{0}) all-reduce(f32[256]{0} %a, f32[256]{0} %b)
      %cp = u32[64,2]{1,0} collective-permute(u32[64,2]{1,0} %z)
      %done = f32[8]{0} all-reduce-done(f32[8]{0} %h)
    """
    got = collective_bytes(hlo)
    assert got["all-gather"] == 16 * 512 * 2
    assert got["all-reduce"] == 1024 * 4 + 2 * 256 * 4
    assert got["collective-permute"] == 64 * 2 * 4


def test_serve_driver_generates():
    from repro.launch.serve import serve

    out, stats = serve("olmoe-1b-7b", batch=2, prompt_len=8, gen=4)
    assert out.shape == (2, 4)
    assert stats["decode_tokens_per_s"] > 0


def test_fit_spec_divisibility():
    import os
    from jax.sharding import PartitionSpec as P

    # uses the default single-device "mesh" workaround: construct via jax
    import jax
    from repro.launch.specs import _fit_spec

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)

    spec = _fit_spec((73448, 2560), P("model", "data"), FakeMesh)
    assert spec == P(None, "data")  # 73448 % 16 != 0 → replicated
    spec = _fit_spec((128, 64), P(("data", "model"), None), FakeMesh)
    assert spec == P(None, None)  # 128 % 256 != 0
    spec = _fit_spec((512, 64), P(("data", "model"), None), FakeMesh)
    assert spec == P(("data", "model"), None)
