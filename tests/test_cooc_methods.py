"""Every counting method must equal the brute-force oracle EXACTLY
(integer counts — the paper's whole point is exactness, so no allclose)."""

import os

import numpy as np
import pytest

from repro.core.cooc import METHODS, dense_counts
from repro.core.oracle import brute_force_counts
from repro.core.stats import pmi_matrix, ppmi_matrix, top_k_pairs
from repro.core.types import DenseSink, FileSink, StatsSink, read_pair_file
from repro.core.naive import count_naive
from repro.core.list_scan import count_list_scan
from repro.data.corpus import synthetic_zipf_collection
from repro.data.preprocess import remap_df_descending

PAPER_METHODS = ["naive", "list-pairs", "list-blocks", "list-scan", "multi-scan"]
TPU_METHODS = [
    "list-pairs-bitpacked",
    "list-blocks-gram",
    "list-scan-segment",
    "multi-scan-matmul",
]


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(80, vocab=150, mean_len=14, seed=11)


@pytest.fixture(scope="module")
def oracle(coll):
    return brute_force_counts(coll)


@pytest.mark.parametrize("method", PAPER_METHODS)
def test_paper_method_exact(method, coll, oracle):
    assert np.array_equal(dense_counts(method, coll), oracle)


@pytest.mark.parametrize("method", TPU_METHODS)
def test_tpu_method_exact(method, coll, oracle):
    # use_kernel=False: oracle jnp path (the Pallas path is swept separately
    # in test_kernels.py; both paths share the exact same semantics)
    assert np.array_equal(dense_counts(method, coll, use_kernel=False), oracle)


@pytest.mark.parametrize("method", ["list-blocks-gram", "list-pairs-bitpacked"])
def test_tpu_method_exact_with_pallas_interpret(method, coll, oracle):
    small = coll.head(30)
    assert np.array_equal(
        dense_counts(method, small, use_kernel=True), brute_force_counts(small)
    )


def test_freq_split_exact(coll):
    cd, _ = remap_df_descending(coll)
    assert np.array_equal(
        dense_counts("freq-split", cd, head=32, use_kernel=False),
        brute_force_counts(cd),
    )


@pytest.mark.parametrize("head", [0, 1, 64, 10_000])
def test_freq_split_head_boundaries(coll, head):
    """Degenerate splits: all-tail (head=0) and all-head (head >= V)."""
    cd, _ = remap_df_descending(coll)
    assert np.array_equal(
        dense_counts("freq-split", cd, head=head, use_kernel=False),
        brute_force_counts(cd),
    )


def test_naive_flushing_equivalence(coll, oracle):
    """Flush thresholds change run structure, never results (paper: 100M)."""
    for flush in [50, 1000, 10**9]:
        sink = DenseSink(coll.vocab_size)
        stats = count_naive(coll, sink, flush_pairs=flush)
        assert np.array_equal(sink.mat, oracle)
        if flush == 50:
            assert stats["num_flushes"] > 1
        assert stats["peak_dict_pairs"] <= max(flush, stats["peak_dict_pairs"])


def test_list_blocks_block_size_sweep(coll, oracle):
    for bs in [1, 7, 13, 150, 1000]:
        assert np.array_equal(
            dense_counts("list-blocks", coll, block_size=bs), oracle
        )


def test_multi_scan_accumulator_sweep(coll, oracle):
    for a in [1, 3, 100, 10_000]:
        assert np.array_equal(dense_counts("multi-scan", coll, accumulators=a), oracle)


def test_counts_bounded_by_df(coll, oracle):
    df = np.bincount(coll.terms, minlength=coll.vocab_size)
    i, j = np.nonzero(oracle)
    assert np.all(oracle[i, j] <= np.minimum(df[i], df[j]))


def test_file_sink_roundtrip(tmp_path, coll, oracle):
    path = os.path.join(tmp_path, "pairs.bin")
    sink = FileSink(path)
    count_list_scan(coll, sink)
    sink.close()
    mat = np.zeros_like(oracle)
    for primary, secondaries, counts in read_pair_file(path):
        mat[primary, secondaries.astype(np.int64)] += counts.astype(np.int64)
    assert np.array_equal(mat, oracle)


def test_stats_sink_aggregates(coll, oracle):
    sink = StatsSink()
    count_list_scan(coll, sink)
    assert sink.distinct_pairs == int((oracle > 0).sum())
    assert sink.total_count == int(oracle.sum())
    i, j = sink.max_pair
    assert oracle[i, j] == oracle.max()


def test_most_frequent_pair_is_high_df(coll, oracle):
    """Paper §3: the most frequent pair was "to"–"the" — the two most common
    terms. On a Zipf corpus the max-count pair must be among high-df terms."""
    df = np.bincount(coll.terms, minlength=coll.vocab_size)
    (i, j, cnt) = top_k_pairs(oracle, 1)[0]
    top_df_terms = set(np.argsort(-df)[:10].tolist())
    assert i in top_df_terms and j in top_df_terms
    assert cnt == oracle.max()


def test_pmi_ppmi(coll, oracle):
    df = np.bincount(coll.terms, minlength=coll.vocab_size)
    pmi = pmi_matrix(oracle, df, coll.num_docs)
    ppmi = ppmi_matrix(oracle, df, coll.num_docs)
    assert np.all(ppmi >= 0)
    i, j = np.nonzero(oracle)
    k = (i[0], j[0])
    expected = np.log(
        oracle[k] * coll.num_docs / (df[k[0]] * df[k[1]])
    )
    assert np.isclose(pmi[k], expected)
    assert np.isclose(ppmi[k], max(expected, 0.0))


def test_all_registered_methods_run(coll):
    assert set(PAPER_METHODS + TPU_METHODS + ["freq-split"]) == set(METHODS)


# ------------------------------------------------- vectorized hot loops
class RecordingSink:
    """Captures the exact emitted row stream — order, splits, and values —
    so vectorized emission paths can be compared to their loop baselines
    stream-for-stream, not just on the dense sum."""

    def __init__(self):
        self.rows = []

    def emit_row(self, primary, secondaries, counts):
        self.rows.append(
            (int(primary), np.asarray(secondaries).copy(),
             np.asarray(counts).copy())
        )


def assert_same_stream(a, b):
    assert len(a.rows) == len(b.rows)
    for (pa, sa, ca), (pb, sb, cb) in zip(a.rows, b.rows):
        assert pa == pb
        assert np.array_equal(sa, sb)
        assert np.array_equal(ca, cb)


@pytest.mark.parametrize("rows_per_batch", [1, 3, 64, 1024])
def test_list_scan_vectorized_identical_to_loop(coll, rows_per_batch):
    """The batched-histogram LIST-SCAN emits the exact row stream of the
    per-document loop baseline, at any batch size (both the dense-bincount
    and the sparse sort-aggregate regimes)."""
    from repro.core.list_scan import count_list_scan_loop

    vec, loop = RecordingSink(), RecordingSink()
    stats_vec = count_list_scan(coll, vec, rows_per_batch=rows_per_batch)
    stats_loop = count_list_scan_loop(coll, loop)
    assert_same_stream(vec, loop)
    assert stats_vec == stats_loop


def test_list_scan_vectorized_identical_on_random_corpora():
    """Same stream identity over corpora shaped to hit edge cases: tiny
    vocab, empty documents region, single doc, dense co-occurrence."""
    from repro.core.list_scan import count_list_scan_loop

    for docs, vocab, mean_len, seed in [
        (1, 5, 2, 0), (12, 8, 4, 1), (60, 400, 6, 2), (40, 32, 20, 3),
    ]:
        c = synthetic_zipf_collection(docs, vocab=vocab, mean_len=mean_len, seed=seed)
        vec, loop = RecordingSink(), RecordingSink()
        count_list_scan(c, vec, rows_per_batch=7)
        count_list_scan_loop(c, loop)
        assert_same_stream(vec, loop)


def test_emit_dense_rows_identical_to_loop_reference():
    """Tile-level nonzero+split emission equals the per-row loop it
    replaced, including strict-upper masking at every tile offset."""
    from repro.core.types import emit_dense_rows

    def loop_reference(mat, sink, row_lo=0, col_lo=0):
        for r in range(mat.shape[0]):
            primary = row_lo + r
            row = mat[r]
            nz = np.nonzero(row)[0]
            nz = nz[nz + col_lo > primary]
            if len(nz):
                sink.emit_row(primary, nz + col_lo, row[nz])

    rng = np.random.default_rng(5)
    for shape, row_lo, col_lo in [
        ((8, 8), 0, 0), ((8, 8), 4, 0), ((8, 8), 0, 4), ((5, 9), 3, 7),
        ((1, 1), 0, 0), ((6, 6), 100, 100), ((4, 4), 2, 2),
    ]:
        mat = (rng.random(shape) < 0.4) * rng.integers(1, 50, shape)
        vec, ref = RecordingSink(), RecordingSink()
        emit_dense_rows(mat, vec, row_lo=row_lo, col_lo=col_lo)
        loop_reference(mat, ref, row_lo=row_lo, col_lo=col_lo)
        assert_same_stream(vec, ref)
    # all-zero tile emits nothing
    empty = RecordingSink()
    emit_dense_rows(np.zeros((4, 4), dtype=np.int64), empty)
    assert empty.rows == []
