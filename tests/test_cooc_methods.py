"""Every counting method must equal the brute-force oracle EXACTLY
(integer counts — the paper's whole point is exactness, so no allclose)."""

import os

import numpy as np
import pytest

from repro.core.cooc import METHODS, dense_counts
from repro.core.oracle import brute_force_counts
from repro.core.stats import pmi_matrix, ppmi_matrix, top_k_pairs
from repro.core.types import DenseSink, FileSink, StatsSink, read_pair_file
from repro.core.naive import count_naive
from repro.core.list_scan import count_list_scan
from repro.data.corpus import synthetic_zipf_collection
from repro.data.preprocess import remap_df_descending

PAPER_METHODS = ["naive", "list-pairs", "list-blocks", "list-scan", "multi-scan"]
TPU_METHODS = [
    "list-pairs-bitpacked",
    "list-blocks-gram",
    "list-scan-segment",
    "multi-scan-matmul",
]


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(80, vocab=150, mean_len=14, seed=11)


@pytest.fixture(scope="module")
def oracle(coll):
    return brute_force_counts(coll)


@pytest.mark.parametrize("method", PAPER_METHODS)
def test_paper_method_exact(method, coll, oracle):
    assert np.array_equal(dense_counts(method, coll), oracle)


@pytest.mark.parametrize("method", TPU_METHODS)
def test_tpu_method_exact(method, coll, oracle):
    # use_kernel=False: oracle jnp path (the Pallas path is swept separately
    # in test_kernels.py; both paths share the exact same semantics)
    assert np.array_equal(dense_counts(method, coll, use_kernel=False), oracle)


@pytest.mark.parametrize("method", ["list-blocks-gram", "list-pairs-bitpacked"])
def test_tpu_method_exact_with_pallas_interpret(method, coll, oracle):
    small = coll.head(30)
    assert np.array_equal(
        dense_counts(method, small, use_kernel=True), brute_force_counts(small)
    )


def test_freq_split_exact(coll):
    cd, _ = remap_df_descending(coll)
    assert np.array_equal(
        dense_counts("freq-split", cd, head=32, use_kernel=False),
        brute_force_counts(cd),
    )


@pytest.mark.parametrize("head", [0, 1, 64, 10_000])
def test_freq_split_head_boundaries(coll, head):
    """Degenerate splits: all-tail (head=0) and all-head (head >= V)."""
    cd, _ = remap_df_descending(coll)
    assert np.array_equal(
        dense_counts("freq-split", cd, head=head, use_kernel=False),
        brute_force_counts(cd),
    )


def test_naive_flushing_equivalence(coll, oracle):
    """Flush thresholds change run structure, never results (paper: 100M)."""
    for flush in [50, 1000, 10**9]:
        sink = DenseSink(coll.vocab_size)
        stats = count_naive(coll, sink, flush_pairs=flush)
        assert np.array_equal(sink.mat, oracle)
        if flush == 50:
            assert stats["num_flushes"] > 1
        assert stats["peak_dict_pairs"] <= max(flush, stats["peak_dict_pairs"])


def test_list_blocks_block_size_sweep(coll, oracle):
    for bs in [1, 7, 13, 150, 1000]:
        assert np.array_equal(
            dense_counts("list-blocks", coll, block_size=bs), oracle
        )


def test_multi_scan_accumulator_sweep(coll, oracle):
    for a in [1, 3, 100, 10_000]:
        assert np.array_equal(dense_counts("multi-scan", coll, accumulators=a), oracle)


def test_counts_bounded_by_df(coll, oracle):
    df = np.bincount(coll.terms, minlength=coll.vocab_size)
    i, j = np.nonzero(oracle)
    assert np.all(oracle[i, j] <= np.minimum(df[i], df[j]))


def test_file_sink_roundtrip(tmp_path, coll, oracle):
    path = os.path.join(tmp_path, "pairs.bin")
    sink = FileSink(path)
    count_list_scan(coll, sink)
    sink.close()
    mat = np.zeros_like(oracle)
    for primary, secondaries, counts in read_pair_file(path):
        mat[primary, secondaries.astype(np.int64)] += counts.astype(np.int64)
    assert np.array_equal(mat, oracle)


def test_stats_sink_aggregates(coll, oracle):
    sink = StatsSink()
    count_list_scan(coll, sink)
    assert sink.distinct_pairs == int((oracle > 0).sum())
    assert sink.total_count == int(oracle.sum())
    i, j = sink.max_pair
    assert oracle[i, j] == oracle.max()


def test_most_frequent_pair_is_high_df(coll, oracle):
    """Paper §3: the most frequent pair was "to"–"the" — the two most common
    terms. On a Zipf corpus the max-count pair must be among high-df terms."""
    df = np.bincount(coll.terms, minlength=coll.vocab_size)
    (i, j, cnt) = top_k_pairs(oracle, 1)[0]
    top_df_terms = set(np.argsort(-df)[:10].tolist())
    assert i in top_df_terms and j in top_df_terms
    assert cnt == oracle.max()


def test_pmi_ppmi(coll, oracle):
    df = np.bincount(coll.terms, minlength=coll.vocab_size)
    pmi = pmi_matrix(oracle, df, coll.num_docs)
    ppmi = ppmi_matrix(oracle, df, coll.num_docs)
    assert np.all(ppmi >= 0)
    i, j = np.nonzero(oracle)
    k = (i[0], j[0])
    expected = np.log(
        oracle[k] * coll.num_docs / (df[k[0]] * df[k[1]])
    )
    assert np.isclose(pmi[k], expected)
    assert np.isclose(ppmi[k], max(expected, 0.0))


def test_all_registered_methods_run(coll):
    assert set(PAPER_METHODS + TPU_METHODS + ["freq-split"]) == set(METHODS)
