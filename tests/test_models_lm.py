"""Per-arch LM smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness checks, decode ≡ full-forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.launch.train import make_lm_train_step, pick_optimizer
from repro.models import transformer as T
from repro.models.layers import blocked_attention, decode_attention

LM_ARCHS = [
    "deepseek-v3-671b",
    "olmoe-1b-7b",
    "qwen1.5-110b",
    "minicpm3-4b",
    "nemotron-4-340b",
]


@pytest.fixture(scope="module", params=LM_ARCHS)
def arch_setup(request):
    spec = get_spec(request.param)
    cfg = spec.smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    return request.param, cfg, params, tokens


def test_forward_shapes_finite(arch_setup):
    arch, cfg, params, tokens = arch_setup
    logits, h, aux = T.forward(params, tokens, cfg)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert h.shape == (2, 24, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


def test_train_step_reduces_loss(arch_setup):
    arch, cfg, params, tokens = arch_setup
    opt, _ = pick_optimizer(cfg.num_params())
    step = jax.jit(make_lm_train_step(cfg, opt))
    state = (params, opt.init(params))
    batch = {"tokens": tokens}
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease: {losses}"


def test_decode_matches_forward(arch_setup):
    """Greedy decode over the cache must reproduce full-forward logits at
    every position (GQA cached path AND absorbed-MLA latent path)."""
    arch, cfg, params, tokens = arch_setup
    B, S = tokens.shape
    logits_full, _, _ = T.forward(params, tokens, cfg)
    cache = T.init_cache(cfg, B, S)
    errs = []
    for t in range(S):
        logits_t, cache = T.decode_step(
            params, cache, tokens[:, t : t + 1], jnp.int32(t), cfg
        )
        errs.append(np.abs(np.asarray(logits_t) - np.asarray(logits_full[:, t])).max())
    assert max(errs) < 2e-2, f"{arch}: decode/forward mismatch {max(errs)}"


def test_prefill_then_decode(arch_setup):
    arch, cfg, params, tokens = arch_setup
    B, S = tokens.shape
    last_logits, cache = T.prefill(params, tokens[:, :-1], cfg)
    # pad the prefill cache out to S positions for the decode step
    full = T.init_cache(cfg, B, S)
    full = jax.tree.map(
        lambda f, p: jax.lax.dynamic_update_slice(
            f, p.astype(f.dtype), (0,) * f.ndim
        ),
        full,
        cache,
    )
    logits_t, _ = T.decode_step(params, full, tokens[:, -1:], jnp.int32(S - 1), cfg)
    logits_full, _, _ = T.forward(params, tokens, cfg)
    np.testing.assert_allclose(
        np.asarray(logits_t), np.asarray(logits_full[:, -1]), atol=2e-2, rtol=1e-3
    )


def test_param_count_matches_reference():
    """Full configs must land near the published sizes."""
    expected = {
        "deepseek-v3-671b": (600e9, 800e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "qwen1.5-110b": (100e9, 120e9),
        "minicpm3-4b": (3.5e9, 5e9),
        "nemotron-4-340b": (320e9, 360e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_spec(arch).model.num_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"


def test_blocked_attention_matches_dense():
    """Blocked flash attention ≡ dense softmax attention (causal + bidir),
    including GQA head grouping."""
    rng = np.random.default_rng(0)
    B, Sq, H, K, Dh = 2, 33, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Sq, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Sq, K, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Sq, K, Dh)).astype(np.float32))
    for causal in (True, False):
        got = blocked_attention(q, k, v, causal=causal, q_chunk=8, kv_chunk=8)
        # dense reference
        kk = jnp.repeat(k, H // K, axis=2)
        vv = jnp.repeat(v, H // K, axis=2)
        s = jnp.einsum("bqhd,bshd->bhqs", q, kk) * Dh**-0.5
        if causal:
            mask = jnp.tril(jnp.ones((Sq, Sq), bool))
            s = jnp.where(mask[None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        want = jnp.einsum("bhqs,bshd->bqhd", p, vv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_attention_matches_dense():
    rng = np.random.default_rng(1)
    B, S, H, K, Dh = 2, 40, 4, 4, 8
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, K, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, K, Dh)).astype(np.float32))
    pos = 17
    got = decode_attention(q, k, v, jnp.int32(pos))
    s = jnp.einsum("bqhd,bshd->bhqs", q, k) * Dh**-0.5
    s = jnp.where((jnp.arange(S) <= pos)[None, None, None], s, -jnp.inf)
    want = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor ≥ 1 and balanced-ish routing, most tokens keep
    their experts; the aux loss must stay near its balanced value (≈1)."""
    spec = get_spec("olmoe-1b-7b")
    cfg = spec.smoke()
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab_size)
    _, _, aux = T.forward(params, tokens, cfg)
    assert 0.5 < float(aux) / cfg.n_layers < 3.0


def test_unroll_layers_equivalence():
    spec = get_spec("qwen1.5-110b")
    cfg = spec.smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    a, _, _ = T.forward(params, tokens, cfg)
    b, _, _ = T.forward(params, tokens, dataclasses.replace(cfg, unroll_layers=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
