"""Typed query-request API: construction-time validation, planner routing,
coalesced execution, shim byte-identity, and streaming top-k."""

import numpy as np
import pytest

from repro.core.cooc import count_to_store
from repro.store import (
    NeighboursRequest,
    PairCountsRequest,
    QueryEngine,
    QueryPlanner,
    Store,
    TopKRequest,
    route_term,
)
from repro.store.requests import coalesce, route_terms

from repro.data.corpus import synthetic_zipf_collection


@pytest.fixture(scope="module")
def coll():
    return synthetic_zipf_collection(200, vocab=256, mean_len=14, seed=9)


@pytest.fixture(scope="module")
def store_path(coll, tmp_path_factory):
    path = str(tmp_path_factory.mktemp("requests") / "store")
    count_to_store("list-scan", coll, path)
    return path


@pytest.fixture()
def engine(store_path):
    return QueryEngine(Store.open(store_path))


# -------------------------------------------------------------- validation
def test_topk_request_validation():
    req = TopKRequest([3, 17], k=5, score="pmi")
    assert req.terms.dtype == np.int64 and req.batch == 2
    with pytest.raises(ValueError, match="unknown score"):
        TopKRequest([1], score="cosine")
    with pytest.raises(ValueError, match="k must be"):
        TopKRequest([1], k=0)
    with pytest.raises(ValueError, match="integer term ids"):
        TopKRequest(np.array([1.5, 2.0]))
    with pytest.raises(ValueError, match="1-D"):
        TopKRequest(np.zeros((2, 2), dtype=np.int64))
    with pytest.raises(ValueError, match="chunk must be"):
        TopKRequest([1], chunk=0)
    # scalars and empty batches normalize
    assert TopKRequest(7).terms.tolist() == [7]
    assert TopKRequest([]).batch == 0


def test_pair_counts_request_validation():
    req = PairCountsRequest([3, 17])          # (2,) -> (1, 2)
    assert req.pairs.shape == (1, 2) and req.pairs.dtype == np.int64
    with pytest.raises(ValueError, match=r"shape \(B, 2\)"):
        PairCountsRequest(np.zeros((3, 3), dtype=np.int64))
    with pytest.raises(ValueError, match="integer term ids"):
        PairCountsRequest(np.array([[1.0, 2.0]]))


def test_neighbours_request_validation():
    assert NeighboursRequest(np.int64(3)).term == 3
    with pytest.raises(ValueError, match="integer id"):
        NeighboursRequest(3.5)
    with pytest.raises(ValueError, match="integer id"):
        NeighboursRequest("3")


def test_planner_rejects_non_requests():
    with pytest.raises(TypeError, match="not a query request"):
        QueryPlanner().plan([("topk", [1], 5)])
    with pytest.raises(ValueError, match="unknown kernel"):
        QueryPlanner(kernel="cuda")
    with pytest.raises(ValueError, match="workers"):
        QueryPlanner(workers=0)


# ----------------------------------------------------------------- routing
def test_route_term_deterministic_and_vectorized():
    terms = np.arange(1000)
    owners = route_terms(terms, 4)
    assert owners.min() >= 0 and owners.max() < 4
    assert all(route_term(int(t), 4) == owners[i] for i, t in enumerate(terms))
    # every worker owns a nontrivial slice of the vocabulary
    assert len(np.unique(owners)) == 4


def test_planner_splits_topk_by_owner():
    req = TopKRequest(np.arange(64), k=5)
    plan = QueryPlanner(workers=4, routing=True).plan([req])
    parts = plan.parts[0]
    assert 1 < len(parts) <= 4
    seen = np.concatenate([p.positions for p in parts])
    assert sorted(seen.tolist()) == list(range(64))  # exact partition
    for p in parts:
        assert (route_terms(p.request.terms, 4) == p.worker).all()
        np.testing.assert_array_equal(p.request.terms, req.terms[p.positions])
    by_worker = plan.by_worker()
    assert set(by_worker) == {p.worker for p in parts}


def test_planner_unrouted_and_special_cases():
    reqs = [
        TopKRequest(np.arange(64), k=5),
        TopKRequest(np.arange(64), k=1000, chunk=64),  # streams never split
        PairCountsRequest(np.array([[1, 2]])),         # pairs never split
        NeighboursRequest(5),
    ]
    plan = QueryPlanner(workers=4, routing=False).plan(reqs)
    assert all(len(p) == 1 and p[0].worker is None for p in plan.parts)
    plan = QueryPlanner(workers=4, routing=True).plan(reqs)
    assert len(plan.parts[1]) == 1     # stream: one worker owns the chunks
    assert plan.parts[1][0].worker == route_term(0, 4)
    assert len(plan.parts[2]) == 1
    assert plan.parts[3][0].worker == route_term(5, 4)
    assert plan.describe()["routing"] is True


# ------------------------------------------------------------- coalescing
def test_coalesce_groups_by_k_score():
    reqs = [
        TopKRequest([1], k=5),
        TopKRequest([2, 3], k=5),
        TopKRequest([4], k=5, score="pmi"),
        TopKRequest([5], k=100, chunk=10),
        PairCountsRequest(np.array([[1, 2]])),
        PairCountsRequest(np.array([[3, 4]])),
        NeighboursRequest(1),
    ]
    groups = coalesce(list(enumerate(reqs)))
    kinds = [g.kind for g in groups]
    assert kinds.count("topk") == 2          # (5, count) + (5, pmi)
    assert kinds.count("topk-stream") == 1
    assert kinds.count("pairs") == 1         # both pair requests together
    assert kinds.count("neighbours") == 1
    topk_count = next(g for g in groups if g.key == (5, "count"))
    assert [t for t, _ in topk_count.items] == [0, 1]


# ----------------------------------------------------- execution + shims
def test_execute_matches_shims_both_kernels(store_path):
    for kernel in ("numpy", "pallas"):
        eng = QueryEngine(Store.open(store_path), kernel=kernel)
        terms = np.array([0, 1, 2, 3, 250])
        pairs = np.array([[0, 1], [5, 5], [7, 200]])
        (ids, scores), counts, (nids, ncnts) = eng.execute([
            TopKRequest(terms, k=6, score="count"),
            PairCountsRequest(pairs),
            NeighboursRequest(3),
        ])
        rids, rscores = eng.topk(terms, k=6, score="count")
        np.testing.assert_array_equal(ids, rids)
        np.testing.assert_array_equal(scores, rscores)
        np.testing.assert_array_equal(counts, eng.pair_counts(pairs))
        np.testing.assert_array_equal(nids, eng.neighbours(3)[0])
        np.testing.assert_array_equal(ncnts, eng.neighbours(3)[1])


def test_execute_coalesces_same_k_score(engine):
    """Two (k, score)-compatible requests answer from one launch and still
    slice back to their individual results."""
    a, b = TopKRequest([1, 2], k=4), TopKRequest([3], k=4)
    (ia, sa), (ib, sb) = engine.execute([a, b])
    ref_i, ref_s = engine.topk([1, 2, 3], k=4)
    np.testing.assert_array_equal(np.concatenate([ia, ib]), ref_i)
    np.testing.assert_array_equal(np.concatenate([sa, sb]), ref_s)


def test_execute_raises_engine_canonical_oov(engine):
    with pytest.raises(ValueError, match="out-of-vocab"):
        engine.execute([TopKRequest([9999], k=3)])
    with pytest.raises(ValueError, match="out-of-vocab"):
        engine.execute([PairCountsRequest(np.array([[0, -4]]))])
    with pytest.raises(ValueError, match="out-of-vocab"):
        engine.execute([NeighboursRequest(9999)])


def test_neighbours_validates_oov(engine):
    """Satellite: neighbours raises the same informative error as topk and
    pair_counts instead of crashing (or silently reading) out of range."""
    with pytest.raises(ValueError, match="out-of-vocab"):
        engine.neighbours(10_000)
    with pytest.raises(ValueError, match="out-of-vocab"):
        engine.neighbours(-1)


# --------------------------------------------------------------- streaming
@pytest.mark.parametrize("score", ["count", "pmi"])
@pytest.mark.parametrize("k,chunk", [(11, 4), (4, 100), (257, 32)])
def test_stream_chunks_concatenate_to_monolithic(engine, score, k, chunk):
    terms = [0, 1, 2]
    chunks = list(engine.topk_stream(terms, k=k, score=score, chunk=chunk))
    mono_ids, mono_scores = engine.topk(terms, k=k, score=score)
    assert len(chunks) == max(-(-k // chunk), 1)
    assert all(c[0].shape[1] <= chunk for c in chunks)
    np.testing.assert_array_equal(
        np.concatenate([c[0] for c in chunks], axis=1), mono_ids)
    np.testing.assert_array_equal(
        np.concatenate([c[1] for c in chunks], axis=1), mono_scores)


def test_stream_is_score_ordered(engine):
    chunks = list(engine.topk_stream([1], k=20, chunk=6))
    scores = np.concatenate([c[1] for c in chunks], axis=1)[0]
    finite = scores[np.isfinite(scores.astype(np.float64))]
    assert (np.diff(finite.astype(np.float64)) <= 0).all()
