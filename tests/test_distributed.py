"""Distributed Gram accumulation — runs in a subprocess with 8 placeholder
devices so the main test process keeps a single device."""

import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np, jax, jax.numpy as jnp
    from repro.core.distributed import make_distributed_gram, gram_reference

    rng = np.random.default_rng(0)
    D, V = 128, 64
    B = (rng.random((D, V)) < 0.2).astype(np.float32)
    ref = np.asarray(gram_reference(jnp.asarray(B)))

    failures = []
    for shape, names in [((2, 4), ("data", "model")), ((2, 2, 2), ("pod", "data", "model"))]:
        mesh = jax.make_mesh(shape, names)
        for sched in ["allgather", "ring"]:
            out = np.asarray(make_distributed_gram(mesh, schedule=sched)(jnp.asarray(B)))
            if not np.array_equal(out, ref):
                failures.append((shape, sched))
    # collective audit: the ring schedule must lower to collective-permute,
    # the allgather schedule to all-gather
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    sh = jnp.asarray(B)
    ring_hlo = make_distributed_gram(mesh, schedule="ring").lower(sh).compile().as_text()
    ag_hlo = make_distributed_gram(mesh, schedule="allgather").lower(sh).compile().as_text()
    assert "collective-permute" in ring_hlo, "ring must use collective-permute"
    assert "all-gather" in ag_hlo, "allgather must use all-gather"
    assert not failures, failures
    print("OK")
    """
)


def test_distributed_gram_schedules():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        cwd=__file__.rsplit("/", 2)[0],
        timeout=600,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "OK" in res.stdout
