"""Docs can't silently rot: every ``>>>`` example in docs/*.md runs as a
doctest (tier-1 and the CI docs job), and every relative link/anchor in
docs/*.md + README.md must resolve."""

import doctest
import glob
import os
import re

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_PAGES = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
LINKED_PAGES = DOC_PAGES + [os.path.join(ROOT, "README.md")]

REQUIRED_PAGES = {
    "architecture.md", "formats.md", "methods.md", "serving.md",
    "observability.md", "streaming.md",
}

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def test_docs_pages_exist():
    names = {os.path.basename(p) for p in DOC_PAGES}
    assert REQUIRED_PAGES <= names, f"missing docs pages: {REQUIRED_PAGES - names}"


@pytest.mark.parametrize("path", DOC_PAGES, ids=os.path.basename)
def test_docs_doctests(path):
    """Run the page's fenced ``>>>`` examples; each page must carry at
    least one (docs without runnable examples rot undetected)."""
    result = doctest.testfile(
        path,
        module_relative=False,
        optionflags=doctest.NORMALIZE_WHITESPACE,
        verbose=False,
    )
    assert result.attempted > 0, f"{path} has no doctest examples"
    assert result.failed == 0, f"{result.failed} doctest failure(s) in {path}"


def _github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop non-word chars, spaces->dashes."""
    slug = heading.strip().lower()
    slug = re.sub(r"[`*_]", "", slug)
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def test_no_dead_links():
    problems = []
    for page in LINKED_PAGES:
        base = os.path.dirname(page)
        text = open(page, encoding="utf-8").read()
        for target in _LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue  # external: not checked offline
            path_part, _, anchor = target.partition("#")
            dest = page if not path_part else os.path.normpath(
                os.path.join(base, path_part)
            )
            if not os.path.exists(dest):
                problems.append(f"{os.path.relpath(page, ROOT)}: broken link {target}")
                continue
            if anchor and dest.endswith(".md"):
                headings = _HEADING_RE.findall(open(dest, encoding="utf-8").read())
                if anchor not in {_github_slug(h) for h in headings}:
                    problems.append(
                        f"{os.path.relpath(page, ROOT)}: missing anchor {target}"
                    )
    assert not problems, "\n".join(problems)
