"""Per-kernel validation: shape/dtype sweeps, Pallas interpret=True vs the
pure-jnp oracle in ref.py, plus exactness vs brute-force numpy."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------- cooc_gram
@pytest.mark.parametrize(
    "d,m,n",
    [
        (1, 1, 1),
        (8, 16, 16),
        (100, 50, 70),       # non-multiples force padding
        (256, 128, 128),     # exactly one block
        (300, 130, 257),     # multi-block + ragged
        (512, 256, 384),
    ],
)
def test_cooc_gram_shapes(d, m, n):
    bi = (RNG.random((d, m)) < 0.15).astype(np.float32)
    bj = (RNG.random((d, n)) < 0.15).astype(np.float32)
    got = np.asarray(ops.cooc_gram(bi, bj))
    want = bi.T.astype(np.int64) @ bj.astype(np.int64)
    assert got.shape == (m, n)
    assert np.array_equal(got.astype(np.int64), want)


@pytest.mark.parametrize("dtype", [np.float32, np.int8, np.int32, bool])
def test_cooc_gram_input_dtypes(dtype):
    bi = (RNG.random((64, 32)) < 0.2).astype(dtype)
    got = np.asarray(ops.cooc_gram(bi, bi))
    want = bi.astype(np.int64).T @ bi.astype(np.int64)
    assert np.array_equal(got.astype(np.int64), want)


@pytest.mark.parametrize("blk", [(128, 128, 256), (256, 128, 512), (128, 256, 1024)])
def test_cooc_gram_block_sweep(blk):
    bm, bn, bd = blk
    bi = (RNG.random((700, 200)) < 0.1).astype(np.float32)
    got = np.asarray(ops.cooc_gram(bi, bi, blk_m=bm, blk_n=bn, blk_d=bd))
    want = np.asarray(ref.cooc_gram_ref(jnp.asarray(bi), jnp.asarray(bi)))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_cooc_gram_kernel_vs_ref_oracle_is_gram():
    """ref.py oracle itself equals the mathematical definition."""
    bi = (RNG.random((128, 64)) < 0.3).astype(np.float32)
    want = bi.T @ bi
    got = np.asarray(ref.cooc_gram_ref(jnp.asarray(bi), jnp.asarray(bi)))
    np.testing.assert_allclose(got, want)


# ----------------------------------------------------------------- bitpair
@pytest.mark.parametrize(
    "m,n,w",
    [(1, 1, 1), (5, 9, 3), (64, 64, 128), (70, 130, 200), (128, 64, 257)],
)
def test_bitpair_shapes(m, n, w):
    wi = RNG.integers(0, 2**32, size=(m, w), dtype=np.uint32)
    wj = RNG.integers(0, 2**32, size=(n, w), dtype=np.uint32)
    got = np.asarray(ops.bitpair_popcount(wi, wj))
    want = np.asarray(
        ref.bitpair_popcount_ref(jnp.asarray(wi), jnp.asarray(wj))
    )
    assert got.shape == (m, n)
    assert np.array_equal(got, want)


def test_bitpair_against_set_intersection():
    """Bitmaps built from explicit posting lists: popcount == |A ∩ B|."""
    n_docs, n_terms = 1000, 12
    W = (n_docs + 31) // 32
    posts = [np.unique(RNG.integers(0, n_docs, size=RNG.integers(1, 200)))
             for _ in range(n_terms)]
    bits = np.zeros((n_terms, W), dtype=np.uint32)
    for t, ds in enumerate(posts):
        np.bitwise_or.at(bits[t], ds // 32, np.uint32(1) << (ds % 32).astype(np.uint32))
    got = np.asarray(ops.bitpair_popcount(bits, bits))
    for a in range(n_terms):
        for b in range(n_terms):
            assert got[a, b] == len(np.intersect1d(posts[a], posts[b]))


def test_bitpair_zero_words():
    wi = np.zeros((4, 8), dtype=np.uint32)
    assert np.all(np.asarray(ops.bitpair_popcount(wi, wi)) == 0)


# ------------------------------------------------------------- segment_hist
@pytest.mark.parametrize(
    "L,rows,vocab",
    [(1, 1, 1), (100, 4, 50), (512, 8, 128), (1000, 16, 300), (2048, 32, 513)],
)
def test_segment_hist_shapes(L, rows, vocab):
    ids = RNG.integers(-1, vocab, size=L).astype(np.int32)
    seg = RNG.integers(-1, rows, size=L).astype(np.int32)
    got = np.asarray(ops.segment_hist(ids, seg, num_rows=rows, vocab=vocab))
    want = np.asarray(ref.segment_hist_ref(jnp.asarray(ids), jnp.asarray(seg), rows, vocab))
    assert got.shape == (rows, vocab)
    assert np.array_equal(got, want)


def test_segment_hist_against_numpy_histogram():
    L, rows, vocab = 700, 5, 90
    ids = RNG.integers(0, vocab, size=L).astype(np.int32)
    seg = RNG.integers(0, rows, size=L).astype(np.int32)
    got = np.asarray(ops.segment_hist(ids, seg, num_rows=rows, vocab=vocab))
    want = np.zeros((rows, vocab), dtype=np.int64)
    np.add.at(want, (seg, ids), 1)
    assert np.array_equal(got.astype(np.int64), want)


def test_segment_hist_all_padding():
    ids = np.full(64, -1, dtype=np.int32)
    seg = np.full(64, -1, dtype=np.int32)
    got = np.asarray(ops.segment_hist(ids, seg, num_rows=3, vocab=10))
    assert np.all(got == 0)
