"""Per-arch smoke tests for GNN + recsys (reduced configs, one train step on
CPU, shape + finite checks; sampler correctness; embedding-bag semantics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_spec
from repro.data.sampler import csr_from_edge_index, random_graph, sample_blocks, sample_neighbors
from repro.launch.train import (
    make_gnn_batched_graphs_step,
    make_gnn_full_graph_step,
    make_gnn_sampled_step,
    make_recsys_train_step,
    pick_optimizer,
)
from repro.models import gnn as G
from repro.models import recsys as R

RNG = np.random.default_rng(0)


# ------------------------------------------------------------------- GNN
@pytest.fixture(scope="module")
def gnn_setup():
    cfg = get_spec("graphsage-reddit").smoke()
    params = G.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_gnn_full_graph_train_step(gnn_setup):
    cfg, params = gnn_setup
    N, E = 120, 480
    feats = jnp.asarray(RNG.normal(size=(N, cfg.d_in)).astype(np.float32))
    ei = jnp.asarray(RNG.integers(0, N, size=(2, E)).astype(np.int32))
    labels = jnp.asarray(RNG.integers(0, cfg.n_classes, N).astype(np.int32))
    mask = jnp.ones((N,), jnp.float32)
    opt, _ = pick_optimizer(0)
    step = jax.jit(make_gnn_full_graph_step(cfg, opt))
    state = (params, opt.init(params))
    losses = []
    for _ in range(5):
        state, m = step(state, feats, ei, labels, mask)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses) and losses[-1] < losses[0]


def test_gnn_sampled_train_step(gnn_setup):
    cfg, params = gnn_setup
    g = random_graph(500, 6, seed=1)
    feats = RNG.normal(size=(500, cfg.d_in)).astype(np.float32)
    seeds = np.arange(32)
    blocks = sample_blocks(g, seeds, cfg.sample_sizes, RNG)
    labels = jnp.asarray(RNG.integers(0, cfg.n_classes, 32).astype(np.int32))
    opt, _ = pick_optimizer(0)
    step = jax.jit(make_gnn_sampled_step(cfg, opt))
    state = (params, opt.init(params))
    state, m = step(
        state,
        jnp.asarray(feats[blocks[0]]),
        jnp.asarray(feats[blocks[1]]),
        jnp.asarray(feats[blocks[2]]),
        labels,
    )
    assert np.isfinite(float(m["loss"]))


def test_gnn_batched_graphs_step(gnn_setup):
    cfg, params = gnn_setup
    Bg, Nn, Ne = 16, 10, 20
    feats = jnp.asarray(RNG.normal(size=(Bg * Nn, cfg.d_in)).astype(np.float32))
    # edges within each graph (offset by graph)
    src = RNG.integers(0, Nn, size=(Bg, Ne)) + np.arange(Bg)[:, None] * Nn
    dst = RNG.integers(0, Nn, size=(Bg, Ne)) + np.arange(Bg)[:, None] * Nn
    ei = jnp.asarray(np.stack([src.ravel(), dst.ravel()]).astype(np.int32))
    gids = jnp.asarray(np.repeat(np.arange(Bg), Nn).astype(np.int32))
    labels = jnp.asarray(RNG.integers(0, cfg.n_classes, Bg).astype(np.int32))
    opt, _ = pick_optimizer(0)
    step = make_gnn_batched_graphs_step(cfg, opt)
    state = (params, opt.init(params))
    state, m = jax.jit(lambda s, f, e, g_, l: step(s, f, e, g_, l, Bg))(
        state, feats, ei, gids, labels
    )
    assert np.isfinite(float(m["loss"]))


def test_segment_aggregation_matches_dense():
    """segment_sum message passing ≡ dense adjacency matmul."""
    cfg = get_spec("graphsage-reddit").smoke()
    params = G.init_params(jax.random.PRNGKey(1), cfg)
    N = 30
    A = (RNG.random((N, N)) < 0.2).astype(np.float32)
    src, dst = np.nonzero(A.T)  # edge src→dst with A[dst, src] = 1
    feats = RNG.normal(size=(N, cfg.d_in)).astype(np.float32)
    ei = jnp.asarray(np.stack([src, dst]).astype(np.int32))
    out = G.forward_full_graph(params, jnp.asarray(feats), ei, cfg)
    # dense reference of the same two layers
    deg = np.maximum(A.sum(1, keepdims=True), 1.0)
    h = feats
    for l in range(cfg.n_layers):
        neigh = (A @ h) / deg
        p = params[f"layer{l}"]
        z = h @ np.asarray(p["w_self"]) + neigh @ np.asarray(p["w_neigh"]) + np.asarray(p["b"])
        z = np.maximum(z, 0)
        h = z / np.maximum(np.linalg.norm(z, axis=-1, keepdims=True), 1e-6)
    want = h @ np.asarray(params["head"]["w"]) + np.asarray(params["head"]["b"])
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)


def test_sampler_neighbors_are_real():
    g = random_graph(300, 5, seed=2)
    seeds = np.array([0, 5, 17, 200])
    nbrs = sample_neighbors(g, seeds, 8, RNG)
    assert nbrs.shape == (4, 8)
    for i, s in enumerate(seeds):
        actual = set(g.indices[g.indptr[s]:g.indptr[s + 1]].tolist())
        for x in nbrs[i]:
            assert int(x) in actual or (not actual and x == s)


def test_csr_roundtrip():
    ei = np.array([[0, 1, 2, 2], [1, 2, 0, 1]], dtype=np.int32)
    g = csr_from_edge_index(ei, 3)
    assert g.num_edges == 4
    assert set(g.indices[g.indptr[1]:g.indptr[2]].tolist()) == {0, 2}


# ---------------------------------------------------------------- recsys
def _batch_for(cfg, B):
    if cfg.kind == "dien":
        return dict(
            hist_items=jnp.asarray(RNG.integers(-1, cfg.n_items, (B, cfg.seq_len)).astype(np.int32)),
            hist_cats=jnp.asarray(RNG.integers(-1, cfg.n_cats, (B, cfg.seq_len)).astype(np.int32)),
            target_item=jnp.asarray(RNG.integers(0, cfg.n_items, B).astype(np.int32)),
            target_cat=jnp.asarray(RNG.integers(0, cfg.n_cats, B).astype(np.int32)),
            label=jnp.asarray(RNG.integers(0, 2, B).astype(np.int32)),
        )
    if cfg.kind == "bert4rec":
        return dict(
            items=jnp.asarray(RNG.integers(0, cfg.n_items + 1, (B, cfg.seq_len)).astype(np.int32)),
            positions=jnp.asarray(RNG.integers(0, cfg.seq_len, (B, cfg.n_masked)).astype(np.int32)),
            labels=jnp.asarray(RNG.integers(0, cfg.n_items, (B, cfg.n_masked)).astype(np.int32)),
        )
    if cfg.kind == "xdeepfm":
        ns = cfg.n_fields - cfg.n_multi_hot
        return dict(
            single_ids=jnp.asarray(
                np.stack([RNG.integers(0, v, B) for v in cfg.field_vocabs[:ns]], 1).astype(np.int32)
            ),
            multi_ids=jnp.asarray(
                RNG.integers(-1, min(cfg.field_vocabs[ns:]), (B, cfg.n_multi_hot, cfg.max_bag)).astype(np.int32)
            ),
            label=jnp.asarray(RNG.integers(0, 2, B).astype(np.int32)),
        )
    return dict(
        hist_items=jnp.asarray(RNG.integers(-1, cfg.n_items, (B, cfg.seq_len)).astype(np.int32)),
        target_item=jnp.asarray(RNG.integers(0, cfg.n_items, B).astype(np.int32)),
        label=jnp.asarray(RNG.integers(0, 2, B).astype(np.int32)),
    )


@pytest.mark.parametrize("arch", ["dien", "bert4rec", "xdeepfm", "bst"])
def test_recsys_train_step(arch):
    cfg = get_spec(arch).smoke()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    opt, _ = pick_optimizer(0)
    step = jax.jit(make_recsys_train_step(cfg, opt))
    state = (params, opt.init(params))
    batch = _batch_for(cfg, 16)
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0], f"{arch}: {losses}"


@pytest.mark.parametrize("arch", ["dien", "bert4rec", "xdeepfm", "bst"])
def test_recsys_retrieval_batched(arch):
    cfg = get_spec(arch).smoke()
    params = R.init_params(jax.random.PRNGKey(0), cfg)
    batch = jax.tree.map(lambda x: x[:1], _batch_for(cfg, 2))
    batch.pop("label", None)
    batch.pop("labels", None)
    cands = jnp.asarray(RNG.integers(0, max(cfg.n_items, 100), 128).astype(np.int32))
    scores = R.retrieval_scores(params, batch, cands, cfg)
    assert scores.shape == (128,)
    assert bool(jnp.all(jnp.isfinite(scores)))


def test_embedding_bag_semantics():
    """embedding_bag ≡ torch.nn.EmbeddingBag (sum/mean with padding)."""
    table = jnp.asarray(RNG.normal(size=(20, 4)).astype(np.float32))
    ids = jnp.asarray(np.array([[1, 3, -1, -1], [0, 0, 5, -1]], dtype=np.int32))
    s = R.embedding_bag(table, ids, "sum")
    np.testing.assert_allclose(
        np.asarray(s[0]), np.asarray(table[1] + table[3]), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(s[1]), np.asarray(table[0] * 2 + table[5]), rtol=1e-6
    )
    m = R.embedding_bag(table, ids, "mean")
    np.testing.assert_allclose(np.asarray(m[0]), np.asarray((table[1] + table[3]) / 2), rtol=1e-6)


def test_embedding_lookup_negative_ids_zero():
    table = jnp.asarray(RNG.normal(size=(10, 3)).astype(np.float32))
    out = R.embedding_lookup(table, jnp.asarray(np.array([-1, 2], np.int32)))
    assert np.all(np.asarray(out[0]) == 0)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(table[2]))


def test_cin_explicit_crosses():
    """CIN first layer ≡ explicit pairwise products compressed by W."""
    cfg = get_spec("xdeepfm").smoke()
    B, F, D = 3, cfg.n_fields, cfg.embed_dim
    x0 = jnp.asarray(RNG.normal(size=(B, F, D)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(F * F, 5)).astype(np.float32))
    out = R._cin([{"w": w}], x0)
    z = np.einsum("bhd,bmd->bhmd", x0, x0).reshape(B, F * F, D)
    want = np.einsum("bqd,qh->bhd", z, w).sum(-1)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=1e-4)


def test_augru_attention_gates():
    """AUGRU with zero attention must keep the state frozen at zero-init."""
    p = {
        "wx": jnp.asarray(RNG.normal(size=(4, 12)).astype(np.float32)),
        "wh": jnp.asarray(RNG.normal(size=(4, 12)).astype(np.float32)),
        "b": jnp.zeros((12,), jnp.float32),
    }
    xs = jnp.asarray(RNG.normal(size=(2, 6, 4)).astype(np.float32))
    frozen = R.augru(p, xs, jnp.zeros((2, 6)))
    assert np.allclose(np.asarray(frozen), 0)
    moving = R.augru(p, xs, jnp.ones((2, 6)))
    assert not np.allclose(np.asarray(moving), 0)
