"""Serving-kernel equivalence: the Pallas top-k gather (interpret mode on
CPU) must be **bit-identical** to the jitted-numpy reference scorer on every
query-engine edge case — empty rows, out-of-vocab terms, k larger than the
row nnz, and ties in count/PMI/Dice. Identity is asserted both at the raw
kernel level and end-to-end through two QueryEngines over the same store."""

import numpy as np
import pytest

from repro.core.cooc import count_to_store
from repro.data.corpus import synthetic_zipf_collection
from repro.data.preprocess import preprocess_documents
from repro.kernels.topk_gather import topk_gather
from repro.store import QueryEngine
from repro.store.query import _score_topk

SCORES = ["count", "pmi", "dice"]


def _reference(ids, cnts, df_t, df_n, num_docs, score, k):
    import jax.numpy as jnp

    ri, rs = _score_topk(
        jnp.asarray(ids), jnp.asarray(cnts), jnp.asarray(df_t),
        jnp.asarray(df_n), num_docs, score=score, k=k,
    )
    return np.asarray(ri), np.asarray(rs)


def _assert_identical(ids, cnts, df_t, df_n, num_docs, score, k):
    ri, rs = _reference(ids, cnts, df_t, df_n, num_docs, score, k)
    pi, ps = topk_gather(
        ids, cnts, df_t, df_n, num_docs=num_docs, score=score, k=k,
        interpret=True,
    )
    np.testing.assert_array_equal(ri, np.asarray(pi), err_msg=f"ids {score}")
    np.testing.assert_array_equal(rs, np.asarray(ps), err_msg=f"scores {score}")
    return ri, rs


# ------------------------------------------------------------- raw kernel
@pytest.mark.parametrize("score", SCORES)
def test_kernel_random_tiles_identical(score):
    rng = np.random.default_rng(3)
    for B, L, k in [(1, 8, 1), (4, 16, 5), (9, 130, 17)]:
        lens = rng.integers(0, L + 1, size=B)
        ids = np.full((B, L), -1, dtype=np.int64)
        cnts = np.zeros((B, L), dtype=np.int64)
        for b in range(B):
            n = int(lens[b])
            ids[b, :n] = np.sort(rng.choice(4 * L, size=n, replace=False))
            cnts[b, :n] = rng.integers(1, 6, size=n)  # narrow range: many ties
        df_t = rng.integers(1, 40, size=B)
        df_n = np.where(ids >= 0, rng.integers(1, 40, size=(B, L)), 1)
        _assert_identical(ids, cnts, df_t, df_n, 500, score, k)


@pytest.mark.parametrize("score", SCORES)
def test_kernel_all_empty_rows(score):
    """A tile of entirely empty rows: every slot padded, ids all -1."""
    B, L, k = 3, 8, 4
    ids = np.full((B, L), -1, dtype=np.int64)
    cnts = np.zeros((B, L), dtype=np.int64)
    df_t = np.ones(B, dtype=np.int64)
    df_n = np.ones((B, L), dtype=np.int64)
    ri, rs = _assert_identical(ids, cnts, df_t, df_n, 10, score, k)
    assert (ri == -1).all()
    if score == "count":
        assert (rs == 0).all()
    else:
        assert np.isneginf(rs).all()


@pytest.mark.parametrize("score", SCORES)
def test_kernel_ties_exact_order(score):
    """All-equal counts and dfs: every candidate ties; both kernels must
    agree on the full selection order (lowest slot index first)."""
    B, L, k = 2, 16, 16
    ids = np.tile(np.arange(10, 10 + L, dtype=np.int64), (B, 1))
    cnts = np.full((B, L), 7, dtype=np.int64)
    df_t = np.full(B, 3, dtype=np.int64)
    df_n = np.full((B, L), 5, dtype=np.int64)
    ri, _ = _assert_identical(ids, cnts, df_t, df_n, 100, score, k)
    np.testing.assert_array_equal(ri[0], np.arange(10, 10 + L))


def test_kernel_k_bounds():
    ids = np.array([[1, 2, -1, -1]])
    cnts = np.array([[1, 1, 0, 0]])
    with pytest.raises(ValueError, match="k=9"):
        topk_gather(ids, cnts, np.array([1]), np.ones_like(ids),
                    num_docs=10, k=9, interpret=True)
    with pytest.raises(ValueError, match="unknown score"):
        topk_gather(ids, cnts, np.array([1]), np.ones_like(ids),
                    num_docs=10, k=1, score="tfidf", interpret=True)


# ------------------------------------------------ end-to-end QueryEngine
@pytest.fixture(scope="module")
def engines(tmp_path_factory):
    docs = [[0, 1, 2], [0, 1], [3], [4, 5, 4], []]  # term 6 never occurs
    c = preprocess_documents(docs, vocab_size=8)
    store, _ = count_to_store(
        "list-scan", c, str(tmp_path_factory.mktemp("s") / "store")
    )
    return (
        QueryEngine(store, kernel="numpy"),
        QueryEngine(store, kernel="pallas", interpret=True),
    )


@pytest.mark.parametrize("score", SCORES)
def test_engine_empty_row_identical(engines, score):
    ref, pal = engines
    for eng in (ref, pal):
        ids, scores = eng.topk([6], k=3, score=score)  # term with no pairs
        assert (ids == -1).all()
    np.testing.assert_array_equal(*(e.topk([6], k=3, score=score)[0] for e in engines))


@pytest.mark.parametrize("score", SCORES)
def test_engine_k_exceeds_nnz_identical(engines, score):
    ref, pal = engines
    ri, rs = ref.topk([0, 3, 6], k=50, score=score)
    pi, ps = pal.topk([0, 3, 6], k=50, score=score)
    np.testing.assert_array_equal(ri, pi)
    np.testing.assert_array_equal(rs, ps)
    assert ri.shape == (3, 50) and (ri[2] == -1).all()


@pytest.mark.parametrize("kernel", ["numpy", "pallas"])
def test_engine_out_of_vocab_raises(engines, kernel):
    eng = engines[0] if kernel == "numpy" else engines[1]
    with pytest.raises(ValueError, match="out-of-vocab"):
        eng.topk([0, 8], k=2)
    with pytest.raises(ValueError, match="out-of-vocab"):
        eng.topk([-1], k=2)
    with pytest.raises(ValueError, match="out-of-vocab"):
        eng.pair_counts(np.array([[0, 99]]))


@pytest.mark.parametrize("score", SCORES)
def test_engine_zipf_store_identical(score, tmp_path):
    """Both kernels, whole-store sweep: identical ids AND scores."""
    c = synthetic_zipf_collection(150, vocab=96, mean_len=12, seed=4)
    store, _ = count_to_store("list-scan", c, str(tmp_path / "store"))
    ref = QueryEngine(store, kernel="numpy")
    pal = QueryEngine(store, kernel="pallas", interpret=True)
    terms = np.arange(96)
    ri, rs = ref.topk(terms, k=9, score=score)
    pi, ps = pal.topk(terms, k=9, score=score)
    np.testing.assert_array_equal(ri, pi)
    np.testing.assert_array_equal(rs, ps)
