"""Parallel ingest: spawned spill-shard workers behind a shared lease
tracker, plus the parallel bucket-merge finalizer, must produce output
byte-identical to the serial PlanExecutor — for every ingest method, any
worker count, and random corpora. Also unit-tests the SharedWorkTracker
lease discipline the workers coordinate through."""

import glob
import os
import tempfile
import time

import numpy as np
import pytest

try:  # the property test richens coverage when hypothesis is available;
    # the deterministic random-corpora sweep below always runs
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.list_scan import count_list_scan_loop
from repro.core.plan import CountJob, ParallelExecutor, Planner, PlanExecutor
from repro.core.types import DenseSink
from repro.data.corpus import synthetic_zipf_collection
from repro.data.preprocess import preprocess_documents, remap_df_descending
from repro.runtime.fault import SharedWorkTracker

# the ingest write-path methods (same set the ingest benchmark sweeps)
INGEST_METHODS = ["list-scan", "list-blocks", "freq-split", "list-scan-segment"]

VOCAB = 40


def random_corpus(seed: int):
    """A random raw corpus (duplicates, unsorted) through the full
    preprocessing path — deterministic per seed, so serial/parallel builds
    see the identical collection."""
    rng = np.random.default_rng(seed)
    docs = [
        rng.integers(0, VOCAB, size=int(rng.integers(0, 25))).tolist()
        for _ in range(int(rng.integers(1, 25)))
    ]
    return preprocess_documents(docs, vocab_size=VOCAB)


# ------------------------------------------------------------------ helpers
def build_store(cd, method, out_root, executor, *, num_shards=4, budget=512):
    """Plan + execute a store build under the spill policy (dense_vocab_cap
    is forced to 1 so even tiny test vocabularies take the spill path the
    parallel executor parallelizes)."""
    job = CountJob(
        collection=cd,
        output="store",
        out_path=os.path.join(out_root, "store"),
        method=method,
        num_shards=num_shards,
        dense_vocab_cap=1,
        memory_budget_pairs=budget,
        df_descending=True,
        use_kernel=False,
    )
    plan = Planner().plan(job)
    assert plan.sink_policy == "spill"
    res = executor.execute(plan, out_dir=os.path.join(out_root, "wd"))
    return res


def segment_files(store_dir):
    """{filename: bytes} of the store's single segment's binary arrays."""
    segs = sorted(glob.glob(os.path.join(store_dir, "seg-*")))
    assert len(segs) == 1, segs
    out = {}
    for p in sorted(glob.glob(os.path.join(segs[0], "*.bin"))):
        with open(p, "rb") as f:
            out[os.path.basename(p)] = f.read()
    assert out, "segment has no binary arrays"
    return out


# ------------------------------------------------- SharedWorkTracker units
def test_shared_tracker_flow(tmp_path):
    path = str(tmp_path / "claims.json")
    t = SharedWorkTracker.create(path, [(0,), (1,)], lease_seconds=30.0)
    u = t.claim("a")
    assert u == (0,)
    assert t.renew(u, "a") is True
    assert t.renew(u, "b") is False           # not the lease holder
    committed = []
    assert t.complete(u, "a", commit=lambda: committed.append(u)) is True
    assert committed == [u]
    assert t.complete(u, "a") is False        # duplicate ignored
    assert t.snapshot()["completions_ignored"] == 1
    # a second process opens the same state file and sees the same queue
    u2 = SharedWorkTracker.open(path).claim("b")
    assert u2 == (1,)
    assert not t.finished                     # (1,) still leased
    assert t.complete(u2, "b")
    assert t.finished
    assert t.done_units() == {(0,), (1,)}


def test_shared_tracker_ttl_reclaim(tmp_path):
    """A lease acquired and never renewed must not block the shard forever:
    a second claimer reclaims it once the TTL deadline passes."""
    t = SharedWorkTracker.create(
        str(tmp_path / "c.json"), [(0,)], lease_seconds=0.2
    )
    u = t.claim("dead")
    assert t.claim("live") is None            # lease still current
    time.sleep(0.3)
    assert t.claim("live") == u               # expired → reclaimed
    assert t.reclaims == 1
    assert t.renew(u, "dead") is False        # original lost the lease
    assert t.complete(u, "live")
    assert t.complete(u, "dead") is False     # late straggler ignored
    assert t.finished


def test_shared_tracker_renew_keeps_lease_alive(tmp_path):
    t = SharedWorkTracker.create(
        str(tmp_path / "c.json"), [(0,)], lease_seconds=0.3
    )
    u = t.claim("w")
    for _ in range(4):                        # heartbeats outlive the TTL
        time.sleep(0.15)
        assert t.renew(u, "w") is True
    assert t.claim("thief") is None           # never reclaimable while renewed
    assert t.complete(u, "w")


def test_shared_tracker_requeue_drops_done_record(tmp_path):
    t = SharedWorkTracker.create(str(tmp_path / "c.json"), [(3,)])
    u = t.claim("w")
    assert t.complete(u, "w")
    assert t.finished
    t.requeue(u)                              # committed artifact went missing
    assert not t.finished
    assert t.done_units() == set()
    assert t.claim("w2") == u


def test_shared_tracker_failed_commit_keeps_unit_undone(tmp_path):
    """complete() runs the commit under the lock BEFORE recording done — a
    commit that raises must leave the unit leased/undone, so the lease TTL
    eventually hands it to another worker."""
    t = SharedWorkTracker.create(
        str(tmp_path / "c.json"), [(0,)], lease_seconds=0.2
    )
    u = t.claim("w")

    def boom():
        raise RuntimeError("rename failed")

    with pytest.raises(RuntimeError, match="rename failed"):
        t.complete(u, "w", commit=boom)
    assert t.done_units() == set()
    time.sleep(0.3)
    assert t.claim("retry") == u


# ----------------------------------------------------- byte-identity tests
def _check_byte_identical(c, workers: int, method: str,
                          serial_cache: dict | None = None) -> None:
    """Parallel build vs serial build vs count_list_scan_loop-seeded oracle
    for one (corpus, worker count, method) combination."""
    cd, _ = remap_df_descending(c)
    oracle = DenseSink(cd.vocab_size)
    count_list_scan_loop(cd, oracle)
    with tempfile.TemporaryDirectory(prefix="par_prop_") as td:
        if serial_cache is not None and method in serial_cache:
            serial = serial_cache[method]
        else:
            a = os.path.join(td, "a")
            build_store(cd, method, a, PlanExecutor())
            serial = segment_files(os.path.join(a, "store"))
            if serial_cache is not None:
                serial_cache[method] = serial
        b = os.path.join(td, "b")
        res = build_store(
            cd, method, b, ParallelExecutor(num_workers=workers)
        )
        assert segment_files(os.path.join(b, "store")) == serial
        assert np.array_equal(res.store.dense(), oracle.mat)
        assert res.summary["ingest_workers"] == workers
        assert res.summary["exact"] is True


# serial reference bytes per method, shared across the worker-count sweep
# (the corpus is deterministic per method, so the reference is too)
_SERIAL_CACHE: dict = {}


@pytest.mark.parametrize("workers", [1, 2, 4])
@pytest.mark.parametrize("method", INGEST_METHODS)
def test_parallel_ingest_byte_identical(method, workers):
    """Random corpora, worker counts N ∈ {1, 2, 4}, every ingest method:
    the parallel build's segment is byte-for-byte the serial executor's,
    and both equal the count_list_scan_loop-seeded oracle."""
    c = random_corpus(seed=100 + INGEST_METHODS.index(method))
    _check_byte_identical(c, workers, method, serial_cache=_SERIAL_CACHE)


def test_parallel_ingest_empty_corpus():
    """Degenerate corpus (no pairs at all) still round-trips: empty shards
    promote, zero buckets merge, and the empty segments match."""
    c = preprocess_documents([[], [7], []], vocab_size=VOCAB)
    _check_byte_identical(c, 2, "list-scan")


if HAVE_HYPOTHESIS:
    documents = st.lists(
        st.lists(st.integers(0, VOCAB - 1), min_size=0, max_size=25),
        min_size=1,
        max_size=25,
    )

    @st.composite
    def corpora(draw):
        return preprocess_documents(draw(documents), vocab_size=VOCAB)

    @settings(max_examples=6, deadline=None)
    @given(corpora(), st.sampled_from([1, 2, 4]),
           st.sampled_from(INGEST_METHODS))
    def test_parallel_ingest_byte_identical_property(c, workers, method):
        _check_byte_identical(c, workers, method)


def test_parallel_merge_pool_explicit_below_threshold(tmp_path):
    """Small spills merge inline by default (pool spawn cost would dominate),
    but an explicit merge_workers= forces the bucket-merge process pool —
    which must still produce byte-identical segments."""
    c = random_corpus(seed=321)
    cd, _ = remap_df_descending(c)
    a = str(tmp_path / "a")
    build_store(cd, "list-scan", a, PlanExecutor())
    want = segment_files(os.path.join(a, "store"))
    b = str(tmp_path / "b")
    build_store(
        cd, "list-scan", b,
        ParallelExecutor(num_workers=2, merge_workers=2),
    )
    assert segment_files(os.path.join(b, "store")) == want


def test_parallel_pairs_file_identical(tmp_path):
    """The pairs-file output target goes through the same shared row
    emitter: parallel bytes == serial bytes."""
    c = synthetic_zipf_collection(150, vocab=500, mean_len=12, seed=13)
    cd, _ = remap_df_descending(c)

    def build(out_root, executor):
        job = CountJob(
            collection=cd,
            output="pairs-file",
            out_path=os.path.join(out_root, "pairs.bin"),
            method="list-scan",
            num_shards=5,
            memory_budget_pairs=1 << 12,
            df_descending=True,
            use_kernel=False,
        )
        plan = Planner().plan(job)
        assert plan.sink_policy == "spill"
        executor.execute(plan, out_dir=os.path.join(out_root, "wd"))
        with open(os.path.join(out_root, "pairs.bin"), "rb") as f:
            return f.read()

    a = build(str(tmp_path / "a"), PlanExecutor())
    b = build(str(tmp_path / "b"), ParallelExecutor(num_workers=2))
    assert a == b


def test_parallel_delegates_non_spill_policies(tmp_path):
    """Dense-policy plans fall back to the serial executor (in-memory merges
    gain nothing from process fan-out) and still produce exact output."""
    c = synthetic_zipf_collection(40, vocab=60, mean_len=8, seed=2)
    job = CountJob(collection=c, output="dense", method="list-scan")
    plan = Planner().plan(job)
    assert plan.sink_policy == "dense"
    res = ParallelExecutor(num_workers=2).execute(
        plan, out_dir=str(tmp_path / "wd")
    )
    from repro.core.oracle import brute_force_counts

    assert np.array_equal(res.counts, brute_force_counts(c))
